"""Direct unit tests for the ft/ watchdogs the serving engine arms around
every tick: StepWatchdog's rolling-median straggler detection (window
eviction, threshold boundary) and HangDetector's arm/disarm/fire-once
semantics.  Wall-clock-sensitive paths drive a monkeypatched
``time.perf_counter`` so the assertions are exact, not probabilistic.
"""

import threading
import time

import pytest

from repro.ft.watchdog import HangDetector, StepWatchdog


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture
def clock(monkeypatch):
    c = _FakeClock()
    monkeypatch.setattr("repro.ft.watchdog.time.perf_counter", c)
    return c


def _step(wd, clock, dt):
    wd.start()
    clock.t += dt
    return wd.stop()


# --------------------------------------------------------------------------- #
# StepWatchdog
# --------------------------------------------------------------------------- #

def test_no_flag_before_five_samples(clock):
    wd = StepWatchdog(threshold=2.0)
    for _ in range(4):
        assert not _step(wd, clock, 1.0)
    assert not _step(wd, clock, 100.0)      # 5th step: history still < 5
    assert wd.stragglers == []
    assert _step(wd, clock, 100.0)          # now the median exists
    assert wd.stragglers == [6]


def test_threshold_boundary_is_strict(clock):
    wd = StepWatchdog(threshold=2.0)
    for _ in range(5):
        _step(wd, clock, 1.0)
    assert not _step(wd, clock, 2.0)        # dt == threshold * median: no
    assert _step(wd, clock, 2.0 + 1e-9)     # strictly above: yes
    assert wd.stragglers == [7]


def test_window_eviction_shifts_median(clock):
    wd = StepWatchdog(window=6, threshold=2.0)
    for _ in range(6):
        _step(wd, clock, 1.0)
    assert wd.median == 1.0
    # fill the window with 10x steps; the 1.0s must be evicted
    for _ in range(6):
        _step(wd, clock, 10.0)
    assert wd.median == 10.0
    assert len(wd._times) == 6              # bounded by window
    # 10.0 is ordinary against the new median (would have been a
    # straggler against the evicted history)
    assert not _step(wd, clock, 10.0)


def test_start_required_before_stop(clock):
    wd = StepWatchdog()
    with pytest.raises(AssertionError):
        wd.stop()


def test_step_numbering_across_flags(clock):
    wd = StepWatchdog(threshold=2.0)
    for _ in range(5):
        _step(wd, clock, 1.0)
    _step(wd, clock, 5.0)
    for _ in range(3):
        _step(wd, clock, 1.0)
    _step(wd, clock, 5.0)
    assert wd.stragglers == [6, 10]


# --------------------------------------------------------------------------- #
# HangDetector
# --------------------------------------------------------------------------- #

def test_fires_once_when_deadline_overrun():
    fired = []
    hd = HangDetector(0.02, lambda: fired.append(1))
    with hd:
        time.sleep(0.1)
    assert hd.fired
    time.sleep(0.05)                        # no second callback later
    assert fired == [1]


def test_disarm_before_deadline_never_fires():
    fired = []
    hd = HangDetector(0.05, lambda: fired.append(1))
    with hd:
        pass                                # returns well inside deadline
    time.sleep(0.12)                        # past where the timer would be
    assert not hd.fired
    assert fired == []
    assert hd._timer is None                # fully disarmed


def test_rearm_resets_fired_flag():
    """One detector guards many ticks (the engine arms it per tick): a
    fired flag from a hung step must not leak into the next arm."""
    fired = []
    hd = HangDetector(0.02, lambda: fired.append(1))
    with hd:
        time.sleep(0.1)
    assert hd.fired and fired == [1]
    with hd:
        pass                                # fast step
    assert not hd.fired, "fired flag leaked across re-arm"
    assert fired == [1]


def test_exit_after_fire_is_clean():
    """Disarm racing the callback: __exit__ after the timer fired must
    not double-report or raise — cancel() on a completed Timer is a
    no-op, so the callback count stays exactly one per overrun arm."""
    calls = []
    hd = HangDetector(0.01, lambda: calls.append(threading.get_ident()))
    for _ in range(3):
        with hd:
            time.sleep(0.05)
        assert hd.fired
    assert len(calls) == 3                  # once per arm, never double


# --------------------------------------------------------------------------- #
# HangDetector re-arm races (ISSUE 10 pin) — fake clock, no sleeps
# --------------------------------------------------------------------------- #

def test_overrun_detected_even_when_timer_never_ran(clock):
    """The race the engine hit on back-to-back recoveries: a step
    overruns the deadline, but __exit__ cancels the Timer before its
    thread is ever scheduled.  The hang is real — the deadline elapsed —
    so __exit__ itself must detect the overrun from the (fake) clock and
    fire, deterministically, with no Timer thread involved at all."""
    fired = []
    # huge real timeout: the Timer thread can never be the one firing
    hd = HangDetector(10.0, lambda: fired.append(1))
    with hd:
        clock.t += 11.0                     # overrun, Timer still pending
    assert hd.fired
    assert fired == [1]
    assert hd._timer is None


def test_back_to_back_overruns_each_fire_once(clock):
    """Two consecutive hung recoveries: each arm observes ITS OWN
    overrun — the second hang must not be silently swallowed by state
    left over from the first (the re-arm bug this pins)."""
    fired = []
    hd = HangDetector(10.0, lambda: fired.append(len(fired) + 1))
    for arm in (1, 2):
        with hd:
            clock.t += 11.0
        assert hd.fired, f"arm {arm} missed its overrun"
    assert fired == [1, 2]
    # and a healthy arm in between resets cleanly
    with hd:
        clock.t += 1.0
    assert not hd.fired
    assert fired == [1, 2]


def test_stale_timer_fire_cannot_corrupt_next_arm(clock):
    """A Timer thread from arm N that slips past cancel() and runs
    during arm N+1 must be ignored: its generation is stale, so it
    neither flips ``fired`` nor invokes the callback against the
    healthy step."""
    fired = []
    hd = HangDetector(10.0, lambda: fired.append(1))
    with hd:
        stale_fire = hd._timer.function     # arm 1's pending callback
        clock.t += 1.0                      # arm 1 is healthy
    assert not hd.fired
    with hd:
        stale_fire()                        # arm 1's Timer runs late
        assert not hd.fired, "stale fire corrupted the live arm"
        clock.t += 1.0
    assert not hd.fired
    assert fired == []


def test_exit_and_timer_agree_on_single_fire(clock):
    """When the Timer DID fire and __exit__ also sees the overrun on the
    clock, exactly one of them reports: whoever flips ``fired`` first
    wins and the other stands down."""
    fired = []
    hd = HangDetector(10.0, lambda: fired.append(1))
    with hd:
        timer_fire = hd._timer.function
        clock.t += 11.0
        timer_fire()                        # Timer beats __exit__
        assert hd.fired
    assert fired == [1]                     # __exit__ stood down
