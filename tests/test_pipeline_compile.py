"""Staged compilation pipeline tests: PassManager ordering/stats, the
elementwise-chain fusion pass, Program save/load, autotune-cache persistence
(including across processes), and the ContinuousBatcher.run() regression."""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AutotunePolicy, DEFAULT_PASSES, FixedPolicy, Graph,
                        Node, PassManager, PipelineError, Program, TensorSpec,
                        compile, default_pipeline, fuse_elementwise, get_pass,
                        infer_shapes, load_program, register_pass,
                        registered_passes)
from repro.core.selector import hardware_fingerprint

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def chain_graph(rng):
    """dense -> relu -> tanh -> sigmoid (a fusable elementwise chain)."""
    g = Graph(
        name="chain",
        inputs={"x": TensorSpec((2, 8))},
        outputs=["y"],
        nodes=[
            Node("d", "dense", ["x", "w"], ["h"]),
            Node("a1", "relu", ["h"], ["h1"]),
            Node("a2", "tanh", ["h1"], ["h2"]),
            Node("a3", "sigmoid", ["h2"], ["y"]),
        ],
        params={"w": rng.standard_normal((8, 8)).astype(np.float32)},
    )
    g.validate()
    return g


# --------------------------------------------------------------------------- #
class TestPassManager:
    def test_runs_passes_in_order_with_stats(self, rng):
        calls = []

        def first(g):
            calls.append("first")
            return g.clone()

        def second(g):
            calls.append("second")
            g2 = g.clone()
            g2.nodes = [n for n in g2.nodes if n.name != "a2"]  # break chain
            g2.nodes[-1].inputs[0] = "h1"
            return g2

        pm = PassManager([first, second])
        g2 = pm.run(chain_graph(rng))
        assert calls == ["first", "second"]
        assert [s.name for s in pm.stats] == ["first", "second"]
        assert pm.stats[0].nodes_before == 4 and pm.stats[0].nodes_after == 4
        assert not pm.stats[0].changed
        assert pm.stats[1].nodes_after == 3 and pm.stats[1].changed
        assert all(s.seconds >= 0 for s in pm.stats)
        assert len(g2.nodes) == 3

    def test_named_passes_resolve_from_registry(self, rng):
        pm = PassManager(["infer_shapes", "eliminate_dead"])
        g = chain_graph(rng)
        g.nodes.append(Node("dead", "relu", ["h"], ["unused"]))
        g2 = pm.run(g)
        assert all(n.name != "dead" for n in g2.nodes)
        assert pm.pass_names() == ["infer_shapes", "eliminate_dead"]

    def test_unknown_pass_raises(self, rng):
        with pytest.raises(PipelineError, match="unknown pass"):
            PassManager(["no_such_pass"]).run(chain_graph(rng))

    def test_register_pass_decorator(self):
        @register_pass("_test_noop")
        def _noop(g):
            return g

        assert get_pass("_test_noop") is _noop
        assert "_test_noop" in registered_passes()

    def test_validate_catches_corrupting_pass(self, rng):
        def bad(g):
            g2 = g.clone()
            g2.nodes = g2.nodes[1:]  # drop the producer of "h"
            return g2

        with pytest.raises(PipelineError, match="malformed"):
            PassManager([bad], validate=True).run(chain_graph(rng))
        # without validation the bad graph passes through silently
        PassManager([bad], validate=False).run(chain_graph(rng))

    def test_fixpoint_iterates_until_stable(self, rng):
        def peel(g):
            """Remove one trailing unary node per application."""
            g2 = g.clone()
            if len(g2.nodes) > 1 and g2.nodes[-1].op in ("relu", "tanh", "sigmoid"):
                last = g2.nodes.pop()
                g2.outputs = [last.inputs[0]]
            return g2

        pm = PassManager([peel], fixpoint=True, max_iters=10)
        g2 = pm.run(chain_graph(rng))
        assert [n.op for n in g2.nodes] == ["dense"]
        iters = {s.iteration for s in pm.stats}
        assert len(iters) == 4  # 3 peels + 1 converged iteration

    def test_default_pipeline_matches_declared_passes(self):
        pm = default_pipeline()
        assert tuple(pm.pass_names()) == DEFAULT_PASSES

    def test_input_graph_untouched(self, rng):
        g = chain_graph(rng)
        ops_before = [n.op for n in g.nodes]
        default_pipeline().run(g)
        assert [n.op for n in g.nodes] == ops_before


# --------------------------------------------------------------------------- #
class TestFuseElementwise:
    def test_chain_collapses_to_single_node(self, rng):
        g2 = fuse_elementwise(chain_graph(rng))
        ops = [n.op for n in g2.nodes]
        assert ops == ["dense", "fused_elementwise"]
        fused = g2.nodes[-1]
        assert tuple(fused.attrs["ops"]) == ("relu", "tanh", "sigmoid")
        assert fused.outputs == ["y"]

    def test_numerics_match_unfused_ref(self, rng):
        g = chain_graph(rng)
        x = rng.standard_normal((2, 8)).astype(np.float32)
        y_ref = np.asarray(
            compile(g, FixedPolicy(prefer=("ref",)), pipeline=())(x=x)[0])
        y_fused = np.asarray(
            compile(fuse_elementwise(g), FixedPolicy(prefer=("ref",)),
                    pipeline=())(x=x)[0])
        np.testing.assert_allclose(y_fused, y_ref, rtol=1e-6, atol=1e-6)

    def test_ref_and_xla_backends_agree(self, rng):
        g = fuse_elementwise(chain_graph(rng))
        x = rng.standard_normal((2, 8)).astype(np.float32)
        y_ref = np.asarray(
            compile(g, FixedPolicy(prefer=("ref",)), pipeline=())(x=x)[0])
        y_xla = np.asarray(
            compile(g, FixedPolicy(prefer=("xla", "ref")), pipeline=())(x=x)[0])
        np.testing.assert_allclose(y_xla, y_ref, rtol=1e-5, atol=1e-6)

    def test_multi_consumer_intermediate_not_fused(self, rng):
        g = chain_graph(rng)
        # h1 gets a second consumer -> the relu must survive
        g.nodes.append(Node("extra", "add", ["h1", "h1"], ["z"]))
        g.outputs = ["y", "z"]
        g2 = fuse_elementwise(g)
        ops = [n.op for n in g2.nodes]
        assert "relu" in ops
        fused = [n for n in g2.nodes if n.op == "fused_elementwise"]
        assert len(fused) == 1
        assert tuple(fused[0].attrs["ops"]) == ("tanh", "sigmoid")

    def test_graph_output_boundary_respected(self, rng):
        g = chain_graph(rng)
        g.outputs = ["h1", "y"]  # h1 is externally visible
        g2 = fuse_elementwise(g)
        assert "relu" in [n.op for n in g2.nodes]


# --------------------------------------------------------------------------- #
class TestProgramCompile:
    def test_compile_reports_pass_stats(self, rng):
        prog = compile(chain_graph(rng), FixedPolicy(prefer=("ref",)))
        names = [s.name for s in prog.pass_stats]
        assert tuple(names) == DEFAULT_PASSES
        assert any(s.changed for s in prog.pass_stats)  # the chain fused
        assert all(s.seconds >= 0 for s in prog.pass_stats)

    def test_compile_executes(self, rng):
        g = chain_graph(rng)
        x = rng.standard_normal((2, 8)).astype(np.float32)
        prog = compile(g, FixedPolicy(prefer=("ref",)))
        (y,) = prog(x=x)
        assert np.asarray(y).shape == (2, 8)
        assert np.isfinite(np.asarray(y)).all()

    def test_assignment_is_frozen(self, rng):
        prog = compile(chain_graph(rng), FixedPolicy(prefer=("ref",)))
        a = prog.assignment
        a["d"] = "tampered"
        assert prog.assignment["d"] == "ref"
        with pytest.raises(TypeError):
            prog.cost_table["d"] = None

    def test_cost_table_frozen_at_compile(self, rng):
        prog = compile(chain_graph(rng), FixedPolicy(prefer=("ref",)))
        assert set(prog.cost_table) == {n.name for n in prog.graph.nodes}
        total = prog.total_cost()
        assert total.flops > 0 and total.bytes > 0

    def test_save_load_roundtrip_assignment(self, rng, tmp_path):
        g = chain_graph(rng)
        x = rng.standard_normal((2, 8)).astype(np.float32)
        prog = compile(g, FixedPolicy(per_op={"dense": ("xla",)},
                                      prefer=("ref",)))
        prog.save(str(tmp_path / "m"))
        # assignment rides in the OXF model.json (node backend pins)
        meta = json.load(open(tmp_path / "m" / "model.json"))
        assert all(nd.get("backend") for nd in meta["nodes"])
        pj = json.load(open(tmp_path / "m" / "program.json"))
        assert pj["assignment"] == prog.assignment

        prog2 = Program.load(str(tmp_path / "m"))
        assert prog2.assignment == prog.assignment
        np.testing.assert_allclose(np.asarray(prog2(x=x)[0]),
                                   np.asarray(prog(x=x)[0]),
                                   rtol=1e-6, atol=1e-6)

    def test_load_program_via_importer(self, rng, tmp_path):
        g = chain_graph(rng)
        prog = compile(g, FixedPolicy(prefer=("ref",)))
        prog.save(str(tmp_path / "m"))
        prog2 = load_program(str(tmp_path / "m"))
        assert prog2.assignment == prog.assignment

    def test_executor_shim_is_deprecated_and_equivalent(self, rng):
        from repro.core import Executor
        g = infer_shapes(chain_graph(rng))
        x = rng.standard_normal((2, 8)).astype(np.float32)
        with pytest.warns(DeprecationWarning):
            ex = Executor(g, FixedPolicy(prefer=("ref",)))
        prog = compile(g, FixedPolicy(prefer=("ref",)), pipeline=())
        assert ex.assignment == prog.assignment
        np.testing.assert_array_equal(np.asarray(ex(x=x)[0]),
                                      np.asarray(prog(x=x)[0]))


# --------------------------------------------------------------------------- #
class TestAutotuneCachePersistence:
    def test_second_instance_loads_not_rebuilds(self, rng, tmp_path):
        g = chain_graph(rng)
        cache = str(tmp_path / "tune.json")
        pol1 = AutotunePolicy(reps=1, cache_path=cache)
        prog1 = compile(g, policy=pol1)
        assert pol1.n_measured > 0 and pol1.n_loaded == 0
        assert os.path.exists(cache)

        pol2 = AutotunePolicy(reps=1, cache_path=cache)
        # the timings dict is loaded at construction, before any compile
        assert pol2.n_loaded == len(pol2._timings) > 0
        prog2 = compile(g, policy=pol2)
        assert pol2.n_measured == 0  # zero re-measurements
        assert prog2.assignment == prog1.assignment

    def test_cached_timings_respect_candidates(self, rng, tmp_path):
        """A cache written by an unrestricted run must not let a
        candidates-restricted policy pick an excluded backend."""
        g = chain_graph(rng)
        cache = str(tmp_path / "tune.json")
        compile(g, policy=AutotunePolicy(reps=1, cache_path=cache))
        pol = AutotunePolicy(reps=1, cache_path=cache, candidates=("ref",))
        prog = compile(g, policy=pol)
        assert set(prog.assignment.values()) == {"ref"}
        assert pol.n_measured == 0  # ref timings were in the cache

    def test_restricted_cache_topped_up_for_wider_candidates(self, rng, tmp_path):
        """A cache written under candidates=('ref',) is incrementally
        extended — not trusted blindly — by an unrestricted policy."""
        g = chain_graph(rng)
        cache = str(tmp_path / "tune.json")
        compile(g, policy=AutotunePolicy(reps=1, cache_path=cache,
                                         candidates=("ref",)))
        pol = AutotunePolicy(reps=1, cache_path=cache)
        compile(g, policy=pol)
        assert pol.n_measured > 0  # the missing backends got benchmarked
        times = next(iter(pol._timings.values()))
        assert len(times) > 1

    def test_cache_keyed_by_hardware_fingerprint(self, rng, tmp_path):
        cache = tmp_path / "tune.json"
        pol1 = AutotunePolicy(reps=1, cache_path=str(cache))
        compile(chain_graph(rng), policy=pol1)
        data = json.load(open(cache))
        assert list(data["fingerprints"]) == [hardware_fingerprint()]
        # remount the timings under a foreign fingerprint -> ignored
        data["fingerprints"] = {"deadbeefdeadbeef":
                                data["fingerprints"][hardware_fingerprint()]}
        json.dump(data, open(cache, "w"))
        pol2 = AutotunePolicy(reps=1, cache_path=str(cache))
        assert pol2.n_loaded == 0 and not pol2._timings

    def test_corrupt_cache_file_ignored(self, rng, tmp_path):
        cache = tmp_path / "tune.json"
        cache.write_text("not json{{{")
        pol = AutotunePolicy(reps=1, cache_path=str(cache))
        assert pol.n_loaded == 0
        compile(chain_graph(rng), policy=pol)  # measures + rewrites cleanly
        assert json.load(open(cache))["version"] == 1

    def test_truncated_cache_degrades_to_in_memory(self, rng, tmp_path):
        """A half-written cache (e.g. process killed mid-write outside the
        atomic-rename path) must not crash compile(); tuning degrades to
        in-memory and the file is rewritten whole."""
        g = chain_graph(rng)
        cache = tmp_path / "tune.json"
        compile(g, policy=AutotunePolicy(reps=1, cache_path=str(cache)))
        full = cache.read_text()
        cache.write_text(full[:len(full) // 2])
        pol = AutotunePolicy(reps=1, cache_path=str(cache))
        assert pol.n_loaded == 0
        prog = compile(g, policy=pol)  # re-measures, does not raise
        assert pol.n_measured > 0
        assert prog.assignment
        data = json.load(open(cache))  # rewritten as valid JSON
        assert data["version"] == 1

    @pytest.mark.parametrize("payload", [
        "[1, 2, 3]",                                      # JSON, not an object
        '{"version": 1, "fingerprints": [1, 2]}',          # wrong-shaped section
        '{"version": 1, "fingerprints": {"%s": ["x"]}}',   # wrong-shaped entries
        '{"version": 99, "fingerprints": {}}',             # future version
    ])
    def test_wrong_shaped_cache_degrades(self, rng, tmp_path, payload):
        cache = tmp_path / "tune.json"
        cache.write_text(payload.replace("%s", hardware_fingerprint()))
        pol = AutotunePolicy(reps=1, cache_path=str(cache))
        assert pol.n_loaded == 0 and not pol._timings
        compile(chain_graph(rng), policy=pol)
        assert pol.n_measured > 0
        data = json.load(open(cache))
        assert hardware_fingerprint() in data["fingerprints"]

    def test_zero_remeasurement_across_processes(self, tmp_path):
        """The acceptance check: two separate processes, one cache file —
        the second performs zero measurements."""
        script = (
            "import sys, numpy as np\n"
            "from repro.core import compile, AutotunePolicy, Graph, Node, TensorSpec\n"
            "g = Graph(name='t', inputs={'x': TensorSpec((2, 4))}, outputs=['y'],\n"
            "          nodes=[Node('d', 'dense', ['x', 'w'], ['y'])],\n"
            "          params={'w': np.eye(4, dtype=np.float32)})\n"
            "pol = AutotunePolicy(reps=1, cache_path=sys.argv[1])\n"
            "compile(g, policy=pol)\n"
            "print(f'MEASURED={pol.n_measured} LOADED={pol.n_loaded}')\n"
        )
        cache = str(tmp_path / "tune.json")
        env = dict(os.environ, PYTHONPATH=SRC)
        outs = []
        for _ in range(2):
            res = subprocess.run([sys.executable, "-c", script, cache],
                                 capture_output=True, text=True, env=env,
                                 timeout=240)
            assert res.returncode == 0, res.stderr
            outs.append(res.stdout)
        assert "MEASURED=1 LOADED=0" in outs[0]
        assert "MEASURED=0 LOADED=1" in outs[1]


# --------------------------------------------------------------------------- #
class _StubLM:
    """Minimal model for the batcher: prefill emits token 3, decode emits
    EOS (1) immediately, so every request finishes one step after admission."""

    vocab = 8

    def init_caches(self, n_slots, cap):
        return {"c": jnp.zeros((n_slots, 1), jnp.float32)}

    def prefill(self, params, batch, cache_cap):
        logits = jnp.zeros((1, self.vocab)).at[0, 3].set(1.0)
        n = batch["tokens"].shape[1]
        return logits, {"c": jnp.zeros((1, 1), jnp.float32)}, \
            jnp.asarray([n], jnp.int32)

    def decode_step(self, params, tokens, caches, lengths):
        b = tokens.shape[0]
        logits = jnp.zeros((b, self.vocab)).at[:, 1].set(1.0)
        return logits, caches


class TestBatcherRunRegression:
    def test_run_returns_requests_admitted_before_run(self):
        from repro.runtime.batching import ContinuousBatcher, Request
        batcher = ContinuousBatcher(_StubLM(), params={}, n_slots=2,
                                    cache_cap=8, eos_id=1)
        reqs = [Request(uid=i, prompt=np.asarray([2, 3], np.int64),
                        max_new_tokens=4) for i in range(3)]
        for r in reqs:
            batcher.submit(r)
        # one manual step admits the first two requests into slots BEFORE
        # run() is called — the old queue-snapshot run() lost them
        batcher.step()
        finished = batcher.run(max_steps=50)
        assert {r.uid for r in finished} == {0, 1, 2}
        assert all(r.done for r in reqs)
        # exactly-once delivery: a second run() neither re-returns old
        # requests nor leaks them in `submitted`
        assert batcher.run(max_steps=50) == []
        assert batcher.submitted == []
