"""BlockPool snapshot / restore / truncate property suite (ISSUE 10).

The self-healing engine's page-level resume and the speculative reject
path both lean on three pool guarantees that this suite drives with
randomized op interleavings (hypothesis when installed, a seeded
deterministic sweep otherwise):

* **restore is idempotent** — ``restore(snap)`` brings the pool to a
  state whose own ``snapshot()`` equals ``snap``, and restoring the same
  snapshot again (a recovered engine may crash again) changes nothing;
* **restore lands on a valid pool** — ``check_integrity`` passes after
  every restore, whatever ops ran since the checkpoint;
* **truncated speculative pages never resurrect** — a page filled by a
  speculative write is registered in the prefix index; rejecting those
  rows must pull it back out, so no later lookup can reuse content that
  encodes rejected tokens;
* **int8 metadata round-trips** — ``kv_dtype`` / ``page_bytes`` survive
  snapshot/restore cycles byte-for-byte in ``stats()`` (the device-side
  scale-sidecar exactness is pinned by the kv8 fault tests: sidecars are
  block-id-indexed arrays, so they ride the same block tables).

Speculative rows are drawn from a disjoint token range so a rejected
chain is globally unique: any post-truncate lookup reuse beyond the
kept length would be unambiguous resurrection, not a small-vocab
collision.
"""

import numpy as np
import pytest

from repro.runtime.kv_cache import BlockPool, kv_page_bytes

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

OPS = ("admit", "append", "spec", "fork", "finish", "drop",
       "checkpoint", "crash")


def _prompt(rng, n, vocab=7):
    # small vocab on purpose: shared prefixes, CoW and index collisions
    return [int(t) for t in rng.integers(0, vocab, size=n)]


def _drive(n_blocks, page, ops, seed, kv_dtype="float32"):
    """Replay a random op sequence with checkpoint/crash interleaved.

    ``checkpoint`` captures ``pool.snapshot()`` plus a shadow copy of
    the live-sequence map; ``crash`` restores the latest checkpoint
    (twice — idempotence) and rolls the shadow back with it, exactly
    like the engine's ``_recover``.  ``spec`` models one speculative
    verify: append K rows from the unique-token range, then truncate an
    arbitrary tail of them back off."""
    page_bytes = kv_page_bytes(2, 2, page, 8, kv_dtype)
    pool = BlockPool(n_blocks, page, kv_dtype=kv_dtype,
                     page_bytes=page_bytes)
    rng = np.random.default_rng(seed)
    maxrows = {}                  # sid -> admitted row cap (L + new - 1)
    snaps = []                    # (snapshot, shadow maxrows)
    unique = [10_000]             # spec tokens: globally unique
    stats = {"crashes": 0, "specs": 0}

    for op in ops:
        if op == "admit":
            plen = int(rng.integers(1, 3 * page))
            max_new = int(rng.integers(1, 2 * page))
            if not pool.fits_ever(plen, max_new):
                continue
            res = pool.admit(_prompt(rng, plen), max_new)
            if res is not None:
                sid, reused = res
                prompt = pool.sequence(sid).tokens + _prompt(
                    rng, plen - reused)
                pool.append(sid, prompt[reused:])
                maxrows[sid] = plen + max_new - 1
        elif op == "append" and maxrows:
            sid = int(rng.choice(list(maxrows)))
            if pool.sequence(sid).n_tokens < maxrows[sid]:
                pool.append(sid, _prompt(rng, 1))
        elif op == "spec" and maxrows:
            sid = int(rng.choice(list(maxrows)))
            seq = pool.sequence(sid)
            room = maxrows[sid] - seq.n_tokens
            if room < 1:
                continue
            stats["specs"] += 1
            n0 = seq.n_tokens
            k = int(rng.integers(1, room + 1))
            rows = list(range(unique[0], unique[0] + k))
            unique[0] += k
            pool.append(sid, rows)
            chain = list(seq.tokens)              # committed + speculative
            n_keep = n0 + int(rng.integers(0, k))  # reject >= 1 row
            pool.truncate(sid, n_keep)
            pool.check_integrity()
            # rejected full-page keys are out of the index ...
            for end in range(page, n0 + k + 1, page):
                if end > n_keep:
                    assert tuple(chain[:end]) not in pool._full, \
                        "truncated speculative page still indexed"
            # ... and no lookup can reuse past the kept rows (the chain
            # is unique beyond n0, so any excess would be resurrection)
            assert pool.lookup(chain + [1])[2] <= n_keep
        elif op == "fork" and maxrows:
            sid = int(rng.choice(list(maxrows)))
            grow = int(rng.integers(1, page + 1))
            nsid = pool.fork(sid, grow)
            if nsid is not None:
                maxrows[nsid] = pool.sequence(nsid).n_tokens + grow
        elif op in ("finish", "drop") and maxrows:
            sid = int(rng.choice(list(maxrows)))
            del maxrows[sid]
            pool.release(sid, register=op == "finish")
        elif op == "checkpoint":
            snaps.append((pool.snapshot(), dict(maxrows)))
        elif op == "crash" and snaps:
            stats["crashes"] += 1
            snap, shadow = snaps[-1]
            pool.restore(snap)
            pool.check_integrity()
            assert pool.snapshot() == snap, "restore not faithful"
            pool.restore(snap)                    # restore is re-runnable
            assert pool.snapshot() == snap, "second restore diverged"
            maxrows = dict(shadow)
        pool.check_integrity()
        s = pool.stats()
        assert s["kv_dtype"] == kv_dtype
        assert s["page_bytes"] == page_bytes

    for sid in list(maxrows):
        pool.release(sid)
    pool.check_integrity()
    s = pool.stats()
    assert s["live_blocks"] == 0 and s["reserved_blocks"] == 0
    assert s["free_blocks"] + s["cached_blocks"] == n_blocks
    return stats


@pytest.mark.parametrize("kv_dtype", ["float32", "int8"])
def test_snapshot_restore_truncate_randomized(kv_dtype):
    rng = np.random.default_rng(7)
    totals = {"crashes": 0, "specs": 0}
    for trial in range(25):
        n_blocks = int(rng.integers(4, 24))
        page = int(rng.integers(2, 9))
        ops = list(rng.choice(OPS, size=int(rng.integers(10, 80))))
        # guarantee restore pressure even on short sequences
        ops = ["checkpoint"] + ops + ["crash"]
        got = _drive(n_blocks, page, ops, seed=1000 * trial + 13,
                     kv_dtype=kv_dtype)
        for key in totals:
            totals[key] += got[key]
    assert totals["crashes"] >= 25 and totals["specs"] >= 25, (
        "random drive never exercised the paths under test", totals)


if HAVE_HYPOTHESIS:
    @given(st.integers(4, 24), st.integers(2, 8),
           st.lists(st.sampled_from(OPS), min_size=1, max_size=80),
           st.integers(0, 2 ** 16))
    @settings(max_examples=60, deadline=None)
    def test_snapshot_restore_truncate_hypothesis(n_blocks, page, ops, seed):
        _drive(n_blocks, page, ["checkpoint"] + ops + ["crash"], seed)


# --------------------------------------------------------------------------- #
# directed edges
# --------------------------------------------------------------------------- #

def test_restore_rejects_mismatched_pool():
    pool = BlockPool(8, 4)
    snap = pool.snapshot()
    other = BlockPool(4, 4)
    with pytest.raises(ValueError, match="blocks"):
        other.restore(snap)


def test_restore_rolls_back_post_snapshot_admissions():
    """Sequences admitted after the checkpoint vanish on restore, and
    sequences released after it come back — the exact shape of a failed
    tick that both admitted and finished work before dying."""
    pool = BlockPool(16, 4)
    sid0, _ = pool.admit(list(range(6)), 4)
    pool.append(sid0, list(range(6)))
    snap = pool.snapshot()
    sid1, _ = pool.admit(list(range(20, 30)), 4)     # post-ckpt admit
    pool.append(sid1, list(range(20, 30)))
    pool.release(sid0)                               # post-ckpt finish
    pool.restore(snap)
    assert pool.sequence(sid0).n_tokens == 6         # resurrected
    with pytest.raises(KeyError):
        pool.sequence(sid1)                          # rolled back
    assert pool.snapshot() == snap
    pool.release(sid0, register=False)
    pool.check_integrity()


def test_truncate_then_restore_round_trips_the_index():
    """Checkpoint -> speculative fill+register -> truncate/deindex ->
    crash-restore must land back on the checkpoint's index exactly (the
    failed tick's register AND deindex both unwind)."""
    pool = BlockPool(8, 4)
    sid, _ = pool.admit([1, 2, 3], 8)
    pool.append(sid, [1, 2, 3])
    snap = pool.snapshot()
    idx0 = pool.stats()["indexed_full_pages"]
    pool.append(sid, [4, 5, 6, 7, 8])                # fills pages -> indexed
    assert pool.stats()["indexed_full_pages"] > idx0
    pool.truncate(sid, 3)
    pool.restore(snap)
    assert pool.stats()["indexed_full_pages"] == idx0
    assert pool.snapshot() == snap
    pool.release(sid, register=False)
    pool.check_integrity()
