"""Serving partition rules — in-process unit tests (no devices needed).

The rule set behind ``compile(mesh=...)``'s ``partition`` pass is pure
name/shape → PartitionSpec logic, so it is tested here on the real
graphs with a fake mesh object (``_div`` and friends only read
``axis_names`` / ``shape``).  The end-to-end multi-device exactness bar
lives in ``test_sharded_serving.py``.
"""

import types

import pytest
from jax.sharding import PartitionSpec as P

import repro  # noqa: F401  (registers every op/backend)
from repro.core.program import compile
from repro.models.graph_lm import (GraphLMConfig, build_decode_graph,
                                   build_paged_decode_graph, init_lm_params,
                                   partition_roles)
from repro.sharding.specs import (cache_specs, check_mesh_compat,
                                  graph_partition_specs, mesh_axes,
                                  serving_value_role)


def fake_mesh(**axes):
    """Duck-typed mesh: the spec rules only read axis_names and shape."""
    return types.SimpleNamespace(axis_names=tuple(axes), shape=dict(axes))


MESH2 = fake_mesh(data=1, model=2)


def _leaf(shape):
    return types.SimpleNamespace(shape=tuple(shape))


# --------------------------------------------------------------------------- #
# cache_specs: paged pools and scale sidecars across GQA ratios
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("hk", [1, 2, 4])
def test_cache_specs_paged_pool_divides_or_replicates(hk):
    """(N_pages, page, Hk, D) pools + (N_pages, Hk) sidecars: the kv-head
    dim shards on "model" when divisible, replicates otherwise — never a
    crash, whatever the GQA ratio."""
    tree = {"l0": {"pages_k": _leaf((10, 4, hk, 8)),
                   "pages_v": _leaf((10, 4, hk, 8)),
                   "pages_k_scale": _leaf((10, hk)),
                   "pages_v_scale": _leaf((10, hk))}}
    specs = cache_specs(tree, None, MESH2, batch=3)
    want_axis = "model" if hk % 2 == 0 else None
    assert specs["l0"]["pages_k"] == P(None, None, want_axis, None)
    assert specs["l0"]["pages_v"] == P(None, None, want_axis, None)
    assert specs["l0"]["pages_k_scale"] == P(None, want_axis)
    assert specs["l0"]["pages_v_scale"] == P(None, want_axis)


def test_cache_specs_paged_pool_never_batch_sharded():
    """A pool's leading dim is the block-pool size, not batch — even when
    the two collide numerically it must not pick up a data-parallel
    shard (rows are block-addressed across every request)."""
    mesh = fake_mesh(data=2, model=2)
    tree = {"pages_k": _leaf((4, 4, 2, 8))}   # N_pages == 2*dp on purpose
    specs = cache_specs(tree, None, mesh, batch=3)
    assert specs["pages_k"] == P(None, None, "model", None)


# --------------------------------------------------------------------------- #
# serving_value_role / partition_roles
# --------------------------------------------------------------------------- #

def test_serving_value_role_classification():
    assert serving_value_role("l0.wq", (32, 32)) == "col"
    assert serving_value_role("l1.wg", (32, 64)) == "col"
    assert serving_value_role("l0.wk", (32, 16)) == "kv_col"
    # row-parallel candidates stay replicated (token-identity rationale)
    for name in ("l0.wo", "l0.wd", "embed", "head_w", "l0.norm1",
                 "final_norm", "logits"):
        assert serving_value_role(name, (32, 32)) == "replicated", name
    for name in ("tokens", "start", "n_new", "block_tables"):
        assert serving_value_role(name, (3,)) == "replicated", name
    assert serving_value_role("cache_k0", (3, 16, 2, 8)) == "dense_cache"
    assert serving_value_role("cache_k0", (10, 4, 2, 8),
                              paged=True) == "paged_pool"
    assert serving_value_role("cache_v1_scale", (10, 2),
                              paged=True) == "kv_scale"
    # outputs mirror their input through the new_ prefix
    assert serving_value_role("new_cache_k0", (3, 16, 2, 8)) == "dense_cache"


def test_partition_roles_covers_every_graph_value():
    cfg = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                        n_kv_heads=2, d_ff=64)
    params = init_lm_params(cfg)
    g = build_paged_decode_graph(cfg, params, batch=2, n_blocks=8,
                                 page_size=4, max_pages=4, kv_dtype="int8")
    roles = partition_roles(g)
    for name in list(g.inputs) + list(g.outputs):
        assert name in roles, name
    assert roles["cache_k0"] == "paged_pool"
    assert roles["cache_k0_scale"] == "kv_scale"
    assert roles["new_cache_v1"] == "paged_pool"
    assert roles["block_tables"] == "replicated"
    assert roles["logits"] == "replicated"


# --------------------------------------------------------------------------- #
# graph_partition_specs + the partition compile stage
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("hk,want", [(1, None), (2, "model"), (4, "model")])
def test_graph_specs_gqa_fallback(hk, want):
    cfg = GraphLMConfig(vocab=61, d_model=32, n_layers=1, n_heads=4,
                        n_kv_heads=hk, d_ff=64)
    params = init_lm_params(cfg)
    g = build_decode_graph(cfg, params, batch=2, cache_cap=16)
    specs = graph_partition_specs(g, MESH2)
    assert specs["cache_k0"] == (P(None, None, "model", None) if want
                                 else P())
    assert specs["new_cache_k0"] == specs["cache_k0"]
    # q heads always divide here; kv projections follow the kv-head count
    assert specs["l0.wq"] == P(None, "model")
    assert specs["l0.wk"] == (P(None, "model") if want else P())
    assert specs["l0.wo"] == P()
    assert specs["tokens"] == P()
    assert specs["logits"] == P()


def test_compile_mesh_stamps_frozen_partition():
    cfg = GraphLMConfig(vocab=61, d_model=32, n_layers=1, n_heads=4,
                        n_kv_heads=2, d_ff=64)
    params = init_lm_params(cfg)
    g = build_paged_decode_graph(cfg, params, batch=2, n_blocks=8,
                                 page_size=4, max_pages=4, kv_dtype="int8")
    prog = compile(g, mesh=MESH2)
    part = prog.partition
    assert part is not None
    assert dict(part["mesh"]) == {"data": 1, "model": 2}
    assert part["specs"]["cache_k0"] == P(None, None, "model", None)
    assert part["specs"]["cache_k0_scale"] == P(None, "model")
    # frozen: the mappings reject mutation
    with pytest.raises(TypeError):
        part["specs"]["cache_k0"] = P()
    # every value the engine exchanges has a spec
    for name in list(g.inputs) + list(g.outputs):
        assert name in part["specs"], name
    # the pass showed up in compile stats
    assert any(s.name == "partition" for s in prog.pass_stats)


def test_unpartitioned_compile_has_no_partition():
    cfg = GraphLMConfig(vocab=61, d_model=32, n_layers=1, n_heads=4,
                        n_kv_heads=2, d_ff=64)
    g = build_decode_graph(cfg, init_lm_params(cfg), batch=2, cache_cap=16)
    assert compile(g).partition is None


# --------------------------------------------------------------------------- #
# mesh identity / compatibility
# --------------------------------------------------------------------------- #

def test_check_mesh_compat():
    rec = mesh_axes(MESH2)
    check_mesh_compat(rec, fake_mesh(data=1, model=2))     # order-free match
    with pytest.raises(ValueError, match="mesh axes"):
        check_mesh_compat(rec, fake_mesh(data=1, model=4))
    with pytest.raises(ValueError, match="re-partition"):
        check_mesh_compat(rec, fake_mesh(model=2))
