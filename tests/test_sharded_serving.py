"""Tensor-parallel serving exactness — the bar for ``compile(mesh=...)``
plus the engine's multi-device path.

Everything runs in SUBPROCESSES with forced host devices (the main test
process keeps the real single CPU device, per the dry-run isolation
rule).  Unlike ``test_sharding_multidev.py`` these tests carry no
version skip: the serving stack is built on the version-portable
``shard_map_compat`` / ``make_serving_mesh``, so the exactness bar holds
on every jax the repo supports.

The bar is strict token IDENTITY, not closeness: the TP=2 engine must
emit exactly the single-device engine's tokens for dense, paged-fp32
and paged-int8 Programs, cold and on prefix hits, through GQA fallback
and through self-heal crash recovery.  That works because the ``tp``
attention backends never split a contraction: heads are computed whole
per device and the only collective is an exact output all-gather
(row-parallel weights stay replicated — see
``repro.sharding.specs.serving_value_role``).
"""

from conftest import run_sub

PREAMBLE = """
import numpy as np, jax
import repro  # registers every op/backend
from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import EngineRequest, build_lm_serving

assert len(jax.devices()) == 8, jax.devices()
TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)

def reqs(seed, n=5, vocab=61):
    rng = np.random.default_rng(seed)
    return [EngineRequest(uid=i,
                prompt=rng.integers(0, vocab,
                                    size=int(rng.integers(1, 13))).astype(np.int32),
                max_new_tokens=int(rng.integers(1, 7)))
            for i in range(n)]

def drive(engine, rs):
    for r in rs:
        assert engine.submit(r), r.dropped
    engine.run(max_ticks=engine.tick + 4000)
    for r in rs:
        assert r.done and r.dropped is None, (r.uid, r.dropped)
    return [tuple(r.out_tokens) for r in rs]

def assert_tp_attention(engine):
    asn = engine.stepper.decode_program.assignment
    tp_nodes = [n for n, b in asn.items() if b == "tp"]
    assert tp_nodes, ("tp backend never selected", asn)
"""


def test_tp_backends_bitwise_equal_xla():
    """Op level: the shard_map tp backends are bitwise-identical to their
    single-device xla lowerings on a 2-device ("model",) mesh."""
    run_sub(PREAMBLE + """
from repro.kernels.serving_ops import (chunk_attention,
                                       paged_decode_attention_q,
                                       serving_mesh)
from repro.launch.mesh import make_serving_mesh

rng = np.random.default_rng(0)
q = rng.standard_normal((2, 4, 4, 8)).astype(np.float32)
k = rng.standard_normal((2, 4, 2, 8)).astype(np.float32)
v = rng.standard_normal((2, 4, 2, 8)).astype(np.float32)
start = np.zeros((2,), np.int32)
want = np.asarray(chunk_attention(q, k, v, start, backend="xla"))
mesh = make_serving_mesh(2)
with serving_mesh(mesh):
    got = np.asarray(chunk_attention(q, k, v, start, backend="tp"))
np.testing.assert_array_equal(got, want)

qd = rng.standard_normal((2, 4, 8)).astype(np.float32)
pk = rng.integers(-127, 128, size=(6, 4, 2, 8)).astype(np.int8)
pv = rng.integers(-127, 128, size=(6, 4, 2, 8)).astype(np.int8)
ks = rng.uniform(0.01, 0.1, size=(6, 2)).astype(np.float32)
vs = rng.uniform(0.01, 0.1, size=(6, 2)).astype(np.float32)
tables = np.array([[0, 2], [1, 3]], np.int32)
lengths = np.array([7, 5], np.int32)
want = np.asarray(paged_decode_attention_q(qd, pk, ks, pv, vs, tables,
                                           lengths, backend="xla"))
with serving_mesh(mesh):
    got = np.asarray(paged_decode_attention_q(qd, pk, ks, pv, vs, tables,
                                              lengths, backend="tp"))
np.testing.assert_array_equal(got, want)
print("OK")
""")


def test_tp_engine_dense_token_identical():
    """Dense caches: TP=2 engine == TP=None engine == unbatched reference;
    and a GQA-small config (Hk=1, tp=2) replicates KV and stays exact."""
    run_sub(PREAMBLE + """
kw = dict(n_slots=3, chunk=4, cache_cap=48)
e1, ref1 = build_lm_serving(TINY, **kw)
base = drive(e1, reqs(7))
e2, ref2 = build_lm_serving(TINY, **kw, tp=2)
assert drive(e2, reqs(7)) == base
assert_tp_attention(e2)
for r, toks in zip(reqs(7), base):
    assert list(toks) == ref2.generate(r.prompt, r.max_new_tokens)

# GQA-small fallback: Hk=1 does not divide tp=2 -> KV replicated, still exact
TG = GraphLMConfig(vocab=61, d_model=32, n_layers=1, n_heads=4,
                   n_kv_heads=1, d_ff=64)
eg, refg = build_lm_serving(TG, n_slots=2, chunk=4, cache_cap=32, tp=2)
for r, toks in zip(reqs(3, n=3), drive(eg, reqs(3, n=3))):
    assert list(toks) == refg.generate(r.prompt, r.max_new_tokens)
print("OK")
""")


def test_tp_engine_paged_fp32_cold_and_prefix_hit():
    run_sub(PREAMBLE + """
kw = dict(n_slots=3, chunk=4, cache_cap=48, paged=True, page_size=8)
e1, _ = build_lm_serving(TINY, **kw)
base = drive(e1, reqs(8))
e2, ref = build_lm_serving(TINY, **kw, tp=2)
assert drive(e2, reqs(8)) == base
assert_tp_attention(e2)

rng = np.random.default_rng(12)
prefix = rng.integers(0, 61, size=24).astype(np.int32)
cold = EngineRequest(uid=100, prompt=np.concatenate(
    [prefix, rng.integers(0, 61, size=3).astype(np.int32)]), max_new_tokens=5)
assert e2.submit(cold); e2.run(max_ticks=e2.tick + 500)
assert cold.out_tokens == ref.generate(cold.prompt, 5)
hits0 = e2.stepper.pool.hit_tokens
warm = EngineRequest(uid=101, prompt=np.concatenate(
    [prefix, rng.integers(0, 61, size=2).astype(np.int32)]), max_new_tokens=5)
assert e2.submit(warm); e2.run(max_ticks=e2.tick + 500)
assert e2.stepper.pool.hit_tokens - hits0 >= 24, "sharded pages never hit"
assert warm.out_tokens == ref.generate(warm.prompt, 5)
e2.stepper.pool.check_integrity()
print("OK")
""")


def test_tp_engine_paged_int8_cold_and_prefix_hit():
    """int8 KV pages + sharded scale sidecars stay token-exact vs the
    dense fp32 reference, cold and on prefix hits."""
    run_sub(PREAMBLE + """
e, ref = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                          paged=True, page_size=8, kv_dtype="int8", tp=2)
assert e.stepper.pool.kv_dtype == "int8"
rng = np.random.default_rng(21)
rs = reqs(21, n=7)
for r, toks in zip(rs, drive(e, rs)):
    assert list(toks) == ref.generate(r.prompt, r.max_new_tokens)
assert_tp_attention(e)

prefix = rng.integers(0, 61, size=24).astype(np.int32)
cold = EngineRequest(uid=100, prompt=np.concatenate(
    [prefix, rng.integers(0, 61, size=3).astype(np.int32)]), max_new_tokens=5)
assert e.submit(cold); e.run(max_ticks=e.tick + 500)
assert cold.out_tokens == ref.generate(cold.prompt, 5)
hits0 = e.stepper.pool.hit_tokens
warm = EngineRequest(uid=101, prompt=np.concatenate(
    [prefix, rng.integers(0, 61, size=2).astype(np.int32)]), max_new_tokens=5)
assert e.submit(warm); e.run(max_ticks=e.tick + 500)
assert e.stepper.pool.hit_tokens - hits0 >= 24
assert warm.out_tokens == ref.generate(warm.prompt, 5)
print("OK")
""")


def test_tp_engine_self_heal_recovery_token_identical():
    """Crash recovery under TP: the checkpoint's id-level pool snapshot
    stays in lockstep with the head-sharded device pages."""
    run_sub(PREAMBLE + """
rng = np.random.default_rng(42)
head = rng.integers(0, 61, size=6).astype(np.int32)
prompts = []
for i in range(6):
    tail = rng.integers(0, 61, size=int(rng.integers(2, 9))).astype(np.int32)
    prompts.append(np.concatenate([head, tail]) if i % 2 else tail)

def run(tp, inject):
    engine, _ = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                                 paged=True, self_heal=True, tp=tp)
    rs = []
    for i, p in enumerate(prompts):
        r = EngineRequest(uid=i, prompt=p, max_new_tokens=6)
        assert engine.submit(r); rs.append(r)
    if inject:
        calls = [0]
        for phase in ("decode", "prefill"):
            orig = getattr(engine.stepper, phase)
            def wrapped(*args, _orig=orig):
                calls[0] += 1
                if calls[0] in (3, 7, 11):
                    raise RuntimeError("injected fault")
                return _orig(*args)
            setattr(engine.stepper, phase, wrapped)
    engine.run()
    assert all(r.done and r.dropped is None for r in rs)
    if inject:
        assert engine.metrics.n_recoveries >= 1
    engine.stepper.pool.check_integrity()
    return [tuple(r.out_tokens) for r in rs]

base = run(None, False)
assert run(2, False) == base, "tp clean run differs"
assert run(2, True) == base, "tp recovery run differs"
print("OK")
""")
