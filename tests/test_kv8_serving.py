"""int8 KV-cache pages: quantized op parity, scale bookkeeping, engine
exactness, and byte-honest pool accounting.

The quantized serving path stores paged K/V as symmetric int8 with one
float32 scale per (page, kv-head).  The bars here: the ``*_q`` ops must
match the fp32 paged ops on dequantized pages across ref / xla /
pallas-interpret on a SCRAMBLED physical block layout; writes must keep
scales monotone (requantizing quieter rows, never amplifying noise into
untouched pages); and the kv8 engine must stay token-exact against the
fp32 dense :class:`~repro.runtime.engine.UnbatchedReference` on the
cold, prefix-hit and copy-on-write paths, with logit error bounded.
"""

import numpy as np
import pytest
from conftest import TINY_LM, make_engine

import repro  # noqa: F401  (registers every op/backend)
from repro.core import backends_for, compile
from repro.core.ir import TensorSpec
from repro.kernels.serving_ops import (paged_cache_update_q,
                                       paged_chunk_attention,
                                       paged_chunk_attention_q,
                                       paged_decode_attention,
                                       paged_decode_attention_q)
from repro.models.graph_lm import (GraphLMConfig, build_paged_prefill_graph,
                                   build_prefill_graph, init_lm_params)
from repro.runtime.engine import EngineRequest, build_lm_serving
from repro.runtime.kv_cache import BlockPool, kv_page_bytes

TINY = GraphLMConfig(**TINY_LM)


def _rng():
    return np.random.default_rng(7)


def _quantize_pages(pages):
    """Symmetric per-(page, kv-head) int8 — the scheme the ops implement."""
    amax = np.abs(pages).max(axis=(1, 3))                    # (N, Hk)
    scales = (amax / 127.0).astype(np.float32)
    safe = np.where(scales > 0, scales, 1.0)
    q = np.clip(np.round(pages / safe[:, None, :, None]),
                -127, 127).astype(np.int8)
    return q, scales


def _dequant(pages_q, scales):
    return pages_q.astype(np.float32) * scales[:, None, :, None]


def _q_layout(rng, *, b=3, cap=32, hk=2, d=8, n_blocks=12, page=8,
              lengths=(14, 9, 5)):
    """Quantized paged K/V under a scrambled block mapping, plus the
    dequantized fp32 pages the ``*_q`` ops must agree with."""
    perm = rng.permutation(n_blocks)
    tables = np.zeros((b, cap // page), np.int32)
    used = iter(perm)
    pages_k = np.zeros((n_blocks, page, hk, d), np.float32)
    pages_v = np.zeros((n_blocks, page, hk, d), np.float32)
    lengths = np.asarray(lengths, np.int32)
    for bi in range(b):
        # every logical page owns a (scrambled) physical block, as the
        # engine guarantees for pages a write may touch; pages past the
        # current length hold zeros (scale 0.0)
        for pi in range(cap // page):
            blk = int(next(used))
            tables[bi, pi] = blk
            if pi * page < int(lengths[bi]):
                pages_k[blk] = rng.standard_normal((page, hk, d))
                pages_v[blk] = rng.standard_normal((page, hk, d))
    qk, sk = _quantize_pages(pages_k)
    qv, sv = _quantize_pages(pages_v)
    return qk, sk, qv, sv, tables, lengths


# --------------------------------------------------------------------------- #
# quantized write: ref/xla identity, round-trip, ragged pages, scale rules
# --------------------------------------------------------------------------- #

def test_cache_update_q_ref_xla_identical_and_roundtrips():
    """Ragged writes spanning a page boundary into a scrambled layout:
    both backends produce bit-identical pages AND scales, written rows
    dequantize back within one quantization step, and pages no slot
    touched come back bit-identical (the ratio==1 requantize path)."""
    rng = _rng()
    qk, sk, _, _, tables, lengths = _q_layout(rng)
    new = rng.standard_normal((3, 4, 2, 8)).astype(np.float32)
    start = lengths.copy()
    n_new = np.asarray([3, 0, 4], np.int32)   # slot 2 crosses rows 5..8
    ref_p, ref_s = (np.asarray(x) for x in paged_cache_update_q(
        qk, sk, new, tables, start, n_new, backend="ref"))
    xla_p, xla_s = (np.asarray(x) for x in paged_cache_update_q(
        qk, sk, new, tables, start, n_new, backend="xla"))
    np.testing.assert_array_equal(ref_p, xla_p)
    np.testing.assert_array_equal(ref_s, xla_s)
    deq = _dequant(ref_p, ref_s)
    for bi in range(3):
        for t in range(int(n_new[bi])):
            pos = int(start[bi]) + t
            blk, row = tables[bi, pos // 8], pos % 8
            tol = ref_s[blk].max() * 0.5 + 1e-7   # half a quantum per head
            np.testing.assert_allclose(deq[blk, row], new[bi, t], atol=2 * tol)
    # idle slot 1: its pages and scales are bit-untouched
    for pi in range(2):
        blk = tables[1, pi]
        np.testing.assert_array_equal(ref_p[blk], qk[blk])
        np.testing.assert_array_equal(ref_s[blk], sk[blk])
    # scales only ever grow
    assert (ref_s >= sk - 1e-9).all()


def test_cache_update_q_all_zero_rows_keep_zero_scale():
    """Writing all-zero rows into a zero pool must leave scale == 0.0 (the
    sentinel for 'only zeros ever stored') and int8 zeros — and attention
    over such pages must stay finite (the falsy-scale guard: dequant is
    'treat as 0', never a division)."""
    qk = np.zeros((4, 8, 2, 8), np.int8)
    sk = np.zeros((4, 2), np.float32)
    qv, sv = qk.copy(), sk.copy()
    tables = np.asarray([[0, 1]], np.int32)
    new = np.zeros((1, 4, 2, 8), np.float32)
    for backend in ("ref", "xla"):
        p, s = (np.asarray(x) for x in paged_cache_update_q(
            qk, sk, new, tables, np.asarray([0], np.int32),
            np.asarray([4], np.int32), backend=backend))
        assert (p == 0).all() and (s == 0.0).all()
    q = _rng().standard_normal((1, 4, 8)).astype(np.float32)
    out = np.asarray(paged_decode_attention_q(
        q, qk, sk, qv, sv, tables, np.asarray([4], np.int32), backend="ref"))
    assert np.isfinite(out).all()
    # all-zero V rows => attention output is exactly 0
    np.testing.assert_array_equal(out, np.zeros_like(out))


def test_cache_update_q_requantizes_when_scale_grows():
    """A loud row landing in a page of quiet rows must raise that page's
    scale and requantize the existing rows under it — old content still
    dequantizes to itself within the NEW (coarser) quantum."""
    rng = _rng()
    pages = np.zeros((2, 8, 2, 8), np.float32)
    quiet = 0.05 * rng.standard_normal((1, 4, 2, 8)).astype(np.float32)
    tables = np.asarray([[0, 1]], np.int32)
    qp, sc = _quantize_pages(pages)            # all-zero start
    qp, sc = (np.asarray(x) for x in paged_cache_update_q(
        qp, sc, quiet, tables, np.asarray([0], np.int32),
        np.asarray([4], np.int32), backend="xla"))
    quiet_scale = sc.copy()
    assert (sc[0] > 0).all()
    loud = 10.0 * np.ones((1, 1, 2, 8), np.float32)
    qp2, sc2 = (np.asarray(x) for x in paged_cache_update_q(
        qp, sc, loud, tables, np.asarray([4], np.int32),
        np.asarray([1], np.int32), backend="xla"))
    assert (sc2[0] > quiet_scale[0]).all()     # grew for the loud row
    deq = _dequant(qp2, sc2)
    np.testing.assert_allclose(deq[0, :4], quiet[0], atol=sc2[0].max() + 1e-7)
    np.testing.assert_allclose(deq[0, 4], loud[0, 0], atol=sc2[0].max())
    # page 1 never written: still exactly zero with zero scale
    assert (qp2[1] == 0).all() and (sc2[1] == 0.0).all()


# --------------------------------------------------------------------------- #
# quantized attention parity vs the fp32 paged ops on dequantized pages
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
def test_paged_decode_attention_q_parity(backend):
    rng = _rng()
    qk, sk, qv, sv, tables, lengths = _q_layout(rng)
    q = rng.standard_normal((3, 4, 8)).astype(np.float32)
    want = np.asarray(paged_decode_attention(
        q, _dequant(qk, sk), _dequant(qv, sv), tables, lengths,
        backend="ref"))
    got = np.asarray(paged_decode_attention_q(
        q, qk, sk, qv, sv, tables, lengths, backend=backend, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
def test_paged_chunk_attention_q_parity(backend):
    rng = _rng()
    qk, sk, qv, sv, tables, _ = _q_layout(rng)
    q = rng.standard_normal((3, 4, 4, 8)).astype(np.float32)
    start = np.asarray([10, 4, 1], np.int32)
    want = np.asarray(paged_chunk_attention(
        q, _dequant(qk, sk), _dequant(qv, sv), tables, start, backend="ref"))
    got = np.asarray(paged_chunk_attention_q(
        q, qk, sk, qv, sv, tables, start, backend=backend, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_q_pallas_supports_guards():
    """page % 8 != 0 excludes the fused pallas kernels but never the
    ref/xla dequant-after-gather fallbacks."""
    tb = TensorSpec((1, 4), "int32")
    ln = TensorSpec((1,), "int32")
    sc = TensorSpec((8, 2))
    ok = TensorSpec((8, 8, 2, 8), "int8")
    bad = TensorSpec((8, 6, 2, 8), "int8")
    qd = TensorSpec((1, 4, 8))
    assert "pallas" in backends_for(
        "paged_decode_attention_q", [qd, ok, sc, ok, sc, tb, ln], {})
    avail = backends_for(
        "paged_decode_attention_q", [qd, bad, sc, bad, sc, tb, ln], {})
    assert "pallas" not in avail and {"ref", "xla"} <= set(avail)
    qc = TensorSpec((1, 8, 4, 8))
    assert "pallas" in backends_for(
        "paged_chunk_attention_q", [qc, ok, sc, ok, sc, tb, ln], {})
    avail = backends_for(
        "paged_chunk_attention_q", [qc, bad, sc, bad, sc, tb, ln], {})
    assert "pallas" not in avail and {"ref", "xla"} <= set(avail)


def test_cache_update_q_rejects_bad_specs():
    """The op declaration refuses fp32 pages and mis-shaped scale
    sidecars at shape-inference time (i.e. graph build, before compile)."""
    from repro.core.registry import get_op
    shape_fn = get_op("paged_cache_update_q").shape_fn
    sc = TensorSpec((4, 2))
    new = TensorSpec((1, 2, 2, 8))
    tb = TensorSpec((1, 2), "int32")
    z = TensorSpec((1,), "int32")
    with pytest.raises(ValueError, match="int8"):
        shape_fn([TensorSpec((4, 8, 2, 8)), sc, new, tb, z, z], {})
    pages = TensorSpec((4, 8, 2, 8), "int8")
    with pytest.raises(ValueError, match="scales"):
        shape_fn([pages, TensorSpec((4, 1)), new, tb, z, z], {})
    assert [s.shape for s in shape_fn([pages, sc, new, tb, z, z], {})] \
        == [(4, 8, 2, 8), (4, 2)]


# --------------------------------------------------------------------------- #
# graph-level: bounded logit error vs the fp32 dense graph
# --------------------------------------------------------------------------- #

def test_kv8_prefill_logits_bounded_and_top1_exact():
    """One full-prompt prefill through the kv8 paged graph vs the fp32
    dense graph: max |logit error| < 0.05 and the greedy top-1 token
    agrees at EVERY position — the documented accuracy contract."""
    cfg = TINY
    params = init_lm_params(cfg, 0)
    t, page, n_blocks = 16, 8, 6
    rng = _rng()
    toks = rng.integers(0, cfg.vocab, size=(1, t)).astype(np.int32)
    start = np.zeros((1,), np.int32)
    n_new = np.full((1,), t, np.int32)
    dense = compile(build_prefill_graph(cfg, params, batch=1, chunk=t,
                                        cache_cap=t))
    want = np.asarray(dense(
        tokens=toks, start=start, n_new=n_new,
        **{f"cache_{kv}{i}": np.zeros((1, t, cfg.n_kv_heads, cfg.d_head),
                                      np.float32)
           for kv in "kv" for i in range(cfg.n_layers)})[0])
    g8 = build_paged_prefill_graph(cfg, params, batch=1, chunk=t,
                                   n_blocks=n_blocks, page_size=page,
                                   max_pages=t // page, kv_dtype="int8")
    feeds = {"tokens": toks, "start": start, "n_new": n_new,
             "block_tables": np.asarray([[3, 1]], np.int32)}
    for kv in "kv":
        for i in range(cfg.n_layers):
            feeds[f"cache_{kv}{i}"] = np.zeros(
                (n_blocks, page, cfg.n_kv_heads, cfg.d_head), np.int8)
            feeds[f"cache_{kv}{i}_scale"] = np.zeros(
                (n_blocks, cfg.n_kv_heads), np.float32)
    got = np.asarray(compile(g8)(**feeds)[0])
    assert np.abs(got - want).max() < 0.05, np.abs(got - want).max()
    np.testing.assert_array_equal(got.argmax(-1), want.argmax(-1))


# --------------------------------------------------------------------------- #
# engine end-to-end: kv8 paged vs the fp32 dense reference
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def kv8_engine():
    # the shared paged-int8 matrix variant (conftest.ENGINE_VARIANTS)
    return make_engine("paged-int8")


def _exact(engine, ref, reqs):
    for r in reqs:
        assert engine.submit(r), r.dropped
    engine.run(max_ticks=engine.tick + 4000)
    for r in reqs:
        assert r.done and r.dropped is None, (r.uid, r.dropped)
        want = ref.generate(r.prompt, r.max_new_tokens)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)
    engine.sched.check_conservation()
    engine.stepper.pool.check_integrity()


def test_kv8_engine_token_exact_cold(kv8_engine):
    engine, ref = kv8_engine
    assert engine.stepper.pool.kv_dtype == "int8"
    rng = np.random.default_rng(21)
    reqs = [EngineRequest(
        uid=i, prompt=rng.integers(0, TINY.vocab,
                                   size=int(rng.integers(1, 13)))
        .astype(np.int32),
        max_new_tokens=int(rng.integers(1, 7))) for i in range(7)]
    _exact(engine, ref, reqs)
    assert engine.stepper.pool.stats()["live_blocks"] == 0


def test_kv8_engine_prefix_hit_exact(kv8_engine):
    engine, ref = kv8_engine
    rng = np.random.default_rng(22)
    prefix = rng.integers(0, TINY.vocab, size=24).astype(np.int32)
    cold = EngineRequest(uid=100, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=3).astype(np.int32)]),
        max_new_tokens=5)
    _exact(engine, ref, [cold])
    hits0 = engine.stepper.pool.hit_tokens
    warm = EngineRequest(uid=101, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=2).astype(np.int32)]),
        max_new_tokens=5)
    _exact(engine, ref, [warm])
    assert engine.stepper.pool.hit_tokens - hits0 >= 24, \
        "quantized pages never prefix-hit"


def test_kv8_engine_cow_divergence_exact(kv8_engine):
    """Requests diverging off a shared quantized partial tail page: the
    first write must copy the int8 page AND its scale row (they are one
    unit), and every stream stays token-exact."""
    engine, ref = kv8_engine
    rng = np.random.default_rng(23)
    pre = rng.integers(0, TINY.vocab, size=21).astype(np.int32)
    seed_req = EngineRequest(uid=200, prompt=pre, max_new_tokens=2)
    _exact(engine, ref, [seed_req])
    cow0 = engine.stepper.pool.cow_count
    reqs = [EngineRequest(uid=201 + i, prompt=np.concatenate(
        [pre, rng.integers(0, TINY.vocab, size=2 + i).astype(np.int32)]),
        max_new_tokens=4) for i in range(3)]
    _exact(engine, ref, reqs)
    assert engine.stepper.pool.cow_count > cow0, "CoW never fired"


def test_kv8_composes_with_int8_programs():
    """kv_dtype="int8" (cache pages) and quantize="int8" (weights) are
    orthogonal; together they must still match the fp32 dense-cache
    int8-Program reference token for token."""
    engine, ref = make_engine("paged-int8", n_slots=2, cache_cap=32,
                              quantize="int8")
    rng = np.random.default_rng(24)
    reqs = [EngineRequest(
        uid=i, prompt=rng.integers(0, TINY.vocab,
                                   size=int(rng.integers(1, 11)))
        .astype(np.int32),
        max_new_tokens=int(rng.integers(1, 5))) for i in range(4)]
    _exact(engine, ref, reqs)


# --------------------------------------------------------------------------- #
# byte-honest pool accounting + validation
# --------------------------------------------------------------------------- #

def test_kv_page_bytes_accounts_scale_sidecars():
    # fp32: layers * K,V * rows * heads * dim * 4B
    assert kv_page_bytes(2, 2, 8, 8) == 2 * 2 * 8 * 2 * 8 * 4
    assert kv_page_bytes(1, 4, 16, 8, "bfloat16") == 1 * 2 * 8 * 4 * 16 * 2
    # int8 adds one f32 scale per (layer, K/V, kv-head)
    assert kv_page_bytes(2, 2, 8, 8, "int8") == (2 * 2 * 8 * 2 * 8
                                                 + 2 * 2 * 2 * 4)
    with pytest.raises(ValueError):
        kv_page_bytes(1, 2, 8, 8, "int4")


def test_block_pool_reports_bytes():
    pb = kv_page_bytes(2, 2, 8, 8, "int8")
    pool = BlockPool(4, 8, kv_dtype="int8", page_bytes=pb)
    s = pool.stats()
    assert s["kv_dtype"] == "int8" and s["page_bytes"] == pb
    assert s["pool_bytes"] == 4 * pb and s["live_bytes"] == 0
    # no page_bytes given -> byte fields are honest Nones, not guesses
    s2 = BlockPool(4, 8).stats()
    assert s2["kv_dtype"] == "float32"
    assert s2["page_bytes"] is None and s2["pool_bytes"] is None


def test_kv_dtype_validation_errors():
    with pytest.raises(ValueError):
        BlockPool(4, 8, kv_dtype="int4")
    with pytest.raises(ValueError, match="paged"):
        build_lm_serving(TINY, n_slots=2, chunk=4, cache_cap=32,
                         kv_dtype="int8")          # dense engine: no pages
    params = init_lm_params(TINY, 0)
    with pytest.raises(ValueError):
        build_paged_prefill_graph(TINY, params, batch=1, chunk=4,
                                  n_blocks=4, page_size=8, max_pages=2,
                                  kv_dtype="float16")
