"""Unit tests for the roofline HLO-parsing and analysis tooling."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.tools.roofline import (V5E, analyze, collective_bytes,
                                  model_flops_for)

HLO = """
HloModule test
%ar = f32[256,128]{1,0} all-reduce(f32[256,128] %x), replica_groups=[16,16]<=[256]
%ag = bf16[64,512]{1,0} all-gather(bf16[64,32] %y), replica_groups={{0,1,2,3}}, dimensions={1}
%rs = f32[32]{0} reduce-scatter(f32[128] %z), replica_groups=[32,8]<=[256]
%cp = bf16[8,8]{1,0} collective-permute(bf16[8,8] %w), source_target_pairs={{0,1}}
%aa = s32[16]{0} all-to-all(s32[16] %v), replica_groups=[64,4]<=[256]
%ars = f32[2,2] all-reduce-start(f32[2,2] %q), replica_groups=[128,2]<=[256]
"""


class TestCollectiveParse:
    def test_counts_and_types(self):
        wire, per_type, counts = collective_bytes(HLO, 256)
        assert counts == {"all-reduce": 2, "all-gather": 1,
                          "reduce-scatter": 1, "collective-permute": 1,
                          "all-to-all": 1}

    def test_ring_costs(self):
        wire, per_type, _ = collective_bytes(HLO, 256)
        # all-reduce: 2(n-1)/n * size; n=16, size=256*128*4
        ar1 = 2 * 15 / 16 * 256 * 128 * 4
        ars = 2 * 1 / 2 * 2 * 2 * 4
        assert per_type["all-reduce"] == pytest.approx(ar1 + ars)
        # all-gather: (n-1)/n * result size; n=4
        assert per_type["all-gather"] == pytest.approx(3 / 4 * 64 * 512 * 2)
        # reduce-scatter: (n-1) * result size (input = result * n); n=8
        assert per_type["reduce-scatter"] == pytest.approx(7 / 8 * 32 * 4 * 8)
        assert per_type["collective-permute"] == pytest.approx(8 * 8 * 2)
        assert per_type["all-to-all"] == pytest.approx(3 / 4 * 16 * 4)

    def test_empty_hlo(self):
        wire, per_type, counts = collective_bytes("HloModule empty", 8)
        assert wire == 0 and not counts


class TestAnalyze:
    def test_bottleneck_selection(self):
        rep = analyze("c", "single", 256,
                      {"flops": 1e12, "bytes accessed": 1e9}, HLO,
                      model_flops=256e12)
        assert rep.compute_s == pytest.approx(1e12 / V5E.peak_flops)
        assert rep.memory_s == pytest.approx(1e9 / V5E.hbm_bw)
        assert rep.bottleneck == "compute"
        assert rep.useful_ratio == pytest.approx(1.0)

    def test_extra_cost_for_pallas(self):
        base = analyze("c", "single", 256, {"flops": 1e12}, "", 1e12)
        with_k = analyze("c", "single", 256, {"flops": 1e12}, "", 1e12,
                         extra_cost=(1e12, 1e9))
        assert with_k.hlo_flops == pytest.approx(2e12)
        assert with_k.hlo_bytes == pytest.approx(1e9)
        assert with_k.compute_s > base.compute_s


class TestModelFlops:
    def test_dense_train(self):
        cfg = get_config("phi3-mini-3.8b")
        n_active = cfg.param_count()["active"]
        assert model_flops_for(cfg, "train", 4096, 256) == pytest.approx(
            6 * n_active * 4096 * 256)
        assert model_flops_for(cfg, "decode", 32768, 128) == pytest.approx(
            2 * n_active * 128)

    def test_moe_active_smaller_than_total(self):
        cfg = get_config("qwen2-moe-a2.7b")
        c = cfg.param_count()
        assert c["active"] < 0.5 * c["total"]

    def test_active_params_sane(self):
        # qwen2-moe A2.7B: ~2.7B active (+ lm_head counted by convention)
        c = get_config("qwen2-moe-a2.7b").param_count()
        assert 1.5e9 < c["active"] < 4.5e9, c["active"]
