"""Tier-aware overload scheduling (ISSUE 10 tentpole b).

Under overload the engine must degrade the LOW tiers first, on two
paths, with the mechanism/policy split pinned here:

* queue shedding — a full queue evicts its lowest-priority member
  (``SlotScheduler.shed_lowest``, the mechanism) instead of turning a
  higher-tier arrival away (``Engine.submit``, the policy), with the
  victim terminal as ``dropped == "shed_low_tier"``;
* preemption — when the queue head would blow its TTFT budget
  (``slo_ttft_ticks`` or its own deadline) and every slot is busy, the
  lowest-priority running slot is preempted.  The victim requeues at its
  original position and resumes via the page-level path: its KV pages
  (or dense cache rows) stay live, so preemption costs pool capacity,
  not recompute — and its greedy output is token-identical to an
  undisturbed run.

Conservation (submitted == rejected + finished + dropped + queued +
busy) must survive both paths; the serve_bench ``overload`` section
turns these unit bars into the macro claim (tier-aware high-tier SLO
attainment beats tier-blind FIFO at 2x offered load).
"""

import numpy as np
import pytest
from conftest import engine_variants, make_engine

from repro.runtime.batching import SlotScheduler
from repro.runtime.engine import EngineRequest


def _req(uid, priority=0, n=4, max_new=4, deadline=None):
    rng = np.random.default_rng(100 + uid)
    return EngineRequest(uid=uid, priority=priority,
                         prompt=rng.integers(2, 61, size=n).astype(np.int32),
                         max_new_tokens=max_new, deadline_tick=deadline)


# --------------------------------------------------------------------------- #
# SlotScheduler.shed_lowest — the mechanism
# --------------------------------------------------------------------------- #

def test_shed_lowest_picks_lowest_priority_then_most_recent():
    sched = SlotScheduler(n_slots=1)
    reqs = [_req(0, priority=1), _req(1, priority=0), _req(2, priority=0),
            _req(3, priority=2)]
    for r in reqs:
        assert sched.submit(r)
    # two priority-0 entries below the floor: the most recently submitted
    # one (uid 2) is shed — it waited least and has the weakest FIFO claim
    victim = sched.shed_lowest(min_priority=2)
    assert victim is reqs[2]
    assert sched.n_rejected == 1
    assert sched.queue_len == 3
    sched.check_conservation()
    # next shed at the same floor takes the remaining priority-0, then
    # the priority-1; the priority-2 head is at the floor and untouchable
    assert sched.shed_lowest(2) is reqs[1]
    assert sched.shed_lowest(2) is reqs[0]
    assert sched.shed_lowest(2) is None
    assert sched.queue_len == 1 and sched.peek() is reqs[3]
    sched.check_conservation()


def test_shed_lowest_floor_is_strict():
    sched = SlotScheduler(n_slots=1)
    a, b = _req(0, priority=1), _req(1, priority=1)
    sched.submit(a)
    sched.submit(b)
    # equal-priority entries are AT the floor, never below it
    assert sched.shed_lowest(min_priority=1) is None
    assert sched.shed_lowest(min_priority=2) is b   # most recent tie-break
    sched.check_conservation()


def test_shed_lowest_preserves_admission_order():
    sched = SlotScheduler(n_slots=2)
    reqs = [_req(i, priority=p) for i, p in enumerate([0, 2, 0, 1])]
    for r in reqs:
        sched.submit(r)
    assert sched.shed_lowest(2) is reqs[2]
    # the heap survives the mid-heap pop: admission still drains in
    # (priority desc, submit order)
    admitted = [r for _, r in sched.admit()]
    assert admitted == [reqs[1], reqs[3]]
    sched.check_conservation()


# --------------------------------------------------------------------------- #
# Engine.submit — tier-aware queue shedding (the policy)
# --------------------------------------------------------------------------- #

def test_full_queue_sheds_low_tier_for_high_tier():
    engine, _ = make_engine("dense", n_slots=1, tier_aware=True, max_queue=2)
    busy = _req(0, priority=1, max_new=8)
    assert engine.submit(busy)
    engine.step()                                   # into the slot
    low1, low2 = _req(1, priority=0), _req(2, priority=0)
    assert engine.submit(low1) and engine.submit(low2)
    assert engine.sched.queue_len == 2              # queue now full
    high = _req(3, priority=1)
    assert engine.submit(high), high.dropped
    # the most recent low-tier entry made room; terminal + accounted
    assert low2.dropped == "shed_low_tier"
    assert low2.finish_tick is not None
    assert engine.metrics.n_tier_shed == 1
    assert engine.sched.queue_len == 2
    engine.sched.check_conservation()
    engine.run()
    assert busy.done and low1.done and high.done
    assert not low2.done
    engine.sched.check_conservation()


def test_full_queue_shed_skips_equal_tier():
    """An arrival never sheds its own tier: FIFO fairness within a tier
    is preserved and the arrival takes the queue_full rejection."""
    engine, _ = make_engine("dense", n_slots=1, tier_aware=True, max_queue=1)
    assert engine.submit(_req(0, priority=0, max_new=8))
    engine.step()
    queued = _req(1, priority=0)
    assert engine.submit(queued)
    same = _req(2, priority=0)
    assert not engine.submit(same)
    assert same.dropped == "queue_full" and queued.dropped is None
    assert engine.metrics.n_tier_shed == 0
    engine.sched.check_conservation()
    engine.run()


def test_tier_blind_engine_rejects_high_tier_instead():
    """The baseline the serve_bench overload section measures against:
    without tier_aware, a full queue turns the high-tier arrival away
    even though a low-tier request is sitting in the queue."""
    engine, _ = make_engine("dense", n_slots=1, max_queue=1)
    assert engine.submit(_req(0, priority=0, max_new=8))
    engine.step()
    low = _req(1, priority=0)
    assert engine.submit(low)
    high = _req(2, priority=1)
    assert not engine.submit(high)
    assert high.dropped == "queue_full" and low.dropped is None
    engine.sched.check_conservation()
    engine.run()


# --------------------------------------------------------------------------- #
# preemption — pages, not recompute
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("dense", "paged-fp32",
                                         "paged-int8"))
def test_preemption_admits_high_tier_and_victim_is_token_identical(
        variant, engine_kw):
    """One slot, a long low-tier decode, then a high-tier arrival with a
    tight TTFT budget: the low-tier slot is preempted, the high-tier
    request meets its budget, and the victim resumes token-identical to
    an undisturbed run.

    The paged engines resume FROM THEIR SURVIVING PAGES (pages live in
    the pool, not the slot, so the preemptor can take the slot without
    destroying them) — ``recovered_rows`` proves the fast-forward.  The
    dense engine's rows DO live in the slot, and the preemptor's prefill
    overwrites them; the bar there is that the owner map detects the
    clobber and falls back to the always-correct full re-prefill instead
    of resuming from another request's rows."""
    def undisturbed(req_fn):
        engine, _ = make_engine(variant, n_slots=1)
        r = req_fn()
        assert engine.submit(r)
        engine.run()
        assert r.done
        return list(r.out_tokens)

    low_fn = lambda: _req(0, priority=0, n=12, max_new=12)   # noqa: E731
    high_fn = lambda: _req(1, priority=1, n=3, max_new=3)    # noqa: E731
    want_low, want_high = undisturbed(low_fn), undisturbed(high_fn)

    engine, _ = make_engine(variant, n_slots=1, tier_aware=True,
                            slo_ttft_ticks=6)
    low = low_fn()
    assert engine.submit(low)
    for _ in range(4):                  # low is mid-stream in the slot
        engine.step()
    high = high_fn()
    assert engine.submit(high)
    engine.run()
    assert engine.metrics.n_preempted >= 1
    assert low.n_requeues >= 1
    assert low.done and high.done
    # the high tier got the slot: it finished before the (earlier,
    # longer) low-tier request and met its TTFT budget
    assert high.finish_tick < low.finish_tick
    assert high.ttft_ticks <= 6 + 1     # +1: preemption frees the slot
    #                                     for the NEXT tick's admission
    # preemption cost pages, not recompute: the victim fast-forwarded
    # past every row it had committed — except dense, whose slot rows
    # the preemptor overwrote; there the clobber-detected fallback
    # re-prefills rather than resume from the wrong request's rows
    if engine.paged:
        assert engine.metrics.recovered_rows > 0
    else:
        assert engine.metrics.recovered_rows == 0
    assert low.out_tokens == want_low
    assert high.out_tokens == want_high
    engine.sched.check_conservation()
    if engine.paged:
        engine.stepper.pool.check_integrity()
        assert engine.stepper.pool.live_sequences == 0


def test_preemption_never_fires_against_equal_or_higher_tier():
    engine, _ = make_engine("dense", n_slots=1, tier_aware=True,
                            slo_ttft_ticks=2)
    first = _req(0, priority=1, max_new=10)
    assert engine.submit(first)
    engine.step()
    # a same-tier arrival with an already-blown budget still waits: only
    # strictly lower-priority slots are preemptable
    second = _req(1, priority=1)
    assert engine.submit(second)
    engine.run()
    assert engine.metrics.n_preempted == 0
    assert first.done and second.done
    assert first.finish_tick <= second.finish_tick
    engine.sched.check_conservation()


def test_preemption_requires_tier_aware():
    """Same squeeze as the matrix test, tier_aware off: no preemption,
    the high-tier request simply waits its turn."""
    engine, _ = make_engine("dense", n_slots=1, slo_ttft_ticks=6)
    low = _req(0, priority=0, n=12, max_new=12)
    assert engine.submit(low)
    for _ in range(4):
        engine.step()
    high = _req(1, priority=1, n=3, max_new=3)
    assert engine.submit(high)
    engine.run()
    assert engine.metrics.n_preempted == 0
    assert low.finish_tick < high.finish_tick
    engine.sched.check_conservation()


def test_preempted_then_shed_victim_releases_its_pages():
    """A preempted request owns live pool pages while it waits in the
    queue.  If the queue then sheds it for an even higher tier, those
    pages must come back — the shed path must release the resume's
    sequence or the pool leaks."""
    engine, _ = make_engine("paged-fp32", n_slots=1, tier_aware=True,
                            slo_ttft_ticks=6, max_queue=1)
    low = _req(0, priority=0, n=12, max_new=12)
    assert engine.submit(low)
    for _ in range(4):
        engine.step()
    mid = _req(1, priority=1, n=3, max_new=6)
    assert engine.submit(mid)
    # run until the preemption parks `low` (holding pages) in the queue
    for _ in range(12):
        engine.step()
        if engine.metrics.n_preempted:
            break
    assert engine.metrics.n_preempted == 1 and not low.done
    live0 = engine.stepper.pool.live_sequences
    assert live0 >= 1
    high = _req(2, priority=2, n=3, max_new=3)
    assert engine.submit(high)
    assert low.dropped == "shed_low_tier"
    engine.run()
    assert mid.done and high.done
    assert engine.metrics.n_tier_shed == 1
    engine.sched.check_conservation()
    engine.stepper.pool.check_integrity()
    assert engine.stepper.pool.live_sequences == 0
