"""Post-training INT8 quantization tests: weight quantization math, the
calibration observer, the quantize graph rewrite, int8-accumulate vs
dequant-fused backends, the example-CNN acceptance criteria (accuracy
within atol 0.1, >=3x smaller weight bytes, re-calibration-free reload),
and the footprint report."""

import json
import os

import numpy as np
import pytest

from repro.core import (FixedPolicy, Graph, Node, PassManager, Program,
                        TensorSpec, calibrate, compile, get_impl,
                        is_quantized, quantize_graph, quantize_weight)
from repro.core.quant import QMAX, activation_scale, weight_scales
from repro.tools.report import activation_bytes, footprint_table, weight_bytes


def conv_graph(rng):
    """conv2d -> bias_add -> relu -> flatten -> dense (exercises both
    quantizable op families after the fuse pipeline)."""
    g = Graph(
        name="qconv",
        inputs={"x": TensorSpec((2, 8, 8, 3))},
        outputs=["y"],
        nodes=[
            Node("c", "conv2d", ["x", "w"], ["h"], {"padding": "SAME"}),
            Node("b", "bias_add", ["h", "bias"], ["hb"]),
            Node("r", "relu", ["hb"], ["hr"]),
            Node("f", "flatten", ["hr"], ["hf"]),
            Node("d", "dense", ["hf", "w2"], ["y"]),
        ],
        params={
            "w": (rng.standard_normal((3, 3, 3, 8)) * 0.2).astype(np.float32),
            "bias": (rng.standard_normal((8,)) * 0.1).astype(np.float32),
            "w2": (rng.standard_normal((8 * 8 * 8, 5)) * 0.05).astype(np.float32),
        },
    )
    g.validate()
    return g


# --------------------------------------------------------------------------- #
class TestWeightQuantization:
    def test_per_channel_scales_shapes(self, rng):
        w_conv = rng.standard_normal((3, 3, 8, 16)).astype(np.float32)
        assert weight_scales(w_conv, 3).shape == (16,)
        w_dense = rng.standard_normal((8, 4)).astype(np.float32)
        assert weight_scales(w_dense, 1).shape == (4,)

    def test_roundtrip_error_bounded_by_half_scale(self, rng):
        w = rng.standard_normal((5, 7)).astype(np.float32)
        w_q, s = quantize_weight(w, 1)
        assert w_q.dtype == np.int8
        assert np.abs(w_q).max() <= QMAX
        err = np.abs(w - w_q.astype(np.float32) * s[None, :])
        assert (err <= s[None, :] / 2 + 1e-7).all()

    def test_channel_with_largest_magnitude_hits_qmax(self, rng):
        w = rng.standard_normal((16, 3)).astype(np.float32)
        w_q, _ = quantize_weight(w, 1)
        # per-channel symmetric: every channel's amax maps to +-QMAX
        assert (np.abs(w_q).max(axis=0) == QMAX).all()

    def test_all_zero_channel_is_safe(self):
        w = np.zeros((4, 2), np.float32)
        w_q, s = quantize_weight(w, 1)
        assert (w_q == 0).all() and (s == 1.0 / QMAX).all()

    def test_activation_scale_symmetric(self):
        assert activation_scale(-2.0, 1.0) == pytest.approx(2.0 / QMAX)
        assert activation_scale(0.0, 3.0) == pytest.approx(3.0 / QMAX)


# --------------------------------------------------------------------------- #
class TestCalibrate:
    def test_observes_every_value(self, rng):
        g = conv_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        ranges = calibrate(g, {"x": x})
        expected = set(g.inputs) | set(g.params) | {
            v for n in g.nodes for v in n.outputs}
        assert expected <= set(ranges)
        for lo, hi in ranges.values():
            assert lo <= hi
        # relu output range is clipped at zero from below
        assert ranges["hr"][0] >= 0.0

    def test_multiple_batches_widen_ranges(self, rng):
        g = conv_graph(rng)
        small = (rng.standard_normal((2, 8, 8, 3)) * 0.1).astype(np.float32)
        large = (rng.standard_normal((2, 8, 8, 3)) * 10).astype(np.float32)
        r_small = calibrate(g, small)  # bare array: single-input graph
        r_both = calibrate(g, [{"x": small}, {"x": large}])
        assert r_both["x"][1] > r_small["x"][1]
        assert r_both["x"][0] < r_small["x"][0]

    def test_channel_mean_recorded(self, rng):
        g = conv_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        ranges = calibrate(g, x)
        mu = ranges["x"].channel_mean
        np.testing.assert_allclose(mu, x.mean(axis=(0, 1, 2)), rtol=1e-5)

    def test_missing_input_raises(self, rng):
        with pytest.raises(ValueError, match="missing inputs"):
            calibrate(conv_graph(rng), {"not_x": np.zeros((2, 8, 8, 3))})


# --------------------------------------------------------------------------- #
class TestQuantizeGraphRewrite:
    def test_rewrites_ops_and_params(self, rng):
        g = conv_graph(rng)
        gq = quantize_graph(g)
        ops = {n.op for n in gq.nodes}
        assert "conv2d_q" in ops and "dense_q" in ops
        assert "conv2d" not in ops and "dense" not in ops
        assert gq.params["w.q8"].dtype == np.int8
        # fp32 originals are dead and dropped -> that's the footprint win
        assert "w" not in gq.params and "w2" not in gq.params
        qnode = next(n for n in gq.nodes if n.op == "conv2d_q")
        assert qnode.attrs["zero_point"] == 0
        assert qnode.attrs["w_scale"].shape == (8,)
        assert "x_scale" not in qnode.attrs  # weight-only without ranges
        gq.validate()

    def test_calibrated_rewrite_freezes_x_scale(self, rng):
        g = conv_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        gq = quantize_graph(g, calibrate(g, x))
        qnode = next(n for n in gq.nodes if n.op == "conv2d_q")
        assert qnode.attrs["x_scale"] == pytest.approx(
            np.abs(x).max() / QMAX, rel=1e-5)

    def test_registered_as_pass(self, rng):
        gq = PassManager(["infer_shapes", "quantize"]).run(conv_graph(rng))
        assert is_quantized(gq)

    def test_input_graph_untouched(self, rng):
        g = conv_graph(rng)
        quantize_graph(g)
        assert {n.op for n in g.nodes} == {"conv2d", "bias_add", "relu",
                                           "flatten", "dense"}
        assert "w.q8" not in g.params

    def test_computed_weight_left_in_fp32(self, rng):
        g = Graph(
            name="computed_w",
            inputs={"x": TensorSpec((2, 4)), "wdyn": TensorSpec((4, 4))},
            outputs=["y"],
            nodes=[Node("d", "dense", ["x", "wdyn"], ["y"])],
        )
        g.validate()
        gq = quantize_graph(g)
        assert [n.op for n in gq.nodes] == ["dense"]

    def test_unknown_dtype_rejected(self, rng):
        with pytest.raises(ValueError, match="int8"):
            quantize_graph(conv_graph(rng), dtype="int4")


# --------------------------------------------------------------------------- #
class TestQuantizedExecution:
    def test_ref_is_true_int8_accumulation(self, rng):
        """The ref backend must match an integer-arithmetic oracle exactly."""
        x = rng.standard_normal((3, 6)).astype(np.float32)
        w = (rng.standard_normal((6, 4)) * 0.3).astype(np.float32)
        w_q, w_s = quantize_weight(w, 1)
        x_scale = float(np.abs(x).max() / QMAX)
        attrs = {"w_scale": w_s, "x_scale": x_scale, "zero_point": 0}
        (y,) = get_impl("dense_q", "ref")([x, w_q], attrs)
        x_q = np.clip(np.round(x / x_scale), -QMAX, QMAX).astype(np.int32)
        acc = x_q @ w_q.astype(np.int32)
        expect = acc.astype(np.float32) * (x_scale * w_s[None, :])
        np.testing.assert_allclose(np.asarray(y), expect, rtol=1e-6, atol=1e-6)

    def test_backends_close_to_fp32(self, rng):
        g = conv_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        y_fp = np.asarray(compile(g, FixedPolicy(prefer=("ref",)))(x=x)[0])
        for prefer in (("xla", "ref"), ("ref",)):
            prog = compile(g, FixedPolicy(prefer=prefer), quantize="int8",
                           calib_data=x)
            y_q = np.asarray(prog(x=x)[0])
            np.testing.assert_allclose(y_q, y_fp, atol=0.05)

    def test_dynamic_weight_only_still_runs(self, rng):
        g = conv_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        prog = compile(g, FixedPolicy(prefer=("ref",)), quantize="int8")
        y_fp = np.asarray(compile(g, FixedPolicy(prefer=("ref",)))(x=x)[0])
        np.testing.assert_allclose(np.asarray(prog(x=x)[0]), y_fp, atol=0.1)

    def test_bad_mode_rejected(self, rng):
        with pytest.raises(ValueError, match="quantize mode"):
            compile(conv_graph(rng), quantize="fp8")


# --------------------------------------------------------------------------- #
class TestExampleCNNAcceptance:
    """The ISSUE acceptance criteria on a CNN from ``examples/``."""

    @pytest.fixture(scope="class")
    def built(self):
        from repro.models.cnn import build_cnn
        rng = np.random.default_rng(7)
        g = build_cnn("wrn-40-2", batch=1)
        x = rng.standard_normal(g.inputs["x"].shape).astype(np.float32)
        prog_fp = compile(g)
        prog_q = compile(g, quantize="int8", calib_data=x)
        return g, x, prog_fp, prog_q

    def test_matches_fp32_within_atol(self, built):
        _, x, prog_fp, prog_q = built
        y_fp = np.asarray(prog_fp(x=x)[0])
        y_q = np.asarray(prog_q(x=x)[0])
        np.testing.assert_allclose(y_q, y_fp, atol=0.1)

    def test_weight_bytes_at_least_3x_smaller(self, built):
        _, _, prog_fp, prog_q = built
        assert weight_bytes(prog_fp) >= 3 * weight_bytes(prog_q)
        assert is_quantized(prog_q.graph) and not is_quantized(prog_fp.graph)

    def test_saved_program_reloads_without_recalibration(self, built, tmp_path):
        _, x, _, prog_q = built
        prog_q.save(str(tmp_path / "m"))
        meta = json.load(open(tmp_path / "m" / "program.json"))
        assert meta["quantized"] is True
        z = np.load(os.path.join(tmp_path, "m", "weights.npz"))
        assert any(str(z[k].dtype) == "int8" for k in z.files)
        prog2 = Program.load(str(tmp_path / "m"))  # no calib_data anywhere
        np.testing.assert_array_equal(np.asarray(prog2(x=x)[0]),
                                      np.asarray(prog_q(x=x)[0]))
        assert prog2.assignment == prog_q.assignment


# --------------------------------------------------------------------------- #
class TestFootprintReport:
    def test_weight_and_activation_bytes(self, rng):
        g = conv_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        prog_fp = compile(g, FixedPolicy(prefer=("ref",)))
        prog_q = compile(g, FixedPolicy(prefer=("ref",)), quantize="int8",
                         calib_data=x)
        assert weight_bytes(prog_fp) > 3 * weight_bytes(prog_q)
        assert activation_bytes(prog_fp) > 0

    def test_footprint_table_markdown(self, rng):
        g = conv_graph(rng)
        prog = compile(g, FixedPolicy(prefer=("ref",)))
        progq = compile(g, FixedPolicy(prefer=("ref",)), quantize="int8")
        table = footprint_table([("fp32", prog), ("int8", progq)])
        lines = table.splitlines()
        assert lines[0].startswith("| program | nodes | weight bytes |")
        assert len(lines) == 4  # header + rule + two rows
        assert "| fp32 |" in table and "| int8 |" in table
