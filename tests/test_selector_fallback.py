"""Backend-selector fallback tests.

The policies must degrade cleanly when a fancy backend's ``supports()``
predicate rejects the node's shapes (e.g. pallas block-divisibility), and
must not crash on ops that only have a single registered backend (e.g.
the serving ops ``cache_update`` / ``chunk_attention``): the chosen
backend is always one of the registered-and-supported set.
"""

import numpy as np
import pytest

import repro  # noqa: F401  (registers every op/backend)
from repro.core import (AutotunePolicy, CostModelPolicy, FixedPolicy,
                        Node, TensorSpec, backends_for)


def _attn_node_and_specs():
    # seq 7 with an explicit block_q=4 -> 7 % 4 != 0 -> pallas unsupported
    node = Node("attn", "attention", ["q", "k", "v"], ["o"],
                attrs={"block_q": 4, "block_kv": 4, "causal": True})
    q = TensorSpec((1, 7, 2, 8), "float32")
    kv = TensorSpec((1, 7, 1, 8), "float32")
    return node, [q, kv, kv]


def _grouped_conv_node_and_specs():
    # groups=2 -> the pallas GEMM conv rejects; ref/xla remain
    node = Node("c", "conv2d", ["x", "w"], ["y"], attrs={"groups": 2})
    return node, [TensorSpec((1, 4, 4, 4), "float32"),
                  TensorSpec((3, 3, 2, 4), "float32")]


def _single_backend_node_and_specs():
    # cache_update has exactly one backend (ref)
    node = Node("u", "cache_update", ["c", "n", "s", "k"], ["o"])
    return node, [TensorSpec((2, 8, 1, 4), "float32"),
                  TensorSpec((2, 2, 1, 4), "float32"),
                  TensorSpec((2,), "int32"), TensorSpec((2,), "int32")]


@pytest.mark.parametrize("make", [_attn_node_and_specs,
                                  _grouped_conv_node_and_specs,
                                  _single_backend_node_and_specs])
def test_costmodel_policy_chooses_supported(make):
    node, specs = make()
    avail = backends_for(node.op, specs, node.attrs)
    assert avail, "test premise: at least one supported backend"
    choice = CostModelPolicy().resolve(node, specs)
    assert choice in avail


def test_pallas_actually_rejected_by_supports():
    node, specs = _attn_node_and_specs()
    all_backends = backends_for(node.op)
    supported = backends_for(node.op, specs, node.attrs)
    assert "pallas" in all_backends
    assert "pallas" not in supported      # the shape filter really fired
    node2, specs2 = _grouped_conv_node_and_specs()
    assert "pallas" not in backends_for(node2.op, specs2, node2.attrs)


def test_single_backend_op_resolves_to_ref():
    node, specs = _single_backend_node_and_specs()
    assert backends_for(node.op, specs, node.attrs) == ["ref"]
    assert CostModelPolicy().resolve(node, specs) == "ref"
    assert FixedPolicy(prefer=("pallas", "xla")).resolve(node, specs) == "ref"


def test_autotune_policy_degrades_cleanly():
    pol = AutotunePolicy(reps=1)
    for make in (_grouped_conv_node_and_specs, _single_backend_node_and_specs):
        node, specs = make()
        avail = backends_for(node.op, specs, node.attrs)
        choice = pol.resolve(node, specs)
        assert choice in avail
    assert pol.n_measured >= 2


def test_autotune_single_backend_chunk_attention():
    node = Node("a", "chunk_attention", ["q", "k", "v", "s"], ["o"])
    specs = [TensorSpec((1, 2, 2, 4), "float32"),
             TensorSpec((1, 8, 1, 4), "float32"),
             TensorSpec((1, 8, 1, 4), "float32"),
             TensorSpec((1,), "int32")]
    assert AutotunePolicy(reps=1).resolve(node, specs) == "ref"


def test_pinned_unsupported_backend_raises():
    node, specs = _attn_node_and_specs()
    node.backend = "pallas"
    with pytest.raises(ValueError, match="pinned backend"):
        FixedPolicy().resolve(node, specs)
