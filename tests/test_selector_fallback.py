"""Backend-selector fallback tests.

The policies must degrade cleanly when a fancy backend's ``supports()``
predicate rejects the node's shapes (e.g. pallas block-divisibility), and
must not crash on ops that only have a single registered backend (e.g.
``swiglu``): the chosen backend is always one of the
registered-and-supported set.  The autotuner must also skip measurement
entirely when there is only one candidate — there is nothing to compare.
"""

import numpy as np
import pytest

import repro  # noqa: F401  (registers every op/backend)
from repro.core import (AutotunePolicy, CostModelPolicy, FixedPolicy,
                        Node, TensorSpec, backends_for)


def _attn_node_and_specs():
    # seq 7 with an explicit block_q=4 -> 7 % 4 != 0 -> pallas unsupported
    node = Node("attn", "attention", ["q", "k", "v"], ["o"],
                attrs={"block_q": 4, "block_kv": 4, "causal": True})
    q = TensorSpec((1, 7, 2, 8), "float32")
    kv = TensorSpec((1, 7, 1, 8), "float32")
    return node, [q, kv, kv]


def _grouped_conv_node_and_specs():
    # groups=2 -> the pallas GEMM conv rejects; ref/xla remain
    node = Node("c", "conv2d", ["x", "w"], ["y"], attrs={"groups": 2})
    return node, [TensorSpec((1, 4, 4, 4), "float32"),
                  TensorSpec((3, 3, 2, 4), "float32")]


def _single_backend_node_and_specs():
    # swiglu has exactly one backend (ref) — XLA fuses it well on its own
    node = Node("sw", "swiglu", ["g", "u"], ["o"])
    return node, [TensorSpec((2, 8), "float32"), TensorSpec((2, 8), "float32")]


@pytest.mark.parametrize("make", [_attn_node_and_specs,
                                  _grouped_conv_node_and_specs,
                                  _single_backend_node_and_specs])
def test_costmodel_policy_chooses_supported(make):
    node, specs = make()
    avail = backends_for(node.op, specs, node.attrs)
    assert avail, "test premise: at least one supported backend"
    choice = CostModelPolicy().resolve(node, specs)
    assert choice in avail


def test_pallas_actually_rejected_by_supports():
    node, specs = _attn_node_and_specs()
    all_backends = backends_for(node.op)
    supported = backends_for(node.op, specs, node.attrs)
    assert "pallas" in all_backends
    assert "pallas" not in supported      # the shape filter really fired
    node2, specs2 = _grouped_conv_node_and_specs()
    assert "pallas" not in backends_for(node2.op, specs2, node2.attrs)


def test_single_backend_op_resolves_to_ref():
    node, specs = _single_backend_node_and_specs()
    assert backends_for(node.op, specs, node.attrs) == ["ref"]
    assert CostModelPolicy().resolve(node, specs) == "ref"
    assert FixedPolicy(prefer=("pallas", "xla")).resolve(node, specs) == "ref"


def test_autotune_policy_degrades_cleanly():
    pol = AutotunePolicy(reps=1)
    for make in (_grouped_conv_node_and_specs, _single_backend_node_and_specs):
        node, specs = make()
        avail = backends_for(node.op, specs, node.attrs)
        choice = pol.resolve(node, specs)
        assert choice in avail
    # grouped conv (ref/xla) was measured; single-backend swiglu was not
    assert pol.n_measured == 1


def test_autotune_skips_single_candidate_measurement():
    """Regression: one registered (or candidate-filtered) backend used to
    burn warm-up + reps iterations to "choose" among one option."""
    node, specs = _single_backend_node_and_specs()
    pol = AutotunePolicy(reps=1)
    assert pol.resolve(node, specs) == "ref"
    assert pol.n_measured == 0 and not pol._timings
    # same skip when `candidates` narrows a multi-backend op down to one
    conv, conv_specs = _grouped_conv_node_and_specs()
    pol2 = AutotunePolicy(reps=1, candidates=("xla",))
    assert pol2.resolve(conv, conv_specs) == "xla"
    assert pol2.n_measured == 0 and not pol2._timings


def test_autotune_multibackend_chunk_attention():
    node = Node("a", "chunk_attention", ["q", "k", "v", "s"], ["o"])
    specs = [TensorSpec((1, 2, 2, 4), "float32"),
             TensorSpec((1, 8, 1, 4), "float32"),
             TensorSpec((1, 8, 1, 4), "float32"),
             TensorSpec((1,), "int32")]
    avail = backends_for(node.op, specs, node.attrs)
    assert set(avail) >= {"ref", "xla"}
    pol = AutotunePolicy(reps=1, candidates=("ref", "xla"))
    assert pol.resolve(node, specs) in avail
    assert pol.n_measured == 1


def test_pinned_unsupported_backend_raises():
    node, specs = _attn_node_and_specs()
    node.backend = "pallas"
    with pytest.raises(ValueError, match="pinned backend"):
        FixedPolicy().resolve(node, specs)
