"""Test config. NOTE: no XLA_FLAGS manipulation here — tests run on the
real single CPU device; only launch/dryrun.py fakes 512 devices.
Multi-device sharding tests spawn subprocesses with their own flags
(:func:`run_sub` below).

Also home of the shared ENGINE VARIANT MATRIX: the serving engine ships
in five flavors (dense, paged-fp32, paged-int8, speculative, TP=2) and
every behavioral guarantee — token exactness, fault recovery, page-level
resume — must hold on all of them.  Suites that used to carry private
per-variant parametrize lists (fault injection, kv8 serving,
speculative) draw from :data:`ENGINE_VARIANTS` via
:func:`engine_variants` / :func:`make_engine` instead, so adding a
variant extends every suite at once.  The ``tp2`` variant needs more
than one device: it is driven through :func:`run_sub` subprocesses with
forced host devices, never built in-process."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Subprocess multi-device tests force virtual host devices via XLA_FLAGS,
# so raw device count is not the limiting condition — the mesh code some
# of them drive is: the explicit-sharding API (jax.sharding.AxisType,
# jax.make_mesh(axis_types=...)), which this host's jax may predate.
# Encoding the real condition here keeps local `pytest -x -q` and CI in
# agreement without a deselect list.  Tests that only need the
# version-portable serving path (shard_map_compat / make_serving_mesh)
# run everywhere and should NOT carry this marker.
multidev = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs jax.sharding.AxisType (explicit-sharding mesh API); "
           "this jax predates it")


def run_sub(code: str, n_dev: int = 8, timeout: int = 560) -> str:
    """Run ``code`` in a fresh interpreter with ``n_dev`` forced host
    devices and the repo on PYTHONPATH; assert success, return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# --------------------------------------------------------------------------- #
# shared engine-variant matrix
# --------------------------------------------------------------------------- #

# The tiny config every engine suite shares: big enough for GQA
# (n_heads != n_kv_heads) and multi-layer cache plumbing, small enough
# that a full burst runs in seconds.
TINY_LM = dict(vocab=61, d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
               d_ff=64)

# build_lm_serving kwargs per variant.  "spec" layers speculative
# decoding on the dense engine; suites that want speculation on a cache
# variant compose it themselves (make_engine(variant, spec_k=3)).
ENGINE_VARIANTS = {
    "dense": {},
    "paged-fp32": {"paged": True, "page_size": 8},
    "paged-int8": {"paged": True, "page_size": 8, "kv_dtype": "int8"},
    "spec": {"spec_k": 3},
    "tp2": {"tp": 2},
}


def engine_variants(*names):
    """``pytest.param`` list over the shared matrix for
    ``@pytest.mark.parametrize("variant,engine_kw", engine_variants(...))``.
    No names selects every variant.  Tests that include ``tp2`` must
    dispatch through :func:`run_sub` (a TP engine cannot build in the
    single-device test process); the serving TP path itself is built on
    the version-portable shard_map_compat mesh, so ``tp2`` carries no
    :data:`multidev` version skip — only suites driving the
    explicit-sharding API need that marker."""
    out = []
    for name in names or tuple(ENGINE_VARIANTS):
        out.append(pytest.param(name, dict(ENGINE_VARIANTS[name]), id=name))
    return out


def make_engine(variant, **overrides):
    """(engine, unbatched_reference) for one matrix variant on the
    shared tiny model; ``overrides`` layer on top of the variant kwargs
    (self_heal, spec_k, tier_aware, ...)."""
    from repro.models.graph_lm import GraphLMConfig
    from repro.runtime.engine import build_lm_serving

    if variant == "tp2":
        raise ValueError("tp2 engines only build under run_sub (needs a "
                         "multi-device mesh)")
    kw = dict(ENGINE_VARIANTS[variant])
    kw.update(overrides)
    kw.setdefault("n_slots", 3)
    kw.setdefault("chunk", 4)
    kw.setdefault("cache_cap", 48)
    return build_lm_serving(GraphLMConfig(**TINY_LM), **kw)


@pytest.fixture(scope="session")
def fault_seed():
    """Base seed for randomized fault-injection tick indices.  CI's
    fault-matrix job rotates it per run (ORPHEUS_FAULT_SEED=$run_id) so
    the matrix walks fresh crash/hang timings over time; locally it
    defaults to 0 for reproducible `pytest -x`."""
    return int(os.environ.get("ORPHEUS_FAULT_SEED", "0"))
