"""Test config. NOTE: no XLA_FLAGS manipulation here — tests run on the
real single CPU device; only launch/dryrun.py fakes 512 devices.
Multi-device sharding tests spawn subprocesses with their own flags
(:func:`run_sub` below)."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Subprocess multi-device tests force virtual host devices via XLA_FLAGS,
# so raw device count is not the limiting condition — the mesh code some
# of them drive is: the explicit-sharding API (jax.sharding.AxisType,
# jax.make_mesh(axis_types=...)), which this host's jax may predate.
# Encoding the real condition here keeps local `pytest -x -q` and CI in
# agreement without a deselect list.  Tests that only need the
# version-portable serving path (shard_map_compat / make_serving_mesh)
# run everywhere and should NOT carry this marker.
multidev = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs jax.sharding.AxisType (explicit-sharding mesh API); "
           "this jax predates it")


def run_sub(code: str, n_dev: int = 8, timeout: int = 560) -> str:
    """Run ``code`` in a fresh interpreter with ``n_dev`` forced host
    devices and the repo on PYTHONPATH; assert success, return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
