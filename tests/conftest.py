"""Test config. NOTE: no XLA_FLAGS manipulation here — tests run on the
real single CPU device; only launch/dryrun.py fakes 512 devices.
Multi-device sharding tests spawn subprocesses with their own flags."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
