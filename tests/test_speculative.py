"""Speculative decoding: draft/verify Programs inside the serving engine.

The correctness bar (ISSUE 8 / ROADMAP open item 4): greedy output with
speculation ON must be token-identical to the fp32 dense
:class:`~repro.runtime.engine.UnbatchedReference` for the dense,
paged-fp32 and paged-int8 engines — cold, across prefix hits, and under
injected faults — because acceptance re-checks every draft proposal
against the target model's own argmax.  For int8 KV pages the stronger
structural invariant is pinned too: the speculative engine's output is
BITWISE equal to the non-speculative kv8 engine's on any seed, because
the unrolled verify replays plain decode's quantize-on-write history
exactly (see ``build_paged_verify_seq_graph``).  Rejected speculative
rows must vanish from the pool bookkeeping (``BlockPool.truncate``)
without corrupting shared or indexed pages.
"""

import numpy as np
import pytest
from conftest import TINY_LM, engine_variants, make_engine
from test_fault_injection import _inject_crash, _inject_hang

import repro  # noqa: F401  (registers every op/backend)
from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import EngineRequest
from repro.runtime.kv_cache import BlockPool

TINY = GraphLMConfig(**TINY_LM)


def _reqs(seed, n=7, plo=1, phi=13, mlo=1, mhi=7):
    rng = np.random.default_rng(seed)
    return [EngineRequest(
        uid=i, prompt=rng.integers(0, TINY.vocab,
                                   size=int(rng.integers(plo, phi)))
        .astype(np.int32),
        max_new_tokens=int(rng.integers(mlo, mhi))) for i in range(n)]


def _exact(engine, ref, reqs):
    for r in reqs:
        assert engine.submit(r), r.dropped
    engine.run(max_ticks=engine.tick + 4000)
    for r in reqs:
        assert r.done and r.dropped is None, (r.uid, r.dropped)
        want = ref.generate(r.prompt, r.max_new_tokens)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)
    engine.sched.check_conservation()
    if engine.paged:
        engine.stepper.pool.check_integrity()


# --------------------------------------------------------------------------- #
# token-exactness vs the unbatched reference (all three engine flavors)
# --------------------------------------------------------------------------- #

def test_spec_dense_token_exact():
    engine, ref = make_engine("spec")
    assert engine.spec_k == 3
    _exact(engine, ref, _reqs(21))
    m = engine.metrics
    assert m.spec_ticks > 0 and m.spec_ticks == m.decode_ticks
    assert 0 <= m.spec_accepted <= m.spec_proposed


def test_spec_paged_fp32_token_exact_cold_and_prefix_hit():
    engine, ref = make_engine("paged-fp32", spec_k=3)
    _exact(engine, ref, _reqs(21))
    assert engine.stepper.pool.stats()["live_blocks"] == 0
    # a warm request sharing a long prefix: speculation must compose with
    # prefix reuse (draft caches start cold and catch up; target pages
    # start at the reused length)
    rng = np.random.default_rng(22)
    prefix = rng.integers(0, TINY.vocab, size=24).astype(np.int32)
    cold = EngineRequest(uid=100, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=3).astype(np.int32)]),
        max_new_tokens=5)
    _exact(engine, ref, [cold])
    hits0 = engine.stepper.pool.hit_tokens
    warm = EngineRequest(uid=101, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=2).astype(np.int32)]),
        max_new_tokens=5)
    _exact(engine, ref, [warm])
    assert engine.stepper.pool.hit_tokens - hits0 >= 24


def test_spec_kv8_token_exact_cold():
    engine, ref = make_engine("paged-int8", spec_k=3)
    _exact(engine, ref, _reqs(21))
    assert engine.stepper.pool.stats()["live_blocks"] == 0


def test_spec_kv8_prefix_hit_exact():
    engine, ref = make_engine("paged-int8", spec_k=3)
    rng = np.random.default_rng(22)
    prefix = rng.integers(0, TINY.vocab, size=24).astype(np.int32)
    cold = EngineRequest(uid=100, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=3).astype(np.int32)]),
        max_new_tokens=5)
    _exact(engine, ref, [cold])
    hits0 = engine.stepper.pool.hit_tokens
    warm = EngineRequest(uid=101, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=2).astype(np.int32)]),
        max_new_tokens=5)
    _exact(engine, ref, [warm])
    assert engine.stepper.pool.hit_tokens - hits0 >= 24


def test_spec_composes_with_int8_weight_programs():
    """quantize="int8" (weights) + kv_dtype="int8" (pages) + speculation,
    against the int8-Program dense reference."""
    engine, ref = make_engine("paged-int8", n_slots=2, cache_cap=32,
                              quantize="int8", spec_k=2)
    _exact(engine, ref, _reqs(24, n=4, phi=11, mhi=5))


@pytest.mark.parametrize("seed", [0, 24])
def test_spec_kv8_bitwise_matches_nonspec_engine(seed):
    """The structural invariant that makes kv8 speculation safe on ANY
    seed: the unrolled verify + replay commit reproduce plain decode's
    quantize-on-write history exactly, so the speculative kv8 engine's
    output is bit-identical to the non-speculative kv8 engine's — even
    on seeds where int8 dequant noise makes BOTH diverge from the fp32
    reference (these two seeds do, with longer outputs than the
    reference-exactness tests pin)."""
    def run(spec_k):
        engine, _ = make_engine("paged-int8", spec_k=spec_k)
        reqs = _reqs(seed, n=6, mlo=1, mhi=9)
        for r in reqs:
            assert engine.submit(r)
        engine.run(max_ticks=engine.tick + 4000)
        for r in reqs:
            assert r.done and r.dropped is None
        engine.stepper.pool.check_integrity()
        return {r.uid: list(r.out_tokens) for r in reqs}

    assert run(spec_k=3) == run(spec_k=0)


# --------------------------------------------------------------------------- #
# acceptance metrics + config validation
# --------------------------------------------------------------------------- #

def test_full_model_draft_accepts_everything():
    """draft_layers == n_layers makes the draft the target: every proposal
    matches the target's argmax, so the accept rate is exactly 1.0 and
    each request finishes in ~ceil(new/width) spec ticks — the upper
    bound the serve_bench speedup smoke leans on."""
    engine, ref = make_engine("spec", n_slots=2,
                              draft_layers=TINY.n_layers)
    reqs = [EngineRequest(uid=i, prompt=np.asarray([3 + i, 5, 7], np.int32),
                          max_new_tokens=12) for i in range(2)]
    _exact(engine, ref, reqs)
    m = engine.metrics
    assert m.spec_proposed > 0
    assert m.spec_accepted == m.spec_proposed     # accept_rate == 1.0
    assert m.accept_rate == 1.0
    # 2 requests x 12 tokens at width 4 -> 3 spec ticks each if batched
    # perfectly; generous bound just pins "way fewer ticks than tokens"
    assert m.spec_ticks <= 8
    spec = m.summary()["spec"]
    assert spec["accept_rate"] == 1.0
    assert spec["proposed"] == m.spec_proposed
    # 12 tokens per request, minus the one the prefill tick emits
    assert spec["decode_tokens"] == 22


def test_spec_metrics_zero_when_disabled():
    engine, ref = make_engine("dense", n_slots=2, cache_cap=32)
    _exact(engine, ref, _reqs(5, n=3, phi=8, mhi=4))
    m = engine.metrics
    assert m.spec_ticks == 0 and m.spec_proposed == 0
    assert m.accept_rate == 0.0
    assert m.decode_tokens > 0 and m.decode_wall_s > 0


def test_draft_layers_validation():
    with pytest.raises(ValueError, match="draft_layers"):
        make_engine("dense", n_slots=2, cache_cap=32, spec_k=2,
                    draft_layers=TINY.n_layers + 1)
    with pytest.raises(ValueError, match="draft_layers"):
        make_engine("dense", n_slots=2, cache_cap=32, spec_k=2,
                    draft_layers=0)


# --------------------------------------------------------------------------- #
# BlockPool.truncate — the reject path's bookkeeping
# --------------------------------------------------------------------------- #

def test_truncate_drops_tail_blocks_and_recredits_reservation():
    pool = BlockPool(8, 4)
    sid, reused = pool.admit([1, 2, 3], max_new_tokens=9)
    assert reused == 0
    pool.append(sid, [1, 2, 3])
    # speculative write crosses two page boundaries: rows 3..9
    pool.append(sid, [10, 11, 12, 13, 14, 15, 16])
    assert len(pool.block_table(sid)) == 3
    reserved0 = pool.sequence(sid).reserved
    pool.truncate(sid, 5)          # keep rows 0..4: drop block 2, trim 1
    seq = pool.sequence(sid)
    assert seq.n_tokens == 5 and seq.tokens == [1, 2, 3, 10, 11]
    assert len(pool.block_table(sid)) == 2
    assert seq.reserved == reserved0 + 1    # dropped block re-credited
    pool.check_integrity()
    # the sequence may regrow to the worst case it was admitted for
    pool.append(sid, [20, 21, 22, 23, 24])
    assert pool.sequence(sid).n_tokens == 10
    pool.check_integrity()
    pool.release(sid)
    assert pool.stats()["live_blocks"] == 0


def test_truncate_deindexes_speculatively_registered_pages():
    """A speculative write that fills a page registers it in the prefix
    index; rejecting those rows must also pull the page out of the index
    (its content encodes rejected tokens and must never be donated)."""
    pool = BlockPool(8, 4)
    sid, _ = pool.admit([1, 2, 3, 4], max_new_tokens=6)
    pool.append(sid, [1, 2, 3, 4])
    pool.append(sid, [5, 6, 7, 8])      # fills page 1 -> indexed
    idx0 = pool.stats()["indexed_full_pages"]
    assert idx0 >= 1
    pool.truncate(sid, 5)               # rows 5..7 were speculative
    assert pool.stats()["indexed_full_pages"] == idx0 - 1
    pool.check_integrity()
    # a fresh prompt matching the REJECTED chain must not prefix-hit it
    sid2, reused = pool.admit([1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=2)
    assert reused <= 4
    pool.release(sid2, register=False)
    pool.release(sid, register=False)
    pool.check_integrity()


def test_truncate_bounds_checked():
    pool = BlockPool(4, 4)
    sid, _ = pool.admit([1, 2], max_new_tokens=2)
    pool.append(sid, [1, 2])
    with pytest.raises(ValueError):
        pool.truncate(sid, 3)
    pool.truncate(sid, 2)               # no-op at current length
    assert pool.sequence(sid).n_tokens == 2
    pool.check_integrity()


# --------------------------------------------------------------------------- #
# fault injection through the speculative phases (satellite: recovery)
# --------------------------------------------------------------------------- #

# injection helpers are shared with the unified fault matrix
# (test_fault_injection._inject_crash / _inject_hang, imported above)
SPEC_PHASES = ("prefill", "draft_prefill", "draft", "verify")


def _run_burst(engine, seed=42):
    reqs, streams = [], []
    for i, r in enumerate(_reqs(seed, n=6, phi=10, mlo=4, mhi=7)):
        toks = []
        r.on_token = lambda _r, t, toks=toks: toks.append(t)
        assert engine.submit(r)
        reqs.append(r)
        streams.append(toks)
    engine.run(max_ticks=engine.tick + 4000)
    for r, toks in zip(reqs, streams):
        assert r.done and r.dropped is None, (r.uid, r.dropped)
        assert toks == r.out_tokens, (
            f"request {r.uid}: stream saw {toks}, request holds "
            f"{r.out_tokens} (dup or skip)")
    return {r.uid: list(r.out_tokens) for r in reqs}


def _spec_engine(variant, self_heal=False, hang_timeout=None, **kw):
    engine, _ = make_engine(variant, spec_k=3, self_heal=self_heal,
                            hang_timeout=hang_timeout, **kw)
    return engine


@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("dense", "paged-fp32",
                                         "paged-int8"))
@pytest.mark.parametrize("seed", [0, 1])
def test_spec_crash_recovery_token_identical(variant, engine_kw, seed,
                                             fault_seed):
    """Crashes landing in prefill / draft-catch-up / draft / verify: the
    accepted-but-uncommitted draft tokens of the failed tick must be
    neither duplicated nor lost after recovery."""
    want = _run_burst(_spec_engine(variant))
    engine = _spec_engine(variant, self_heal=True)
    rng = np.random.default_rng(1000 * fault_seed + seed)
    fails = set(int(c) for c in rng.choice(np.arange(2, 20), size=3,
                                           replace=False))
    _inject_crash(engine.stepper, fails, SPEC_PHASES)
    got = _run_burst(engine)
    assert engine.metrics.n_recoveries >= 1
    assert got == want
    engine.sched.check_conservation()
    if engine.paged:
        engine.stepper.pool.check_integrity()
        assert engine.stepper.pool.live_sequences == 0


@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("dense", "paged-fp32",
                                         "paged-int8"))
def test_spec_hang_recovery_token_identical(variant, engine_kw):
    """Hangs (the call completes but overruns the deadline, so its result
    is discarded): draft-cache and fp32 page writes of the discarded tick
    are overwritten identically on retry; the kv8 verify leaves the live
    pages untouched, so its discarded tick leaves no residue at all."""
    want = _run_burst(_spec_engine(variant))
    engine = _spec_engine(variant, self_heal=True, hang_timeout=0.25)
    _inject_hang(engine.stepper, {3, 9}, sleep_s=0.6, phases=SPEC_PHASES)
    got = _run_burst(engine)
    assert engine.metrics.n_hang_failures >= 2
    assert engine.metrics.n_recoveries >= 2
    assert got == want
    if engine.paged:
        engine.stepper.pool.check_integrity()


def test_spec_kv8_commit_crash_recovery_token_identical():
    """A crash on the spec-commit call itself: the tick's pool bookkeeping
    rolls back to the checkpoint, the retried verify re-reads the
    untouched pages, and the replayed commit lands the same rows."""
    want = _run_burst(_spec_engine("paged-int8"))
    engine = _spec_engine("paged-int8", self_heal=True)
    _inject_crash(engine.stepper, {1, 3}, phases=("commit_spec",))
    got = _run_burst(engine)
    assert engine.metrics.n_recoveries >= 2
    assert got == want
    engine.stepper.pool.check_integrity()
    assert engine.stepper.pool.live_sequences == 0


def test_spec_kv8_commit_hang_recovery_token_identical():
    """A hang on the spec-commit call: the write chain completed on
    device before being discarded, and the retried commit replays the
    identical single-row writes — identical rows quantize to identical
    bytes and never raise a page scale, so the replay is idempotent."""
    want = _run_burst(_spec_engine("paged-int8"))
    engine = _spec_engine("paged-int8", self_heal=True, hang_timeout=0.25)
    _inject_hang(engine.stepper, {2}, sleep_s=0.6, phases=("commit_spec",))
    got = _run_burst(engine)
    assert engine.metrics.n_hang_failures >= 1
    assert got == want
    engine.stepper.pool.check_integrity()
