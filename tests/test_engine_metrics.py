"""EngineMetrics edge cases: percentile math on degenerate windows, the
p50/p95/p99 summary shape, and deterministic TTFT-tick accounting —
including under the paged cache's prefix-hit fast-forward, where the
first token arrives in fewer ticks because prefill skips reused rows.
"""

import numpy as np
import pytest

from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import (EngineMetrics, EngineRequest, _pct,
                                  _pct_dict, build_lm_serving)

TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)


# --------------------------------------------------------------------------- #
# percentile edge cases
# --------------------------------------------------------------------------- #

def test_pct_empty_window_is_none_not_zero():
    """Regression (ISSUE 8): an empty window used to report 0.0 — a run
    with zero finished requests then scored a perfect p99 TTFT of 0.0 in
    serve_bench/run_load JSON.  "No data" must be None (serialized as
    null, rendered as an em dash), never a best-possible number."""
    for q in (0, 50, 95, 99, 100):
        assert _pct([], q) is None


def test_pct_single_sample_every_quantile():
    for q in (0, 50, 95, 99, 100):
        assert _pct([3.25], q) == 3.25


def test_pct_all_equal_window():
    xs = [7.0] * 40
    for q in (50, 95, 99):
        assert _pct(xs, q) == 7.0


def test_pct_interpolates_and_orders():
    xs = list(np.arange(1.0, 101.0))      # 1..100
    assert _pct(xs, 50) == pytest.approx(50.5)
    assert _pct(xs, 0) == 1.0 and _pct(xs, 100) == 100.0
    assert _pct(xs, 50) <= _pct(xs, 95) <= _pct(xs, 99)
    # order-invariant
    rng = np.random.default_rng(0)
    shuffled = list(rng.permutation(xs))
    for q in (50, 95, 99):
        assert _pct(shuffled, q) == pytest.approx(_pct(xs, q))


def test_pct_dict_shape():
    d = _pct_dict([1.0, 2.0, 3.0])
    assert set(d) == {"p50", "p95", "p99", "n_samples"}
    assert d["n_samples"] == 3
    assert d["p50"] <= d["p95"] <= d["p99"]
    # empty window: every percentile is null and the sample count says why
    assert _pct_dict([]) == {"p50": None, "p95": None, "p99": None,
                             "n_samples": 0}


def test_summary_has_p99_and_self_heal():
    m = EngineMetrics(n_slots=2)
    m.latencies_s = [0.1, 0.2, 0.9]
    m.ttfts_s = [0.05]
    s = m.summary()
    for key in ("latency_s", "ttft_s"):
        assert set(s[key]) == {"p50", "p95", "p99", "n_samples"}
    assert s["ttft_s"]["p99"] == 0.05          # single sample
    assert s["ttft_s"]["n_samples"] == 1
    sh = s["self_heal"]
    assert set(sh) == {"failed_ticks", "n_crash_failures", "n_hang_failures",
                       "n_recoveries", "requeued_requests", "straggler_ticks",
                       "recovered_rows"}
    assert all(v == 0 for v in sh.values())    # zero when self_heal is off
    ov = s["overload"]
    assert set(ov) == {"n_preempted", "n_tier_shed"}
    assert all(v == 0 for v in ov.values())    # zero when tier_aware is off
    sp = s["spec"]
    assert set(sp) == {"spec_ticks", "proposed", "accepted", "accept_rate",
                       "decode_tokens", "decode_wall_s",
                       "decode_tokens_per_s"}
    assert all(v == 0 for v in sp.values())    # zero when spec_k == 0


# --------------------------------------------------------------------------- #
# report rendering on starved tiers (ISSUE 10 satellite)
# --------------------------------------------------------------------------- #

def _tier_row(offered, finished, met, shed=0):
    from repro.runtime.engine import _pct_dict
    samples = [3.0] * finished
    return {"n_offered": offered, "n_finished": finished, "n_shed": shed,
            "n_dropped": offered - finished - shed,
            "n_slo_met": met,
            "slo_attainment": met / finished if finished else None,
            "goodput_requests_per_s": float(met),
            "ttft_ticks": _pct_dict(samples), "gap_ticks": _pct_dict(samples)}


def test_load_table_renders_zero_finished_tier_as_dash():
    """Regression: a tier whose every request was shed under overload has
    ``slo_attainment: null`` and empty percentile windows; load_table
    used to feed the None straight into a ``%`` format spec and crash.
    It must render em dashes — and never a fake 0% or perfect 100%."""
    from repro.tools.report import load_table
    rec = {"load": {
        "slo": {"ttft_ticks": 12, "gap_ticks": 4},
        "overall": _tier_row(6, 4, 3, shed=2),
        "tiers": {"interactive": _tier_row(4, 4, 3),
                  "batch": _tier_row(2, 0, 0, shed=2)}}}
    table = load_table([("starved", rec)])
    starved = [ln for ln in table.splitlines() if "| batch |" in ln]
    assert len(starved) == 1
    assert "—" in starved[0]
    assert "0%" not in starved[0] and "100%" not in starved[0]
    healthy = [ln for ln in table.splitlines() if "| interactive |" in ln][0]
    assert "75%" in healthy and "—" not in healthy


def test_overload_table_attainment_is_met_over_offered():
    """The overload table scores attainment against OFFERED requests (a
    shed request missed its SLO by definition); a zero-offered tier is an
    em dash.  The high tier is starred so the headline rows are findable
    in a multi-config report."""
    from repro.tools.report import overload_table
    pol = lambda tiers, pre, shed: {                       # noqa: E731
        "report": {"tiers": tiers}, "n_preempted": pre, "n_tier_shed": shed}
    rec = {"overload": {
        "high_tier": "interactive",
        "policies": {
            "tier_blind": pol({"interactive": _tier_row(4, 2, 1, shed=2),
                               "idle": _tier_row(0, 0, 0)}, 0, 0),
            "tier_aware": pol({"interactive": _tier_row(4, 4, 4),
                               "idle": _tier_row(0, 0, 0)}, 1, 2)}}}
    table = overload_table([("cfg", rec)])
    lines = table.splitlines()
    blind = [ln for ln in lines if "tier_blind | interactive" in ln][0]
    aware = [ln for ln in lines if "tier_aware | interactive" in ln][0]
    assert "interactive *" in blind          # high tier is starred
    assert "25%" in blind                    # 1 met / 4 OFFERED, not 1/2
    assert "100%" in aware
    idle_rows = [ln for ln in lines if "| idle |" in ln]
    assert len(idle_rows) == 2 and all("—" in ln for ln in idle_rows)


def test_empty_sections_render_no_rows():
    """Records without the section produce a header-only table instead of
    crashing (reports span mixed-schema record sets)."""
    from repro.tools.report import load_table, overload_table
    for fn in (load_table, overload_table):
        table = fn([("old", {"engine": {}})])
        assert len(table.splitlines()) == 2  # header + separator only


# --------------------------------------------------------------------------- #
# deterministic TTFT ticks, with and without prefix fast-forward
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def paged_engine():
    return build_lm_serving(TINY, n_slots=2, chunk=4, cache_cap=48,
                            paged=True, page_size=4)[0]


def _run_one(engine, prompt, uid):
    req = EngineRequest(uid=uid, prompt=np.asarray(prompt, np.int32),
                        max_new_tokens=3)
    assert engine.submit(req), req.dropped
    engine.run(max_ticks=engine.tick + 10_000)
    assert req.done
    return req


def test_ttft_ticks_accounting(paged_engine):
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, TINY.vocab, size=10).astype(np.int32)
    req = _run_one(paged_engine, prompt, uid=1)
    # 10 prompt tokens at chunk 4 on an idle engine: 3 prefill ticks, the
    # last of which emits the first token — plus the submit->admit tick
    assert req.first_token_tick is not None
    assert req.ttft_ticks == req.first_token_tick - req.submit_tick
    assert req.ttft_ticks >= 3


def test_ttft_shrinks_under_prefix_hit(paged_engine):
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, TINY.vocab, size=16).astype(np.int32)
    cold = _run_one(paged_engine, np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=2).astype(np.int32)]),
        uid=2)
    warm = _run_one(paged_engine, np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=2).astype(np.int32)]),
        uid=3)
    assert cold.ttft_ticks is not None and warm.ttft_ticks is not None
    # the warm request's prefill fast-forwards past the shared prefix
    # pages, so its first token arrives in strictly fewer ticks
    assert warm.ttft_ticks < cold.ttft_ticks, (warm.ttft_ticks,
                                               cold.ttft_ticks)
    assert paged_engine.stepper.pool.hit_tokens >= len(prefix)


def test_unsubmitted_request_has_no_ttft():
    req = EngineRequest(uid=0, prompt=np.ones(2, np.int32), max_new_tokens=1)
    assert req.ttft_ticks is None and req.ttft_s is None
    assert req.latency_s is None
