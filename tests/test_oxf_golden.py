"""Golden-file regression test for the OXF bundle format.

``tests/golden/tiny_int8`` is a checked-in int8-quantized Program bundle
(dense+bias+relu fused then quantized: ``dense_fused_q`` with w_scale /
x_scale / zero_point attrs, an int8 weight twin, a bias-corrected qbias —
the whole PR-2 surface).  The bundle must load and, on re-save, reproduce
``program.json`` and ``model.json`` byte-for-byte: any silent change to
attr serialization, assignment pinning, cost-table emission or key
ordering fails here before it corrupts deployed artifacts.
"""

import os

import numpy as np
import pytest

from repro.core import Program, load_graph
from repro.core.quant import is_quantized

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "tiny_int8")


def test_golden_bundle_loads_quantized():
    prog = Program.load(GOLDEN)
    assert is_quantized(prog.graph)
    ops = {n.op for n in prog.graph.nodes}
    assert ops == {"dense_fused_q", "dense_q"}
    # pinned assignment reproduced without re-tuning
    assert set(prog.assignment.values()) == {"xla"}
    # int8 weight twins + self-describing quant attrs survived the trip
    assert prog.graph.params["w1.q8"].dtype == np.int8
    node = next(n for n in prog.graph.nodes if n.op == "dense_fused_q")
    for key in ("w_scale", "x_scale", "zero_point"):
        assert key in node.attrs, key


def test_golden_bundle_resave_byte_identical(tmp_path):
    prog = Program.load(GOLDEN)
    out = tmp_path / "resaved"
    prog.save(str(out))
    for fname in ("program.json", "model.json"):
        golden = open(os.path.join(GOLDEN, fname), "rb").read()
        resaved = open(out / fname, "rb").read()
        assert resaved == golden, f"{fname} drifted from the golden bundle"
    # weights round-trip exactly (array-compare; npz container bytes may
    # legitimately differ)
    g0, g1 = load_graph(GOLDEN), load_graph(str(out))
    assert set(g0.params) == set(g1.params)
    for k in g0.params:
        np.testing.assert_array_equal(np.asarray(g0.params[k]),
                                      np.asarray(g1.params[k]), err_msg=k)


def test_golden_bundle_executes_to_expected_output():
    prog = Program.load(GOLDEN)
    x = np.load(os.path.join(GOLDEN, "input_x.npy"))
    want = np.load(os.path.join(GOLDEN, "expected_y.npy"))
    (y,) = prog(x=x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-6)


def test_golden_double_roundtrip_stable(tmp_path):
    """save(load(save(load(x)))) is a fixpoint, not just one lucky hop."""
    prog = Program.load(GOLDEN)
    a = tmp_path / "a"
    prog.save(str(a))
    b = tmp_path / "b"
    Program.load(str(a)).save(str(b))
    for fname in ("program.json", "model.json"):
        assert open(a / fname, "rb").read() == open(b / fname, "rb").read()


# --------------------------------------------------------------------------- #
# partitioned bundles (PR 9): the "partition" program.json section
# --------------------------------------------------------------------------- #

def test_partitioned_bundle_roundtrip_on_test_mesh():
    """Save a partitioned Program on a real 2x2 mesh, reload it onto a
    compatible mesh with zero re-planning: specs survive byte-identically
    (resave fixpoint) and object-identically; an incompatible mesh raises
    the documented ValueError."""
    from conftest import run_sub
    run_sub("""
import json, os, tempfile
import numpy as np, jax
from jax.sharding import Mesh
import repro
from repro.core.program import Program, compile
from repro.models.graph_lm import GraphLMConfig, build_decode_graph, \\
    init_lm_params

cfg = GraphLMConfig(vocab=61, d_model=32, n_layers=1, n_heads=4,
                    n_kv_heads=2, d_ff=64)
g = build_decode_graph(cfg, init_lm_params(cfg), batch=2, cache_cap=16)
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
prog = compile(g, mesh=mesh)
assert prog.partition is not None

tmp = tempfile.mkdtemp()
a, b = os.path.join(tmp, "a"), os.path.join(tmp, "b")
prog.save(a)
meta = json.load(open(os.path.join(a, "program.json")))
assert meta["partition"]["mesh"] == {"data": 2, "model": 2}

# reload onto the same mesh: specs identical, no re-planning
loaded = Program.load(a, mesh=mesh)
assert dict(loaded.partition["mesh"]) == dict(prog.partition["mesh"])
assert dict(loaded.partition["specs"]) == dict(prog.partition["specs"])

# resave fixpoint: the partition section is byte-stable
loaded.save(b)
assert open(os.path.join(a, "program.json"), "rb").read() == \\
       open(os.path.join(b, "program.json"), "rb").read()

# a mesh of different shape is refused with the documented error
bad = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("model",))
try:
    Program.load(a, mesh=bad)
except ValueError as e:
    assert "mesh axes" in str(e), e
else:
    raise AssertionError("mesh mismatch not caught")

# mesh=None load keeps the recorded partition (inspection / re-serve on
# a compatible mesh built later)
again = Program.load(a)
assert dict(again.partition["specs"]) == dict(prog.partition["specs"])
print("OK")
""")


def test_unpartitioned_bundle_has_no_partition_key(tmp_path):
    """Additive evolution: bundles saved without a mesh carry no
    "partition" key at all — the golden bytes above prove it for the
    checked-in artifact; this pins the Program.partition API side."""
    import json
    prog = Program.load(GOLDEN)
    assert prog.partition is None
    out = tmp_path / "plain"
    prog.save(str(out))
    meta = json.load(open(out / "program.json"))
    assert "partition" not in meta
