"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode)
against its pure-jnp oracle in ref.py."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_partial
from repro.kernels.gemm import batched_gemm, gemm
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd import ssd_scan

rng = np.random.default_rng(7)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,sq,skv,hq,hkv,d,causal,window", [
    (2, 128, 128, 4, 2, 64, True, None),
    (1, 256, 256, 8, 8, 128, True, None),
    (2, 128, 256, 4, 1, 64, True, None),      # chunked-prefill offset
    (1, 256, 256, 4, 2, 64, True, 64),        # sliding window
    (1, 128, 128, 2, 2, 96, False, None),     # non-causal, odd head dim
    (1, 64, 64, 2, 1, 32, True, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(b, sq, skv, hq, hkv, d, causal, window, dtype):
    q, k, v = (randn((b, sq, hq, d), dtype), randn((b, skv, hkv, d), dtype),
               randn((b, skv, hkv, d), dtype))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol(dtype))


def test_flash_attention_mixed_v_dim():
    """MLA shape: v head dim != qk head dim."""
    q, k = randn((1, 128, 4, 96)), randn((1, 128, 2, 96))
    v = randn((1, 128, 2, 64))
    out = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    ref = R.attention_ref(q, k, v)
    assert out.shape == (1, 128, 4, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,skv,hq,hkv,d", [
    (2, 256, 8, 2, 64), (1, 512, 4, 1, 128), (3, 128, 16, 16, 96),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vs_ref(b, skv, hq, hkv, d, dtype):
    q = randn((b, hq, d), dtype)
    k, v = randn((b, skv, hkv, d), dtype), randn((b, skv, hkv, d), dtype)
    lens = jnp.asarray(rng.integers(1, skv + 1, (b,)), jnp.int32)
    out = flash_decode(q, k, v, lens, block_kv=64, interpret=True)
    ref = R.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol(dtype))


def test_flash_decode_partial_combine_exact():
    """Sharded partials combined == full softmax (the tree-decode identity),
    including an all-masked shard (length 0)."""
    b, skv, hq, hkv, d = 2, 256, 8, 2, 64
    q, k, v = randn((b, hq, d)), randn((b, skv, hkv, d)), randn((b, skv, hkv, d))
    lens = jnp.asarray([100, 256], jnp.int32)
    ref = R.decode_attention_ref(q, k, v, lens)
    parts = []
    n_shards = 4
    per = skv // n_shards
    for i in range(n_shards):
        local_len = jnp.clip(lens - i * per, 0, per)
        parts.append(flash_decode_partial(q, k[:, i*per:(i+1)*per],
                                          v[:, i*per:(i+1)*per], local_len,
                                          block_kv=64, interpret=True))
    outs = jnp.stack([p[0] for p in parts])
    ms = jnp.stack([p[1] for p in parts])
    ls = jnp.stack([p[2] for p in parts])
    comb = R.combine_partials_ref(outs, ms, ls)
    np.testing.assert_allclose(np.asarray(comb), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 64, 1, 32, 32),
    (1, 256, 8, 64, 2, 128, 64),
    (2, 64, 2, 32, 2, 16, 64),      # chunk > seq
    (1, 96, 4, 32, 1, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_ref(b, s, h, p, g, n, chunk, dtype):
    x = randn((b, s, h, p), dtype)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.1 + 0.01,
                     jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((h,))) - 0.1, jnp.float32)
    B = randn((b, s, g, n), dtype)
    C = randn((b, s, g, n), dtype)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    y_ref, st_ref = R.ssd_ref(x, dt, A, B, C, D)
    y_k, st_k = ssd_scan(x, dt, A, B, C, D, chunk=min(chunk, s), interpret=True)
    # bf16 outputs round at ~0.4% ULP on O(10) magnitudes: compare relative
    atol = 5e-4 if dtype == jnp.float32 else 5e-2
    rtol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=rtol, atol=atol)
    # the carried state is f32 in both implementations: tight tolerance
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref), atol=5e-4)


def test_ssd_chunked_matches_sequential():
    b, s, h, p, g, n = 1, 128, 2, 32, 1, 16
    x = randn((b, s, h, p))
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.1 + 0.01)
    A = jnp.asarray(-np.abs(rng.standard_normal((h,))) - 0.1)
    B, C = randn((b, s, g, n)), randn((b, s, g, n))
    y1, s1 = R.ssd_ref(x, dt, A, B, C, None)
    y2, s2 = R.ssd_chunked_ref(x, dt, A, B, C, None, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_step_continues_prefill():
    """decode steps continuing a prefill state == one long scan."""
    b, s, h, p, g, n = 1, 64, 2, 16, 1, 8
    x = randn((b, s + 4, h, p))
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s + 4, h))) * 0.1 + 0.01)
    A = jnp.asarray(-np.abs(rng.standard_normal((h,))) - 0.1)
    B, C = randn((b, s + 4, g, n)), randn((b, s + 4, g, n))
    D = jnp.asarray(rng.standard_normal((h,)))
    y_full, _ = R.ssd_ref(x, dt, A, B, C, D)
    _, state = R.ssd_ref(x[:, :s], dt[:, :s], A, B[:, :s], C[:, :s], D)
    for t in range(s, s + 4):
        y_t, state = R.ssd_step_ref(x[:, t], dt[:, t], A, B[:, t], C[:, t],
                                    D, state)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, t]),
                                   atol=1e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(4, 17, 256), (2, 100, 1024), (384, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_ref(shape, dtype):
    x = randn(shape, dtype)
    w = randn(shape[-1:], dtype)
    r = randn(shape, dtype)
    out = rmsnorm(x, w, block_rows=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(R.rmsnorm_ref(x, w), np.float32),
                               atol=tol(dtype))
    out_r = rmsnorm(x, w, residual=r, block_rows=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out_r, np.float32),
        np.asarray(R.rmsnorm_ref(x, w, residual=r), np.float32),
        atol=tol(dtype))


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,n", [(256, 512, 256), (100, 300, 200),
                                   (33, 65, 129), (1, 128, 1)])
def test_gemm_vs_ref(m, k, n):
    x, w = randn((m, k)), randn((k, n))
    out = gemm(x, w, block_m=64, block_n=64, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(R.gemm_ref(x, w)),
                               atol=1e-3)


@pytest.mark.parametrize("e,m,k,n", [(4, 64, 128, 96), (3, 50, 70, 30)])
def test_batched_gemm_vs_ref(e, m, k, n):
    x, w = randn((e, m, k)), randn((e, k, n))
    out = batched_gemm(x, w, block_m=32, block_n=32, block_k=64,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(R.batched_gemm_ref(x, w)), atol=1e-3)


def test_gemm_bf16_accumulates_f32():
    x = randn((128, 256), jnp.bfloat16)
    w = randn((256, 128), jnp.bfloat16)
    out = gemm(x, w, interpret=True)
    ref = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=2e-2, atol=2e-1)
