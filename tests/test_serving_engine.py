"""Serving engine tests.

Scheduler invariants (deterministic randomized + hypothesis variants when
hypothesis is installed):

* no request dropped or duplicated — every submitted request reaches
  exactly one terminal state, conservation holds at every step;
* FIFO admission among same-priority requests;
* slot-count conservation (never more than n_slots active);
* chunked-prefill output == one-shot prefill output (token-exact, greedy).

Engine end-to-end: token-exactness vs the unbatched reference for fp32
AND int8 Programs, slot-reuse state isolation, streaming callbacks, the
asyncio front-end, admission control, deadlines, and metrics.
"""

import asyncio

import numpy as np
import pytest

from repro.models.graph_lm import GraphLMConfig
from repro.runtime.batching import SlotScheduler
from repro.runtime.engine import (AsyncEngine, EngineRequest,
                                  build_lm_serving)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)


@pytest.fixture(scope="module")
def serving_fp32():
    return build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48)


@pytest.fixture(scope="module")
def serving_int8():
    return build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                            quantize="int8")


def _req(uid, rng, *, max_prompt=13, max_new=7, priority=0):
    plen = int(rng.integers(1, max_prompt))
    return EngineRequest(uid=uid,
                         prompt=rng.integers(0, TINY.vocab,
                                             size=plen).astype(np.int32),
                         max_new_tokens=int(rng.integers(1, max_new)),
                         priority=priority)


# --------------------------------------------------------------------------- #
# SlotScheduler invariants (no model, no jax — pure scheduling)
# --------------------------------------------------------------------------- #

class _Dummy:
    def __init__(self, uid, priority=0):
        self.uid = uid
        self.priority = priority


def _drive_random(n_slots, max_queue, ops, priorities):
    """Replay a random op sequence against SlotScheduler, checking the
    invariants at every step.  ``ops`` is a sequence of 'submit' /
    'finish' / 'drop' / 'admit' strings."""
    sched = SlotScheduler(n_slots, max_queue=max_queue)
    uid = 0
    admitted_order = []
    terminal = set()
    rng = np.random.default_rng(0)
    for op in ops:
        if op == "submit":
            r = _Dummy(uid, priorities[uid % len(priorities)])
            uid += 1
            sched.submit(r)
        elif op == "admit":
            for slot, req in sched.admit():
                admitted_order.append(req)
        elif op in ("finish", "drop"):
            busy = [i for i, s in enumerate(sched.active) if s is not None]
            if busy:
                slot = int(rng.choice(busy))
                req = (sched.finish(slot) if op == "finish"
                       else sched.drop(slot))
                assert req.uid not in terminal, "request finalised twice"
                terminal.add(req.uid)
        assert sched.busy_slots <= n_slots
        sched.check_conservation()
    # each admitted request appeared exactly once
    uids = [r.uid for r in admitted_order]
    assert len(uids) == len(set(uids)), "request admitted twice"
    return sched, admitted_order


def test_scheduler_no_drop_or_dup_randomized():
    rng = np.random.default_rng(7)
    for trial in range(25):
        n_slots = int(rng.integers(1, 5))
        max_queue = [None, 1, 3][trial % 3]
        ops = list(rng.choice(["submit", "admit", "finish", "drop"],
                              size=int(rng.integers(5, 60))))
        _drive_random(n_slots, max_queue, ops, priorities=[0, 1, 2])


def test_scheduler_fifo_same_priority():
    sched = SlotScheduler(2)
    reqs = [_Dummy(i) for i in range(6)]
    for r in reqs:
        sched.submit(r)
    order = []
    while sched.has_work():
        for slot, req in sched.admit():
            order.append(req.uid)
        for slot in range(2):
            if sched.active[slot] is not None:
                sched.finish(slot)
    assert order == [0, 1, 2, 3, 4, 5]


def test_scheduler_priority_preempts_fifo():
    sched = SlotScheduler(1)
    sched.submit(_Dummy(0, priority=0))
    sched.submit(_Dummy(1, priority=5))
    sched.submit(_Dummy(2, priority=0))
    order = []
    while sched.has_work():
        for _, req in sched.admit():
            order.append(req.uid)
        sched.finish(0)
    assert order == [1, 0, 2]   # high priority first; FIFO among equals


def test_scheduler_slot_conservation_and_queue_bound():
    sched = SlotScheduler(2, max_queue=2)
    accepted = [sched.submit(_Dummy(i)) for i in range(6)]
    assert accepted == [True, True, False, False, False, False]
    assert sched.n_rejected == 4
    sched.admit()
    assert sched.busy_slots == 2 and sched.queue_len == 0
    sched.check_conservation()


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 4), st.sampled_from([None, 1, 4]),
           st.lists(st.sampled_from(["submit", "admit", "finish", "drop"]),
                    min_size=1, max_size=60),
           st.lists(st.integers(0, 3), min_size=1, max_size=4))
    @settings(max_examples=60, deadline=None)
    def test_scheduler_invariants_hypothesis(n_slots, max_queue, ops, prios):
        _drive_random(n_slots, max_queue, ops, priorities=prios)

    @given(st.lists(st.integers(0, 0), min_size=1, max_size=12))
    @settings(max_examples=25, deadline=None)
    def test_scheduler_fifo_hypothesis(prios):
        sched = SlotScheduler(1)
        for i in range(len(prios)):
            sched.submit(_Dummy(i, prios[i]))
        order = []
        while sched.has_work():
            for _, req in sched.admit():
                order.append(req.uid)
            sched.finish(0)
        assert order == sorted(order)


# --------------------------------------------------------------------------- #
# Chunked prefill == one-shot prefill (token-exact, greedy)
# --------------------------------------------------------------------------- #

def test_chunked_prefill_equals_oneshot(serving_fp32):
    _, ref = serving_fp32
    rng = np.random.default_rng(3)
    for plen in (1, 2, 5, 9, 11):
        prompt = rng.integers(0, TINY.vocab, size=plen).astype(np.int32)
        oneshot = ref.generate(prompt, 5)
        for chunk in (1, 3, 4):
            assert ref.generate(prompt, 5, chunk=chunk) == oneshot, \
                (plen, chunk)


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 12), st.sampled_from([1, 2, 3, 4]),
           st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def test_chunked_equals_oneshot_hypothesis(plen, chunk, seed):
        # module fixture not available to @given: build once, cache on the
        # function object (Programs are compiled lazily per chunk size)
        if not hasattr(test_chunked_equals_oneshot_hypothesis, "_ref"):
            test_chunked_equals_oneshot_hypothesis._ref = build_lm_serving(
                TINY, n_slots=2, chunk=4, cache_cap=48)[1]
        ref = test_chunked_equals_oneshot_hypothesis._ref
        prompt = np.random.default_rng(seed).integers(
            0, TINY.vocab, size=plen).astype(np.int32)
        assert ref.generate(prompt, 4, chunk=chunk) == ref.generate(prompt, 4)


# --------------------------------------------------------------------------- #
# Engine vs unbatched reference — fp32 and int8
# --------------------------------------------------------------------------- #

def _exactness(engine, ref, seed):
    rng = np.random.default_rng(seed)
    reqs = [_req(i, rng) for i in range(7)]
    for r in reqs:
        assert engine.submit(r)
    finished = engine.run(max_ticks=2000)
    assert {r.uid for r in finished} >= {r.uid for r in reqs}
    for r in reqs:
        assert r.done and r.dropped is None
        assert r.out_tokens == ref.generate(r.prompt, r.max_new_tokens), r.uid
    engine.sched.check_conservation()


def test_engine_token_exact_fp32(serving_fp32):
    _exactness(*serving_fp32, seed=11)


def test_engine_token_exact_int8(serving_int8):
    _exactness(*serving_int8, seed=12)


def test_engine_slot_reuse_no_state_leak(serving_fp32):
    """A second wave of requests on a warm engine (caches full of the
    first wave's K/V) must still match the fresh-cache reference."""
    engine, ref = serving_fp32
    for seed in (21, 22):
        _exactness(engine, ref, seed)


def test_engine_int8_uses_quantized_programs(serving_int8):
    engine, _ = serving_int8
    from repro.core.quant import is_quantized
    assert is_quantized(engine.stepper.decode_program.graph)
    assert is_quantized(engine.stepper.prefill_program.graph)


# --------------------------------------------------------------------------- #
# Streaming, async front-end, admission control, deadlines, metrics
# --------------------------------------------------------------------------- #

def test_streaming_callbacks_in_order(serving_fp32):
    engine, _ = serving_fp32
    seen = []
    rng = np.random.default_rng(31)
    req = _req(100, rng)
    req.on_token = lambda r, t: seen.append((r.uid, t))
    assert engine.submit(req)
    engine.run(max_ticks=500)
    assert [t for _, t in seen] == req.out_tokens
    assert all(u == 100 for u, _ in seen)


def test_async_engine_streams_match_reference(serving_fp32):
    engine, ref = serving_fp32
    aeng = AsyncEngine(engine)
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, TINY.vocab, size=n).astype(np.int32)
               for n in (3, 7)]

    async def collect(prompt):
        return [tok async for tok in aeng.generate(prompt, 5)]

    async def main():
        return await asyncio.gather(collect(prompts[0]), collect(prompts[1]),
                                    aeng.run())

    out_a, out_b, _ = asyncio.run(main())
    assert out_a == ref.generate(prompts[0], 5)
    assert out_b == ref.generate(prompts[1], 5)


def test_admission_control(serving_fp32):
    engine, _ = serving_fp32
    rng = np.random.default_rng(51)
    too_long = EngineRequest(uid=200, prompt=np.zeros(45, np.int32),
                             max_new_tokens=30)
    assert not engine.submit(too_long)
    assert too_long.dropped == "too_long"
    empty = EngineRequest(uid=201, prompt=np.zeros(0, np.int32),
                          max_new_tokens=3)
    assert not engine.submit(empty)
    assert empty.dropped == "empty"
    engine.sched.check_conservation()
    # queue-full rejection (dedicated engine so the shared one stays clean)
    small, _ = build_lm_serving(TINY, n_slots=1, chunk=4, cache_cap=32,
                                max_queue=1)
    r1, r2 = _req(1, rng), _req(2, rng)
    assert small.submit(r1)
    assert not small.submit(r2)
    assert r2.dropped == "queue_full"
    small.run(max_ticks=200)
    assert r1.done
    small.sched.check_conservation()


def test_deadline_drops_but_preserves_others(serving_fp32):
    engine, ref = serving_fp32
    rng = np.random.default_rng(61)
    doomed = _req(300, rng)
    doomed.deadline_tick = engine.tick + 1   # expires almost immediately
    doomed.max_new_tokens = 30               # could never finish in time
    survivor = _req(301, rng)
    assert engine.submit(doomed) and engine.submit(survivor)
    engine.run(max_ticks=500)
    assert doomed.dropped == "deadline" and not doomed.done
    assert survivor.done
    assert survivor.out_tokens == ref.generate(survivor.prompt,
                                               survivor.max_new_tokens)
    engine.sched.check_conservation()


def test_metrics_summary_shape(serving_fp32):
    engine, _ = serving_fp32
    rng = np.random.default_rng(71)
    for i in range(3):
        engine.submit(_req(400 + i, rng))
    engine.run(max_ticks=500)
    m = engine.metrics.summary()
    for key in ("tokens_per_s", "busy_slot_fraction", "latency_s", "ttft_s",
                "max_intertoken_gap_s", "n_finished", "decode_ticks",
                "prefill_ticks"):
        assert key in m, key
    assert 0.0 <= m["busy_slot_fraction"] <= 1.0
    assert m["latency_s"]["p50"] <= m["latency_s"]["p95"] + 1e-9
    assert m["ttft_s"]["p50"] <= m["ttft_s"]["p95"] + 1e-9
    assert m["tokens_out"] > 0 and m["n_finished"] >= 3
