"""Substrate tests: optimizer, checkpointing (incl. elastic + atomicity +
resume), data pipeline, fault tolerance, continuous batching."""

import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, io as ckpt_io
from repro.configs import get_reduced
from repro.data import PrefetchLoader, SyntheticLM
from repro.ft import Coordinator, HangDetector, StepWatchdog, plan_mesh_after_failure
from repro.models.lm import LM
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import warmup_cosine
from repro.runtime.batching import ContinuousBatcher, Request
from repro.runtime.train import make_train_step


# --------------------------------------------------------------------------- #
class TestAdamW:
    def test_quadratic_convergence(self):
        """AdamW minimises a quadratic (the from-scratch optimizer works)."""
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
        state = adamw.init(params, cfg)
        loss = lambda p: jnp.sum((p["w"] - target) ** 2)
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.update(g, state, params, cfg)
        assert float(loss(params)) < 1e-3

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros(4)}
        cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        state = adamw.init(params, cfg)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, m = adamw.update(g, state, params, cfg)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_master_fp32_with_bf16_params(self):
        params = {"w": jnp.ones(8, jnp.bfloat16)}
        cfg = AdamWConfig(lr=1e-4, master_fp32=True)
        state = adamw.init(params, cfg)
        assert state["master"]["w"].dtype == jnp.float32
        g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
        p2, s2, _ = adamw.update(g, state, params, cfg)
        assert p2["w"].dtype == jnp.bfloat16
        # tiny updates accumulate in the master even when bf16 can't see them
        for _ in range(3):
            p2, s2, _ = adamw.update(g, s2, p2, cfg)
        assert not np.array_equal(np.asarray(s2["master"]["w"]),
                                  np.asarray(state["master"]["w"]))

    def test_schedule(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


# --------------------------------------------------------------------------- #
class TestTrainLoop:
    def test_loss_decreases_on_synthetic(self):
        cfg = get_reduced("phi3-mini-3.8b")
        model = LM(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
        state = adamw.init(params, opt_cfg)
        step = make_train_step(model, cfg, opt_cfg, donate=False)
        ds = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8, seed=0)
        losses = []
        for i in range(30):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i % 4).items()}
            params, state, metrics = step(params, state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[::10]
        assert np.isfinite(losses).all()


# --------------------------------------------------------------------------- #
class TestCheckpoint:
    def _state(self, key=0):
        k = jax.random.PRNGKey(key)
        return {"params": {"w": jax.random.normal(k, (4, 8)),
                           "stack": [jnp.ones((2, 3)), jnp.zeros((5,))]},
                "step": jnp.asarray(7)}

    def test_roundtrip(self, tmp_path):
        state = self._state()
        ckpt_io.save(str(tmp_path), 7, state)
        target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                              state)
        restored = ckpt_io.restore(str(tmp_path), target)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), state, restored)

    def test_atomicity_tmp_dir_ignored(self, tmp_path):
        state = self._state()
        ckpt_io.save(str(tmp_path), 1, state)
        # simulate a crash mid-save of step 2: stray .tmp dir
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert ckpt_io.list_steps(str(tmp_path)) == [1]

    def test_manager_rotation_and_resume(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval=2, keep=2,
                                async_save=False)
        state = self._state()
        for step in range(1, 9):
            mgr.maybe_save(step, state, {"loss": 1.0 / step})
        assert mgr.latest_step() == 8
        assert len(ckpt_io.list_steps(str(tmp_path))) == 2  # rotated
        meta = ckpt_io.restore_metadata(str(tmp_path))
        assert meta["step"] == 8

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), interval=1, async_save=True)
        mgr.save(3, self._state())
        mgr.wait()
        assert mgr.latest_step() == 3

    @pytest.mark.skipif(
        not hasattr(jax.sharding, "AxisType"),
        reason="needs jax.sharding.AxisType (explicit-sharding mesh API); "
               "this jax predates it")
    def test_elastic_restore_different_sharding(self, tmp_path):
        """Checkpoint written 'on one mesh', restored with explicit new
        shardings (single-device here; the reshard path is device_put)."""
        state = self._state()
        ckpt_io.save(str(tmp_path), 1, state)
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                              state)
        restored = ckpt_io.restore(str(tmp_path), target, shardings=sh)
        assert restored["params"]["w"].sharding == NamedSharding(mesh, P())

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt_io.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            ckpt_io.restore(str(tmp_path),
                            {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})

    def test_train_resume_bitexact(self, tmp_path):
        """Crash/restart: resumed run reproduces the uninterrupted run."""
        cfg = get_reduced("minitron-4b")
        model = LM(cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        ds = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4, seed=1)
        step_fn = make_train_step(model, cfg, opt_cfg, donate=False)

        def run(n_steps, params, state, start=0):
            for i in range(start, n_steps):
                batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
                params, state, _ = step_fn(params, state, batch)
            return params, state

        p0 = model.init_params(jax.random.PRNGKey(0))
        s0 = adamw.init(p0, opt_cfg)
        p_full, _ = run(6, p0, s0)

        p_half, s_half = run(3, p0, s0)
        ckpt_io.save(str(tmp_path), 3, {"params": p_half, "opt": s_half})
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": p_half, "opt": s_half})
        rest = ckpt_io.restore(str(tmp_path), target)
        p_res, _ = run(6, rest["params"], rest["opt"], start=3)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6),
            p_full, p_res)


# --------------------------------------------------------------------------- #
class TestData:
    def test_prefetch_loader_orders_steps(self):
        ds = SyntheticLM(vocab=101, seq_len=8, batch=2, seed=5)
        loader = PrefetchLoader(ds.batch_at, prefetch=2)
        steps = []
        for _ in range(5):
            step, batch = next(loader)
            steps.append(step)
            np.testing.assert_array_equal(batch["tokens"],
                                          ds.batch_at(step)["tokens"])
        loader.close()
        assert steps == [0, 1, 2, 3, 4]


# --------------------------------------------------------------------------- #
class TestFaultTolerance:
    def test_watchdog_flags_straggler(self):
        wd = StepWatchdog(window=20, threshold=2.0)
        for i in range(10):
            wd.start()
            time.sleep(0.004)
            assert not wd.stop()
        wd.start()
        time.sleep(0.05)
        assert wd.stop() is True
        assert wd.stragglers == [11]

    def test_hang_detector_fires(self):
        fired = []
        with HangDetector(0.02, lambda: fired.append(1)):
            time.sleep(0.08)
        assert fired
        with HangDetector(1.0, lambda: fired.append(2)):
            pass
        assert fired == [1]

    def test_coordinator_membership(self):
        c = Coordinator(deadline=0.05)
        c.register("host0")
        c.register("host1")
        gen0 = c.generation
        for _ in range(3):
            c.heartbeat("host0")
            time.sleep(0.02)
        dead = c.sweep()
        assert dead == ["host1"]
        assert c.alive() == ["host0"]
        assert c.generation > gen0

    def test_elastic_mesh_plan(self):
        assert plan_mesh_after_failure(512) == ((32, 16), ("data", "model"))
        assert plan_mesh_after_failure(496) == ((31, 16), ("data", "model"))
        assert plan_mesh_after_failure(8) is None


# --------------------------------------------------------------------------- #
class TestContinuousBatching:
    def test_outputs_match_unbatched_and_slots_reused(self):
        cfg = get_reduced("phi3-mini-3.8b")
        model = LM(cfg)
        params = model.init_params(jax.random.PRNGKey(3))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(2, cfg.vocab, size=rng.integers(3, 7))
                   .astype(np.int32) for _ in range(5)]

        # unbatched greedy reference
        def greedy(prompt, n):
            toks = jnp.asarray(prompt)[None]
            lg, caches, lengths = model.prefill(params, {"tokens": toks},
                                                cache_cap=32)
            out = [int(jnp.argmax(lg[0]))]
            for _ in range(n - 1):
                lg, caches = model.decode_step(
                    params, jnp.asarray([out[-1]]), caches, lengths)
                lengths = lengths + 1
                out.append(int(jnp.argmax(lg[0])))
            return out

        batcher = ContinuousBatcher(model, params, n_slots=2, cache_cap=32,
                                    eos_id=-1)  # no eos: run to max tokens
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            batcher.submit(r)
        batcher.run(max_steps=100)
        assert all(r.done for r in reqs)
        # 5 requests through 2 slots => slots were reused
        assert batcher.utilisation > 0.5
        for r in reqs:
            assert r.out_tokens[:4] == greedy(r.prompt, 4), f"req {r.uid}"
