"""Fault injection against the self-healing serving engine — the unified
engine fault matrix.

Every cache variant of the engine (``conftest.ENGINE_VARIANTS``: dense,
paged-fp32, paged-int8, speculative; TP=2 via subprocess) is killed
mid-burst — an injected Program exception ("crash") or an injected
overrun of the hang deadline ("hang") at randomized tick indices — and
recovery must be invisible in the output:

* no request lost: every submitted request still reaches ``done``;
* no token duplicated or skipped: the per-token streaming callbacks see
  exactly the tokens of an uninterrupted run, in order;
* token-identical: greedy output after recovery equals the uninterrupted
  same-variant run's (and the fp32 variants equal the unbatched
  reference);
* the block pool passes ``check_integrity`` after every recovery (the
  failed tick's recorded-but-never-written rows must not survive);
* page-level resume (ISSUE 10): a recovered request fast-forwards past
  every row that survived the failure — KV pages for the paged engines,
  committed cache rows for the dense engine — re-executing ONLY the
  failed tick, with deterministic prefill-tick counts to prove it;
* the ft/ coordinator sees the restart as a membership event.

The randomized crash/hang tick indices derive from the ``fault_seed``
fixture, which CI's fault-matrix job rotates per run.
"""

import time

import numpy as np
import pytest
from conftest import TINY_LM, engine_variants, make_engine, run_sub

from repro.ft.coordinator import Coordinator
from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import Engine, EngineRequest, TickFailure

TINY = GraphLMConfig(**TINY_LM)

N_REQS = 6
MAX_NEW = 6


def _prompts():
    rng = np.random.default_rng(42)
    # one shared head so the paged runs exercise prefix reuse + CoW under
    # recovery, not just private pages
    head = rng.integers(0, TINY.vocab, size=6).astype(np.int32)
    out = []
    for i in range(N_REQS):
        tail = rng.integers(0, TINY.vocab,
                            size=int(rng.integers(2, 9))).astype(np.int32)
        out.append(np.concatenate([head, tail]) if i % 2 else tail)
    return out


PROMPTS = _prompts()


def _submit_all(engine):
    """Submit the standard burst; returns (requests, per-request streamed
    token capture)."""
    reqs, streams = [], []
    for i, p in enumerate(PROMPTS):
        toks = []
        req = EngineRequest(uid=i, prompt=p, max_new_tokens=MAX_NEW,
                            on_token=lambda r, t, toks=toks: toks.append(t))
        assert engine.submit(req)
        reqs.append(req)
        streams.append(toks)
    return reqs, streams


# every phase a stepper may expose; injection wraps the ones present, so
# one helper serves the plain AND the speculative steppers
ALL_PHASES = ("decode", "prefill", "draft_prefill", "draft", "verify")


def _inject_crash(stepper, fail_calls, phases=None):
    """Wrap the stepper's step functions: the Nth guarded call (counting
    across every wrapped phase) raises for N in ``fail_calls``."""
    calls = [0]
    for phase in phases or [p for p in ALL_PHASES if hasattr(stepper, p)]:
        orig = getattr(stepper, phase)

        def wrapped(*args, _orig=orig):
            calls[0] += 1
            if calls[0] in fail_calls:
                raise RuntimeError(f"injected fault at call {calls[0]}")
            return _orig(*args)

        setattr(stepper, phase, wrapped)
    return calls


def _inject_hang(stepper, hang_calls, sleep_s, phases=None):
    calls = [0]
    for phase in phases or [p for p in ALL_PHASES if hasattr(stepper, p)]:
        orig = getattr(stepper, phase)

        def wrapped(*args, _orig=orig):
            calls[0] += 1
            out = _orig(*args)
            if calls[0] in hang_calls:
                time.sleep(sleep_s)     # overrun the deadline, then return
            return out

        setattr(stepper, phase, wrapped)
    return calls


def _random_fail_calls(seed, n=3, lo=2, hi=16):
    # the uninterrupted burst makes ~19 guarded calls on the slowest
    # variant; stay under that so every sampled index actually fires
    # whatever the seed
    rng = np.random.default_rng(seed)
    return set(int(c) for c in rng.choice(np.arange(lo, hi), size=n,
                                          replace=False))


# one uninterrupted run per variant: the token-identity oracle.  The
# fp32 variants are additionally pinned to the unbatched reference; the
# int8 variant's oracle is its own clean run (int8 dequant noise may
# legitimately diverge from fp32 — the bounded-error contract lives in
# test_kv8_serving.py).
_ORACLES = {}


def _oracle(variant):
    if variant not in _ORACLES:
        engine, ref = make_engine(variant)
        reqs, streams = _submit_all(engine)
        engine.run()
        for r, toks in zip(reqs, streams):
            assert r.done and toks == r.out_tokens
            if "int8" not in variant:
                assert r.out_tokens == ref.generate(r.prompt, MAX_NEW,
                                                    chunk=4)
        _ORACLES[variant] = {r.uid: list(r.out_tokens) for r in reqs}
    return _ORACLES[variant]


def _check_identical(reqs, streams, outputs):
    for r, toks in zip(reqs, streams):
        assert r.done, (r.uid, r.dropped)
        assert r.out_tokens == outputs[r.uid], (
            f"request {r.uid} diverged after recovery: "
            f"{r.out_tokens} vs {outputs[r.uid]}")
        assert toks == r.out_tokens, (
            f"request {r.uid}: streaming callback saw {toks}, "
            f"request holds {r.out_tokens} (dup or skip)")


def _check_pool_clean(engine):
    if not engine.paged:
        return
    engine.stepper.pool.check_integrity()
    # recovery must not leak sequences: every request finished, so no
    # live sequences remain and reservations are all returned
    assert engine.stepper.pool.live_sequences == 0
    assert engine.stepper.pool.stats()["reserved_blocks"] == 0


# --------------------------------------------------------------------------- #
# the matrix: crash + hang recovery on every in-process variant
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("dense", "paged-fp32",
                                         "paged-int8", "spec"))
@pytest.mark.parametrize("seed", [0, 1])
def test_crash_recovery_token_identical(variant, engine_kw, seed,
                                        fault_seed):
    outputs = _oracle(variant)
    engine, _ = make_engine(variant, self_heal=True)
    reqs, streams = _submit_all(engine)
    _inject_crash(engine.stepper, _random_fail_calls(1000 * fault_seed + seed))
    engine.run()
    assert engine.metrics.n_recoveries >= 1
    assert engine.metrics.n_crash_failures == engine.metrics.failed_ticks
    assert engine.metrics.requeued_requests >= 1
    _check_identical(reqs, streams, outputs)
    assert sum(r.n_requeues for r in reqs) == engine.metrics.requeued_requests
    engine.sched.check_conservation()
    _check_pool_clean(engine)


@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("dense", "paged-fp32",
                                         "paged-int8", "spec"))
def test_hang_recovery_token_identical(variant, engine_kw):
    outputs = _oracle(variant)
    engine, _ = make_engine(variant, self_heal=True, hang_timeout=0.25)
    reqs, streams = _submit_all(engine)
    _inject_hang(engine.stepper, {3, 9}, sleep_s=0.6)
    engine.run()
    assert engine.metrics.n_hang_failures >= 2
    assert engine.metrics.n_recoveries >= 2
    _check_identical(reqs, streams, outputs)
    engine.sched.check_conservation()
    _check_pool_clean(engine)


# --------------------------------------------------------------------------- #
# page-level resume: deterministic tick counts (the tentpole bar)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("dense", "paged-fp32",
                                         "paged-int8"))
def test_page_level_resume_skips_committed_rows(variant, engine_kw):
    """A recovered request re-executes ZERO prefill ticks for rows that
    survived the failure.  One request, 16-token prompt, chunk 4: clean
    run prefills in 4 ticks; crash the second decode call and the resume
    prefill must cost exactly ONE more tick (the failed tick's token
    position) — not the 5 a cold re-prefill of the 17 committed rows
    would take — with ``recovered_rows`` accounting for the fast-forward
    row for row."""
    rng = np.random.default_rng(11)
    prompt = rng.integers(2, TINY.vocab, size=16).astype(np.int32)

    def run(inject):
        engine, _ = make_engine(variant, self_heal=True)
        req = EngineRequest(uid=0, prompt=prompt, max_new_tokens=6)
        if inject:
            _inject_crash(engine.stepper, {2}, phases=("decode",))
        assert engine.submit(req)
        engine.run()
        assert req.done and req.dropped is None
        return engine, req

    base_engine, base_req = run(inject=False)
    # the controlled crash point assumes the clean run decodes past call
    # 2 (no early EOS) — pin that so a model change can't silence this
    assert len(base_req.out_tokens) >= 3
    cold_prefill = base_engine.metrics.prefill_ticks
    assert cold_prefill == 4                       # ceil(16 / chunk=4)
    rec_engine, rec_req = run(inject=True)
    assert rec_engine.metrics.n_recoveries == 1
    assert rec_req.out_tokens == base_req.out_tokens
    # prompt rows + the one committed decode row all survived ...
    assert rec_engine.metrics.recovered_rows == len(prompt) + 1
    # ... so resume re-executes exactly one prefill tick, not ceil(17/4)
    assert rec_engine.metrics.prefill_ticks == cold_prefill + 1
    _check_pool_clean(rec_engine)


@pytest.mark.parametrize("variant,engine_kw",
                         engine_variants("paged-fp32", "paged-int8"))
def test_page_level_resume_burst_never_reprefills(variant, engine_kw,
                                                  fault_seed):
    """Burst-level version of the tick-count bar: with every injected
    failure landing in decode, the recovered run's TOTAL prefill ticks
    exceed the clean run's by at most one resume tick per requeue —
    impossible under whole-stream re-prefill of multi-chunk streams."""
    outputs = _oracle(variant)
    clean_engine, _ = make_engine(variant)
    reqs, streams = _submit_all(clean_engine)
    clean_engine.run()
    clean_prefill = clean_engine.metrics.prefill_ticks

    engine, _ = make_engine(variant, self_heal=True)
    reqs, streams = _submit_all(engine)
    _inject_crash(engine.stepper,
                  _random_fail_calls(3000 + fault_seed, lo=8, hi=16),
                  phases=("decode",))
    engine.run()
    assert engine.metrics.n_recoveries >= 1
    _check_identical(reqs, streams, outputs)
    assert engine.metrics.recovered_rows > 0
    assert (engine.metrics.prefill_ticks
            <= clean_prefill + engine.metrics.requeued_requests)
    engine.sched.check_conservation()
    _check_pool_clean(engine)


# --------------------------------------------------------------------------- #
# TP=2: the same matrix bars under tensor parallelism (subprocess)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("variant,engine_kw", engine_variants("tp2"))
def test_tp2_crash_recovery_and_page_level_resume(variant, engine_kw):
    """The tp2 matrix column: crash recovery stays token-identical to
    the single-device clean run AND resumes from surviving pages (the
    sharded caches are slot/block-indexed on axis 0 exactly like the
    single-device ones, so the id-level resume bookkeeping carries
    over unchanged)."""
    run_sub("""
import numpy as np, jax
import repro
from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import EngineRequest, build_lm_serving

assert len(jax.devices()) == 8, jax.devices()
TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)
rng = np.random.default_rng(42)
head = rng.integers(0, 61, size=6).astype(np.int32)
prompts = []
for i in range(6):
    tail = rng.integers(0, 61, size=int(rng.integers(2, 9))).astype(np.int32)
    prompts.append(np.concatenate([head, tail]) if i % 2 else tail)

def run(tp, inject):
    engine, _ = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                                 paged=True, self_heal=True, tp=tp)
    rs = []
    for i, p in enumerate(prompts):
        r = EngineRequest(uid=i, prompt=p, max_new_tokens=6)
        assert engine.submit(r); rs.append(r)
    if inject:
        calls = [0]
        for phase in ("decode", "prefill"):
            orig = getattr(engine.stepper, phase)
            def wrapped(*args, _orig=orig):
                calls[0] += 1
                if calls[0] in (9, 13):
                    raise RuntimeError("injected fault")
                return _orig(*args)
            setattr(engine.stepper, phase, wrapped)
    engine.run()
    assert all(r.done and r.dropped is None for r in rs)
    if inject:
        assert engine.metrics.n_recoveries >= 1
        # resume came from surviving pages, not a cold re-prefill
        assert engine.metrics.recovered_rows > 0, "page-level resume idle"
    engine.stepper.pool.check_integrity()
    assert engine.stepper.pool.live_sequences == 0
    return [tuple(r.out_tokens) for r in rs]

base = run(None, False)
assert run(2, False) == base, "tp clean run differs"
assert run(2, True) == base, "tp recovery run differs"
print("OK")
""")


# --------------------------------------------------------------------------- #
# scheduler/recovery interactions (variant-independent)
# --------------------------------------------------------------------------- #

def test_int8_weights_compose_with_recovery():
    """kv_dtype="int8" pages + quantize="int8" weight Programs through
    recovery: the restored pool bookkeeping must stay bit-consistent
    with the int8 device pages AND their scale sidecars — compared
    against an uninterrupted run of the same stack."""
    def run(inject):
        engine, _ = make_engine("paged-int8", quantize="int8",
                                self_heal=inject)
        reqs, streams = _submit_all(engine)
        if inject:
            _inject_crash(engine.stepper, _random_fail_calls(7))
        engine.run()
        for r, toks in zip(reqs, streams):
            assert r.done and toks == r.out_tokens
        if inject:
            assert engine.metrics.n_recoveries >= 1
            engine.stepper.pool.check_integrity()
        return {r.uid: list(r.out_tokens) for r in reqs}

    assert run(inject=False) == run(inject=True)


def test_recovery_requeue_never_sheds_admitted_requests():
    """Bounded-queue interaction with recovery (ISSUE 8 audit):
    ``_recover()`` requeues every in-flight request via
    ``SlotScheduler.preempt()``, which pushes straight into the heap and
    deliberately does NOT apply ``max_queue`` — admission control is for
    NEW work only, and a request the engine already accepted must never
    bounce off its own recovery.  Run a burst larger than ``max_queue``
    with crashes timed so slots are busy and the queue is full at
    recovery: everything admitted still finishes, nothing is rejected
    after submit time, and conservation holds."""
    outputs = _oracle("dense")
    engine, _ = make_engine("dense", n_slots=2, self_heal=True, max_queue=2)
    reqs, streams = [], []
    for i, p in enumerate(PROMPTS):
        toks = []
        req = EngineRequest(uid=i, prompt=p, max_new_tokens=MAX_NEW,
                            on_token=lambda r, t, toks=toks: toks.append(t))
        if engine.submit(req):
            reqs.append(req)
            streams.append(toks)
        else:
            # over-bound submits reject immediately at SUBMIT time — the
            # only place admission control is allowed to bite
            assert req.dropped == "queue_full"
        if i == 1:
            # drain the first two into slots so the next two fill the
            # queue again: busy slots + full queue at crash time is the
            # worst case for the preempt() requeue
            engine.step()
    rejected0 = engine.metrics.n_rejected
    assert rejected0 >= 1, "burst did not exceed max_queue"
    assert len(reqs) >= 4
    assert engine.sched.queue_len == 2 and engine.sched.busy_slots == 2
    # crash early ticks: 2 busy slots + a full queue get preempt()ed
    _inject_crash(engine.stepper, {2, 4, 7}, phases=("decode", "prefill"))
    engine.run()
    assert engine.metrics.n_recoveries >= 1
    assert engine.metrics.requeued_requests >= 1
    # recovery never sheds admitted work: the rejected count is frozen at
    # its submit-time value and every admitted request finishes intact
    assert engine.metrics.n_rejected == rejected0
    _check_identical(reqs, streams, outputs)
    engine.sched.check_conservation()


def test_recovery_is_a_membership_event():
    outputs = _oracle("dense")
    engine, _ = make_engine("dense")
    coord = Coordinator(deadline=60.0)
    engine = Engine(engine.stepper, self_heal=True, coordinator=coord,
                    host_id="engine-0")
    gen0 = coord.generation
    assert coord.alive() == ["engine-0"]
    reqs, streams = _submit_all(engine)
    _inject_crash(engine.stepper, {4}, phases=("decode", "prefill"))
    engine.run()
    assert engine.metrics.n_recoveries == 1
    # the re-registration after recovery bumps the membership generation
    assert coord.generation > gen0
    assert coord.alive() == ["engine-0"]
    _check_identical(reqs, streams, outputs)


def test_gives_up_after_max_recoveries():
    engine, _ = make_engine("dense", self_heal=True, max_recoveries=3)
    _submit_all(engine)
    _inject_crash(engine.stepper, set(range(1, 10_000)))   # every tick fails
    with pytest.raises(TickFailure, match="giving up"):
        engine.run()
    assert engine.metrics.n_recoveries == 3


def test_without_self_heal_faults_propagate():
    engine, _ = make_engine("dense")                # self_heal off
    _submit_all(engine)
    _inject_crash(engine.stepper, {2})
    with pytest.raises(RuntimeError, match="injected fault"):
        engine.run()
    assert engine.metrics.n_recoveries == 0
