"""Fault injection against the self-healing serving engine.

The bar (see runtime/engine.py "Self-healing"): kill the engine mid-burst
— an injected Program exception ("crash") or an injected overrun of the
hang deadline ("hang") at randomized tick indices — and recovery must be
invisible in the output:

* no request lost: every submitted request still reaches ``done``;
* no token duplicated or skipped: the per-token streaming callbacks see
  exactly the tokens of an uninterrupted run, in order;
* token-identical: greedy output after recovery equals the uninterrupted
  run's, for the dense engine AND the paged engine (fp32 and int8 KV);
* the block pool passes ``check_integrity`` after every recovery (the
  failed tick's recorded-but-never-written rows must not survive);
* the ft/ coordinator sees the restart as a membership event.
"""

import time

import numpy as np
import pytest

from repro.ft.coordinator import Coordinator
from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import (Engine, EngineRequest, TickFailure,
                                  build_lm_serving)

TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)

N_REQS = 6
MAX_NEW = 6


def _prompts():
    rng = np.random.default_rng(42)
    # one shared head so the paged runs exercise prefix reuse + CoW under
    # recovery, not just private pages
    head = rng.integers(0, TINY.vocab, size=6).astype(np.int32)
    out = []
    for i in range(N_REQS):
        tail = rng.integers(0, TINY.vocab,
                            size=int(rng.integers(2, 9))).astype(np.int32)
        out.append(np.concatenate([head, tail]) if i % 2 else tail)
    return out


PROMPTS = _prompts()


def _submit_all(engine):
    """Submit the standard burst; returns (requests, per-request streamed
    token capture)."""
    reqs, streams = [], []
    for i, p in enumerate(PROMPTS):
        toks = []
        req = EngineRequest(uid=i, prompt=p, max_new_tokens=MAX_NEW,
                            on_token=lambda r, t, toks=toks: toks.append(t))
        assert engine.submit(req)
        reqs.append(req)
        streams.append(toks)
    return reqs, streams


def _inject_crash(stepper, fail_calls, phases=("decode", "prefill")):
    """Wrap the stepper's step functions: the Nth guarded call (counting
    across both phases) raises for N in ``fail_calls``."""
    calls = [0]
    for phase in phases:
        orig = getattr(stepper, phase)

        def wrapped(*args, _orig=orig):
            calls[0] += 1
            if calls[0] in fail_calls:
                raise RuntimeError(f"injected fault at call {calls[0]}")
            return _orig(*args)

        setattr(stepper, phase, wrapped)
    return calls


def _inject_hang(stepper, hang_calls, sleep_s):
    calls = [0]
    for phase in ("decode", "prefill"):
        orig = getattr(stepper, phase)

        def wrapped(*args, _orig=orig):
            calls[0] += 1
            out = _orig(*args)
            if calls[0] in hang_calls:
                time.sleep(sleep_s)     # overrun the deadline, then return
            return out

        setattr(stepper, phase, wrapped)
    return calls


def _random_fail_calls(seed, n=3, lo=2, hi=16):
    # the uninterrupted burst makes ~19 guarded calls; stay under that so
    # every sampled index actually fires whatever the seed
    rng = np.random.default_rng(seed)
    return set(int(c) for c in rng.choice(np.arange(lo, hi), size=n,
                                          replace=False))


@pytest.fixture(scope="module")
def baseline():
    """Uninterrupted dense run: the token-identity oracle for every
    fp32 recovery scenario (dense==paged exactness is pinned elsewhere)."""
    engine, ref = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48)
    reqs, streams = _submit_all(engine)
    engine.run()
    outputs = {}
    for r, toks in zip(reqs, streams):
        assert r.done and toks == r.out_tokens
        assert r.out_tokens == ref.generate(r.prompt, MAX_NEW, chunk=4)
        outputs[r.uid] = list(r.out_tokens)
    return engine.stepper, outputs


def _check_identical(reqs, streams, outputs):
    for r, toks in zip(reqs, streams):
        assert r.done, (r.uid, r.dropped)
        assert r.out_tokens == outputs[r.uid], (
            f"request {r.uid} diverged after recovery: "
            f"{r.out_tokens} vs {outputs[r.uid]}")
        assert toks == r.out_tokens, (
            f"request {r.uid}: streaming callback saw {toks}, "
            f"request holds {r.out_tokens} (dup or skip)")


@pytest.mark.parametrize("seed", [0, 1])
def test_dense_crash_recovery_token_identical(baseline, seed):
    stepper, outputs = baseline
    engine = Engine(stepper, self_heal=True)    # fresh engine, same Programs
    reqs, streams = _submit_all(engine)
    _inject_crash(engine.stepper, _random_fail_calls(seed))
    engine.run()
    assert engine.metrics.n_recoveries >= 1
    assert engine.metrics.n_crash_failures == engine.metrics.failed_ticks
    assert engine.metrics.requeued_requests >= 1
    _check_identical(reqs, streams, outputs)
    assert sum(r.n_requeues for r in reqs) == engine.metrics.requeued_requests
    engine.sched.check_conservation()


@pytest.mark.parametrize("seed", [0, 3])
def test_paged_crash_recovery_token_identical(baseline, seed):
    _, outputs = baseline
    engine, _ = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                                 paged=True, self_heal=True)
    reqs, streams = _submit_all(engine)
    _inject_crash(engine.stepper, _random_fail_calls(seed + 10))
    engine.run()
    assert engine.metrics.n_recoveries >= 1
    _check_identical(reqs, streams, outputs)
    engine.stepper.pool.check_integrity()
    engine.sched.check_conservation()
    # recovery must not leak sequences: every request finished, so no live
    # sequences remain and reservations are all returned
    assert engine.stepper.pool.live_sequences == 0
    assert engine.stepper.pool.stats()["reserved_blocks"] == 0


def test_paged_hang_recovery_token_identical(baseline):
    _, outputs = baseline
    engine, _ = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                                 paged=True, self_heal=True,
                                 hang_timeout=0.25)
    reqs, streams = _submit_all(engine)
    _inject_hang(engine.stepper, {3, 9}, sleep_s=0.6)
    engine.run()
    assert engine.metrics.n_hang_failures >= 2
    assert engine.metrics.n_recoveries >= 2
    _check_identical(reqs, streams, outputs)
    engine.stepper.pool.check_integrity()


def test_int8_kv_crash_recovery_token_identical():
    """Quantized KV pages through recovery: the restored pool bookkeeping
    must stay bit-consistent with the int8 device pages AND their scale
    sidecars — compared against an uninterrupted int8 run."""
    def run(inject):
        engine, _ = build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                                     paged=True, kv_dtype="int8",
                                     quantize="int8", self_heal=inject)
        reqs, streams = _submit_all(engine)
        if inject:
            _inject_crash(engine.stepper, _random_fail_calls(7))
        engine.run()
        for r, toks in zip(reqs, streams):
            assert r.done and toks == r.out_tokens
        if inject:
            assert engine.metrics.n_recoveries >= 1
            engine.stepper.pool.check_integrity()
        return {r.uid: list(r.out_tokens) for r in reqs}

    assert run(inject=False) == run(inject=True)


def test_recovery_requeue_never_sheds_admitted_requests(baseline):
    """Bounded-queue interaction with recovery (ISSUE 8 audit):
    ``_recover()`` requeues every in-flight request via
    ``SlotScheduler.preempt()``, which pushes straight into the heap and
    deliberately does NOT apply ``max_queue`` — admission control is for
    NEW work only, and a request the engine already accepted must never
    bounce off its own recovery.  Run a burst larger than ``max_queue``
    with crashes timed so slots are busy and the queue is full at
    recovery: everything admitted still finishes, nothing is rejected
    after submit time, and conservation holds."""
    _, outputs = baseline
    engine, _ = build_lm_serving(TINY, n_slots=2, chunk=4, cache_cap=48,
                                 self_heal=True, max_queue=2)
    reqs, streams = [], []
    for i, p in enumerate(PROMPTS):
        toks = []
        req = EngineRequest(uid=i, prompt=p, max_new_tokens=MAX_NEW,
                            on_token=lambda r, t, toks=toks: toks.append(t))
        if engine.submit(req):
            reqs.append(req)
            streams.append(toks)
        else:
            # over-bound submits reject immediately at SUBMIT time — the
            # only place admission control is allowed to bite
            assert req.dropped == "queue_full"
        if i == 1:
            # drain the first two into slots so the next two fill the
            # queue again: busy slots + full queue at crash time is the
            # worst case for the preempt() requeue
            engine.step()
    rejected0 = engine.metrics.n_rejected
    assert rejected0 >= 1, "burst did not exceed max_queue"
    assert len(reqs) >= 4
    assert engine.sched.queue_len == 2 and engine.sched.busy_slots == 2
    # crash early ticks: 2 busy slots + a full queue get preempt()ed
    _inject_crash(engine.stepper, {2, 4, 7})
    engine.run()
    assert engine.metrics.n_recoveries >= 1
    assert engine.metrics.requeued_requests >= 1
    # recovery never sheds admitted work: the rejected count is frozen at
    # its submit-time value and every admitted request finishes intact
    assert engine.metrics.n_rejected == rejected0
    _check_identical(reqs, streams, outputs)
    engine.sched.check_conservation()


def test_recovery_is_a_membership_event(baseline):
    stepper, outputs = baseline
    coord = Coordinator(deadline=60.0)
    engine = Engine(stepper, self_heal=True, coordinator=coord,
                    host_id="engine-0")
    gen0 = coord.generation
    assert coord.alive() == ["engine-0"]
    reqs, streams = _submit_all(engine)
    _inject_crash(engine.stepper, {4})
    engine.run()
    assert engine.metrics.n_recoveries == 1
    # the re-registration after recovery bumps the membership generation
    assert coord.generation > gen0
    assert coord.alive() == ["engine-0"]
    _check_identical(reqs, streams, outputs)


def test_gives_up_after_max_recoveries(baseline):
    stepper, _ = baseline
    engine = Engine(stepper, self_heal=True, max_recoveries=3)
    reqs, _ = _submit_all(engine)
    _inject_crash(engine.stepper, set(range(1, 10_000)))   # every tick fails
    with pytest.raises(TickFailure, match="giving up"):
        engine.run()
    assert engine.metrics.n_recoveries == 3


def test_without_self_heal_faults_propagate(baseline):
    stepper, _ = baseline
    engine = Engine(stepper)                     # self_heal off
    _submit_all(engine)
    _inject_crash(engine.stepper, {2})
    with pytest.raises(RuntimeError, match="injected fault"):
        engine.run()
    assert engine.metrics.n_recoveries == 0
