"""Pipeline parallelism + MoE dispatch-mode tests."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models.lm import LM
from tests.test_sharding_multidev import multidev, run_sub


class TestMoEDispatchModes:
    def _loss(self, cfg, toks):
        model = LM(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        loss, _ = model.train_loss(params, {"tokens": toks, "labels": toks},
                                   remat=False)
        return float(loss), model, params

    @pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "deepseek-v2-lite-16b"])
    def test_local_equals_global_when_no_drops(self, arch):
        cfg = get_reduced(arch)   # capacity_factor 8 -> no drops
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
        l_global, _, _ = self._loss(
            cfg.with_overrides(moe=dataclasses.replace(cfg.moe,
                                                       dispatch="global")),
            toks)
        l_local, _, _ = self._loss(
            cfg.with_overrides(moe=dataclasses.replace(cfg.moe,
                                                       dispatch="local")),
            toks)
        assert abs(l_global - l_local) < 1e-5

    def test_local_dispatch_grads_finite(self):
        cfg = get_reduced("qwen2-moe-a2.7b")
        cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe,
                                                         dispatch="local"))
        model = LM(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        g = jax.grad(lambda p: model.train_loss(
            p, {"tokens": toks, "labels": toks})[0])(params)
        gn = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g)))
        assert jnp.isfinite(gn) and gn > 0


class TestPipeline:
    @multidev
    def test_pipeline_matches_sequential(self):
        run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.runtime.pipeline import pipeline_apply
mesh = jax.make_mesh((4, 2), ("pod", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.default_rng(0)
n_stages, n_micro, mb, d = 4, 6, 2, 16
W = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)
stage = lambda w, h: jnp.tanh(h @ w)
with mesh:
    y = pipeline_apply(mesh, stage, W, x, axis="pod")
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ W[s])
assert float(jnp.abs(y - ref).max()) < 1e-5
print("OK")
""")

    @multidev
    def test_seq_shard_decode_matches_replicated(self):
        """The §Perf seq-shard cache fallback must be numerics-neutral."""
        run_sub("""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.configs.base import ShapeCfg
from repro.models.lm import LM
from repro.runtime.serve import make_decode_step
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get_reduced("stablelm-12b")
model = LM(cfg)
params = model.init_params(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
_, caches, lengths = model.prefill(params, {"tokens": toks}, cache_cap=32)
new_tok = jnp.asarray([3, 5], jnp.int32)
outs = {}
for label, fb in [("replicated", False), ("seqshard", True)]:
    with mesh:
        step = make_decode_step(model, cfg, mesh=mesh, batch=2, cache_cap=32,
                                seq_shard_fallback=fb, donate_cache=False)
        logits, _ = step(params, new_tok, caches, lengths)
    outs[label] = np.asarray(logits)
err = np.abs(outs["replicated"] - outs["seqshard"]).max()
assert err < 1e-4, err
print("OK", err)
""")
