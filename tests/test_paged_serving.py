"""Paged serving stack: op parity, engine exactness, block admission.

The exactness bar for the paged KV cache is strict: the paged engine's
greedy output must be TOKEN-IDENTICAL to the dense
:class:`~repro.runtime.engine.UnbatchedReference` for fp32 and int8
Programs, with and without prefix hits, including the copy-on-write
divergence path (concurrent requests sharing a cached partial tail
page).  Backend parity pins the paged ops (ref / xla / pallas-interpret)
against their dense equivalents on a scrambled physical block layout.
"""

import numpy as np
import pytest

import repro  # noqa: F401  (registers every op/backend)
from repro.core import backends_for
from repro.core.ir import TensorSpec
from repro.kernels.ops import decode_attention
from repro.kernels.serving_ops import (cache_update, chunk_attention,
                                       paged_cache_update,
                                       paged_chunk_attention,
                                       paged_decode_attention)
from repro.models.graph_lm import GraphLMConfig
from repro.runtime.engine import EngineRequest, build_lm_serving

TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)


def _rng():
    return np.random.default_rng(3)


def _paged_layout(rng, *, b=3, cap=16, hk=2, d=8, n_blocks=10, page=4,
                  lengths=(14, 9, 5)):
    """A dense cache plus the equivalent paged layout under a scrambled
    block mapping (so parity failures can't hide behind identity maps)."""
    perm = rng.permutation(n_blocks)
    mp = cap // page
    tables = np.zeros((b, mp), np.int32)
    used = iter(perm)
    dense_k = rng.standard_normal((b, cap, hk, d)).astype(np.float32)
    dense_v = rng.standard_normal((b, cap, hk, d)).astype(np.float32)
    pages_k = np.zeros((n_blocks, page, hk, d), np.float32)
    pages_v = np.zeros((n_blocks, page, hk, d), np.float32)
    lengths = np.asarray(lengths, np.int32)
    for bi in range(b):
        for pi in range(-(-int(lengths[bi]) // page)):
            blk = int(next(used))
            tables[bi, pi] = blk
            pages_k[blk] = dense_k[bi, pi * page:(pi + 1) * page]
            pages_v[blk] = dense_v[bi, pi * page:(pi + 1) * page]
    return dense_k, dense_v, pages_k, pages_v, tables, lengths


# --------------------------------------------------------------------------- #
# op parity vs the dense equivalents
# --------------------------------------------------------------------------- #

def test_paged_cache_update_matches_dense_rows():
    rng = _rng()
    dk, _, pk, _, tables, lengths = _paged_layout(rng)
    new = rng.standard_normal((3, 4, 2, 8)).astype(np.float32)
    start, n_new = lengths.copy(), np.asarray([2, 0, 3], np.int32)
    ref = np.asarray(paged_cache_update(pk, new, tables, start, n_new,
                                        backend="ref"))
    xla = np.asarray(paged_cache_update(pk, new, tables, start, n_new,
                                        backend="xla"))
    np.testing.assert_array_equal(ref, xla)
    dense = np.asarray(cache_update(dk, new, start, n_new, backend="ref"))
    for bi in range(3):
        for t in range(int(n_new[bi])):
            pos = int(start[bi]) + t
            np.testing.assert_array_equal(
                ref[tables[bi, pos // 4], pos % 4], dense[bi, pos])
    # idle slot's pages untouched
    np.testing.assert_array_equal(ref[tables[1, 0]], pk[tables[1, 0]])


@pytest.mark.parametrize("backend", ["ref", "xla", "pallas"])
def test_paged_decode_attention_parity(backend):
    rng = _rng()
    dk, dv, pk, pv, tables, lengths = _paged_layout(rng)
    q = rng.standard_normal((3, 4, 8)).astype(np.float32)
    want = np.asarray(decode_attention(q, dk, dv, lengths, backend="ref"))
    got = np.asarray(paged_decode_attention(q, pk, pv, tables, lengths,
                                            backend=backend, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("backend", ["ref", "xla"])
@pytest.mark.parametrize("scale", [None, 0.0])
def test_paged_chunk_attention_parity(backend, scale):
    rng = _rng()
    dk, dv, pk, pv, tables, _ = _paged_layout(rng)
    q = rng.standard_normal((3, 4, 4, 8)).astype(np.float32)
    start = np.asarray([10, 4, 1], np.int32)
    want = np.asarray(chunk_attention(q, dk, dv, start, scale=scale,
                                      backend="ref"))
    got = np.asarray(paged_chunk_attention(q, pk, pv, tables, start,
                                           scale=scale, backend=backend))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_paged_decode_pallas_supports_guard():
    qs = TensorSpec((1, 4, 8))
    tb = TensorSpec((1, 4), "int32")
    ln = TensorSpec((1,), "int32")
    ok = TensorSpec((8, 8, 2, 8))       # page 8 % 8 == 0
    bad = TensorSpec((8, 6, 2, 8))      # page 6 % 8 != 0
    assert "pallas" in backends_for("paged_decode_attention",
                                    [qs, ok, ok, tb, ln], {})
    avail = backends_for("paged_decode_attention", [qs, bad, bad, tb, ln], {})
    assert "pallas" not in avail and {"ref", "xla"} <= set(avail)


def test_dense_cache_update_ragged_final_chunk_parity():
    """start > cap - T with start + n_new <= cap (a ragged final chunk
    ending exactly at capacity): both backends must write rows at the true
    positions.  The ref backend used to clip padding rows onto cap-1 and
    corrupt it via a duplicate-index scatter."""
    rng = _rng()
    cache = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    new = rng.standard_normal((2, 4, 2, 8)).astype(np.float32)
    start = np.asarray([14, 13], np.int32)
    n_new = np.asarray([2, 3], np.int32)
    ref = np.asarray(cache_update(cache, new, start, n_new, backend="ref"))
    xla = np.asarray(cache_update(cache, new, start, n_new, backend="xla"))
    np.testing.assert_array_equal(ref, xla)
    np.testing.assert_array_equal(ref[0, 14:16], new[0, :2])
    np.testing.assert_array_equal(ref[1, 13:16], new[1, :3])
    np.testing.assert_array_equal(ref[0, :14], cache[0, :14])


# --------------------------------------------------------------------------- #
# engine end-to-end: paged vs dense reference
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def paged_fp32():
    return build_lm_serving(TINY, n_slots=3, chunk=4, cache_cap=48,
                            paged=True, page_size=8)


@pytest.fixture(scope="module")
def paged_int8():
    return build_lm_serving(TINY, n_slots=2, chunk=4, cache_cap=32,
                            paged=True, page_size=8, quantize="int8")


def _req(uid, rng, *, max_prompt=13, max_new=7):
    plen = int(rng.integers(1, max_prompt))
    return EngineRequest(uid=uid,
                         prompt=rng.integers(0, TINY.vocab,
                                             size=plen).astype(np.int32),
                         max_new_tokens=int(rng.integers(1, max_new)))


def _exact(engine, ref, reqs):
    for r in reqs:
        assert engine.submit(r), r.dropped
    engine.run(max_ticks=4000)
    for r in reqs:
        assert r.done and r.dropped is None, (r.uid, r.dropped)
        want = ref.generate(r.prompt, r.max_new_tokens)
        assert r.out_tokens == want, (r.uid, r.out_tokens, want)
    engine.sched.check_conservation()
    engine.stepper.pool.check_integrity()


def test_paged_engine_token_exact_fp32_cold(paged_fp32):
    engine, ref = paged_fp32
    rng = np.random.default_rng(11)
    _exact(engine, ref, [_req(i, rng) for i in range(7)])
    assert engine.stepper.pool.stats()["live_blocks"] == 0


def test_paged_engine_prefix_hit_exact_and_faster(paged_fp32):
    """A prompt whose prefix is cached must (a) register a hit, (b) stay
    token-exact, (c) finish prefill in fewer ticks than the cold run."""
    engine, ref = paged_fp32
    rng = np.random.default_rng(12)
    prefix = rng.integers(0, TINY.vocab, size=24).astype(np.int32)
    cold = EngineRequest(uid=100, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=3).astype(np.int32)]),
        max_new_tokens=5)
    assert engine.submit(cold)
    engine.run(max_ticks=500)
    cold_prefill_ticks = (cold.first_token_tick or 0) - cold.submit_tick
    hits_before = engine.stepper.pool.hit_tokens
    warm = EngineRequest(uid=101, prompt=np.concatenate(
        [prefix, rng.integers(0, TINY.vocab, size=2).astype(np.int32)]),
        max_new_tokens=5)
    assert engine.submit(warm)
    engine.run(max_ticks=500)
    assert engine.stepper.pool.hit_tokens - hits_before >= 24
    assert warm.out_tokens == ref.generate(warm.prompt, 5)
    warm_prefill_ticks = (warm.first_token_tick or 0) - warm.submit_tick
    assert warm_prefill_ticks < cold_prefill_ticks, \
        (warm_prefill_ticks, cold_prefill_ticks)


def test_paged_engine_cow_divergence_exact(paged_fp32):
    """Concurrent requests sharing a cached PARTIAL tail page: each one's
    first write into the shared page must copy-on-write, and every stream
    must stay token-exact."""
    engine, ref = paged_fp32
    rng = np.random.default_rng(13)
    pre = rng.integers(0, TINY.vocab, size=21).astype(np.int32)  # tail: 5 rows
    seed_req = EngineRequest(uid=200, prompt=pre, max_new_tokens=2)
    assert engine.submit(seed_req)
    engine.run(max_ticks=500)
    cow0 = engine.stepper.pool.cow_count
    reqs = [EngineRequest(uid=201 + i, prompt=np.concatenate(
        [pre, rng.integers(0, TINY.vocab, size=2 + i).astype(np.int32)]),
        max_new_tokens=4) for i in range(3)]
    _exact(engine, ref, reqs)
    assert engine.stepper.pool.cow_count > cow0, "CoW never fired"


def test_paged_engine_token_exact_int8(paged_int8):
    engine, ref = paged_int8
    from repro.core.quant import is_quantized
    assert is_quantized(engine.stepper.decode_program.graph)
    assert is_quantized(engine.stepper.prefill_program.graph)
    rng = np.random.default_rng(14)
    reqs = [_req(i, rng, max_prompt=11, max_new=5) for i in range(5)]
    _exact(engine, ref, reqs)
    # prefix hit under int8
    warm = EngineRequest(uid=50, prompt=np.concatenate(
        [reqs[0].prompt, reqs[0].prompt[:2]]), max_new_tokens=3)
    hits0 = engine.stepper.pool.hit_tokens
    _exact(engine, ref, [warm])
    assert engine.stepper.pool.hit_tokens >= hits0


def test_block_admission_defers_then_drains():
    """More worst-case demand than the pool holds: admission must wait on
    BLOCK availability (not slot count), then drain everything exactly."""
    engine, ref = build_lm_serving(TINY, n_slots=4, chunk=4, cache_cap=32,
                                   paged=True, page_size=8, n_blocks=5)
    rng = np.random.default_rng(15)
    # each request reserves pages_needed(8, 9) = 2 pages of 8, so only two
    # fit the 5-block pool at once: slots 3 and 4 sit free while admission
    # waits on blocks — the thing this test is about
    reqs = [EngineRequest(uid=i,
                          prompt=rng.integers(0, TINY.vocab, size=8)
                          .astype(np.int32),
                          max_new_tokens=9) for i in range(6)]
    for r in reqs:
        assert engine.submit(r)
    engine.step()
    assert 0 < engine.sched.busy_slots, "nothing admitted"
    engine.run(max_ticks=4000)
    for r in reqs:
        assert r.done and r.out_tokens == ref.generate(r.prompt, 9), r.uid
    assert engine.stepper.pool.n_admit_deferred > 0, \
        "admission was never block-limited"
    engine.stepper.pool.check_integrity()


def test_submit_rejects_what_can_never_fit():
    engine, _ = build_lm_serving(TINY, n_slots=2, chunk=4, cache_cap=32,
                                 paged=True, page_size=8, n_blocks=6)
    # per-sequence cap: 32 rows
    too_long = EngineRequest(uid=1, prompt=np.zeros(30, np.int32),
                             max_new_tokens=4)
    assert not engine.submit(too_long) and too_long.dropped == "too_long"
    # fits the table but not the whole pool? cap 32 = 4 pages <= 6 blocks,
    # so the boundary case is admissible
    edge = EngineRequest(uid=2, prompt=np.zeros(32, np.int32),
                         max_new_tokens=1)
    assert engine.submit(edge)
    engine.run(max_ticks=500)
    assert edge.done


# --------------------------------------------------------------------------- #
# selection plumbing: the paged ops are first-class registry citizens
# --------------------------------------------------------------------------- #

def test_paged_graph_compiles_under_cost_model_policy():
    from repro.core import CostModelPolicy, compile
    from repro.models.graph_lm import (build_paged_prefill_graph,
                                       init_lm_params)
    cfg = GraphLMConfig(vocab=37, d_model=16, n_layers=1, n_heads=4,
                        n_kv_heads=2, d_ff=32)
    params = init_lm_params(cfg, 0)
    g = build_paged_prefill_graph(cfg, params, batch=2, chunk=4,
                                  n_blocks=8, page_size=4, max_pages=4)
    prog = compile(g, policy=CostModelPolicy())
    ops = {n.op for n in prog.graph.nodes}
    assert {"paged_cache_update", "paged_chunk_attention"} <= ops
    rng = _rng()
    (logits, *_) = prog(
        tokens=rng.integers(0, 37, size=(2, 4)).astype(np.int32),
        start=np.zeros((2,), np.int32), n_new=np.full((2,), 4, np.int32),
        block_tables=np.asarray([[0, 1, 0, 0], [2, 3, 0, 0]], np.int32),
        cache_k0=np.zeros((8, 4, 2, 4), np.float32),
        cache_v0=np.zeros((8, 4, 2, 4), np.float32))
    assert np.isfinite(np.asarray(logits)).all()


def test_autotune_cache_keys_paged_op_shapes(tmp_path):
    import json
    from repro.core import AutotunePolicy, compile
    from repro.models.graph_lm import build_paged_decode_graph, init_lm_params
    cfg = GraphLMConfig(vocab=37, d_model=16, n_layers=1, n_heads=4,
                        n_kv_heads=2, d_ff=32)
    params = init_lm_params(cfg, 0)
    g = build_paged_decode_graph(cfg, params, batch=2, n_blocks=8,
                                 page_size=8, max_pages=2)
    cache = str(tmp_path / "autotune.json")
    pol = AutotunePolicy(reps=1, candidates=("ref", "xla", "pallas"),
                         cache_path=cache)
    prog = compile(g, policy=pol)
    assert pol.n_measured > 0
    keys = [k for fp in json.load(open(cache))["fingerprints"].values()
            for k in fp]
    for op in ("paged_cache_update", "paged_decode_attention"):
        assert any(json.loads(k)[0] == op for k in keys), f"{op} not cached"
    for node in prog.graph.nodes:
        if node.op.startswith("paged_"):
            assert prog.assignment[node.name] in ("ref", "xla", "pallas")


# --------------------------------------------------------------------------- #
# the admission boundary fix (dense engine): len(prompt) == cache_cap
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("chunk", [4, 5])     # 5 does not divide 32
def test_dense_boundary_prompt_equals_cache_cap(chunk):
    engine, ref = build_lm_serving(TINY, n_slots=2, chunk=chunk,
                                   cache_cap=32)
    rng = np.random.default_rng(16)
    prompt = rng.integers(0, TINY.vocab, size=32).astype(np.int32)
    req = EngineRequest(uid=1, prompt=prompt, max_new_tokens=1)
    assert engine.submit(req), req.dropped
    engine.run(max_ticks=200)
    assert req.done
    assert req.out_tokens == ref.generate(prompt, 1)
    assert req.out_tokens == ref.generate(prompt, 1, chunk=chunk)
    # one token longer must still be rejected
    over = EngineRequest(uid=2, prompt=prompt, max_new_tokens=2)
    assert not engine.submit(over) and over.dropped == "too_long"
