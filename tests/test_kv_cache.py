"""BlockPool property suite — the paged KV cache's host-side invariants.

Randomised (deterministic + hypothesis when installed) sequences of
admit / append / fork / finish / drop are replayed against
:class:`repro.runtime.kv_cache.BlockPool`, asserting after every step:

* no block is leaked or double-freed — every block is in exactly one of
  free / cached (refcount 0, prefix-indexed, evictable) / live;
* a block's refcount equals the number of block tables containing it;
* reservations cover worst-case growth (an admitted sequence can always
  reach its declared ``max_new_tokens`` — ``_alloc`` asserts otherwise);
* when everything finishes, refcounts return to zero and
  free + cached == n_blocks.

Plus directed tests for prefix matching, copy-on-write divergence,
partial-tail sharing, LRU eviction and the stats counters.
"""

import numpy as np
import pytest

from repro.runtime.kv_cache import BlockPool, pages_needed

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _prompt(rng, n, vocab=13):
    return [int(t) for t in rng.integers(0, vocab, size=n)]


# --------------------------------------------------------------------------- #
# directed unit tests
# --------------------------------------------------------------------------- #

def test_pages_needed_last_token_not_written():
    # L + max_new - 1 rows: the final generated token is never written back
    assert pages_needed(8, 1, 8) == 1       # exactly one page
    assert pages_needed(8, 2, 8) == 2
    assert pages_needed(1, 1, 8) == 1
    assert pages_needed(16, 9, 8) == 3


def test_admit_append_finish_roundtrip():
    pool = BlockPool(8, 4)
    rng = np.random.default_rng(0)
    p = _prompt(rng, 6)
    sid, reused = pool.admit(p, 3)
    assert reused == 0
    pool.append(sid, p)
    pool.append(sid, [1, 2])                 # generated tokens
    pool.check_integrity()
    assert pool.sequence(sid).n_tokens == 8
    pool.release(sid)
    pool.check_integrity()
    st = pool.stats()
    assert st["live_blocks"] == 0 and st["reserved_blocks"] == 0
    assert st["free_blocks"] + st["cached_blocks"] == 8


def test_full_page_prefix_hit_and_cap():
    pool = BlockPool(16, 4)
    p = list(range(10))
    sid, reused = pool.admit(p, 2)
    assert reused == 0
    pool.append(sid, p)
    pool.release(sid)
    # identical prompt: both full pages hit, plus one row of the
    # registered partial tail — capped at len-1 = 9
    sid2, reused2 = pool.admit(p, 2)
    assert reused2 == 9
    assert pool.block_table(sid2) == pool.block_table(sid2)  # smoke
    pool.release(sid2, register=False)
    # prompt sharing only the first page
    q = p[:4] + [99] * 6
    sid3, reused3 = pool.admit(q, 2)
    assert reused3 == 4
    pool.release(sid3, register=False)
    # a 9-token prompt can reuse at most 8 (= len-1) tokens
    sid4, reused4 = pool.admit(p[:9], 2)
    assert reused4 == 8
    pool.release(sid4, register=False)
    pool.check_integrity()


def test_partial_tail_share_triggers_cow():
    pool = BlockPool(16, 4)
    p = list(range(6))                        # 1 full page + 2-row tail
    sid, _ = pool.admit(p, 1)
    pool.append(sid, p)
    pool.release(sid)                         # registers the partial tail
    assert pool.stats()["indexed_partial_pages"] == 1
    # new prompt matching the full page + 1 row of the tail
    q = p[:5] + [77, 78]
    sid2, reused = pool.admit(q, 2)
    assert reused == 5                        # 4 (full page) + 1 (tail row)
    before = pool.cow_count
    pool.append(sid2, q[5:])                  # first write into shared tail
    assert pool.cow_count == before + 1
    src, dst = pool.take_copies()[0]
    assert src != dst
    pool.check_integrity()
    pool.release(sid2, register=False)
    pool.check_integrity()


def test_fork_divergence_cow_both_ways():
    pool = BlockPool(16, 4)
    p = list(range(5))
    sid, _ = pool.admit(p, 4)
    pool.append(sid, p)
    nsid = pool.fork(sid, 4)
    assert nsid is not None
    pool.check_integrity()
    before = pool.cow_count
    pool.append(sid, [50])                    # parent writes shared tail
    pool.append(nsid, [60])                   # then the clone writes
    assert pool.cow_count >= before + 1       # at least one side copied
    assert pool.sequence(sid).tokens[-1] == 50
    assert pool.sequence(nsid).tokens[-1] == 60
    pool.release(sid)
    pool.release(nsid)
    pool.check_integrity()
    assert pool.stats()["live_blocks"] == 0


def test_admit_defers_when_pool_exhausted_then_recovers():
    pool = BlockPool(4, 4)
    rng = np.random.default_rng(1)
    a = _prompt(rng, 8)
    sid, _ = pool.admit(a, 8)                 # needs 8+8-1=15 rows -> 4 pages
    assert sid is not None
    assert pool.admit(_prompt(rng, 4), 2) is None   # nothing left
    assert pool.n_admit_deferred == 1
    pool.append(sid, a)
    pool.release(sid, register=False)
    assert pool.admit(_prompt(rng, 4), 2) is not None
    pool.check_integrity()


def test_lru_eviction_reclaims_cached_blocks():
    pool = BlockPool(4, 4)
    p1, p2 = list(range(4)), list(range(10, 14))
    for p in (p1, p2):
        sid, _ = pool.admit(p, 5)             # 4+5-1=8 rows -> 2 pages
        pool.append(sid, p + [1])
        pool.release(sid)                     # full page + tail cached
    assert pool.stats()["cached_blocks"] == 4
    # new admission must evict from the LRU cache to find blocks
    sid, reused = pool.admit(list(range(20, 26)), 4)
    assert sid is not None and reused == 0
    pool.append(sid, list(range(20, 26)))
    assert pool.evictions > 0
    pool.check_integrity()
    pool.release(sid, register=False)
    pool.check_integrity()


def test_stats_shape():
    pool = BlockPool(8, 4)
    s = pool.stats()
    for key in ("n_blocks", "page_size", "free_blocks", "cached_blocks",
                "live_blocks", "fragmentation", "hit_rate", "cow_count",
                "evictions", "n_admit_deferred"):
        assert key in s, key
    assert s["free_blocks"] == 8


# --------------------------------------------------------------------------- #
# randomized property drive
# --------------------------------------------------------------------------- #

def _drive(n_blocks, page_size, ops, seed, vocab=7):
    """Replay a random op sequence, checking integrity at every step.
    Small vocab on purpose: shared prefixes (and therefore CoW) happen."""
    pool = BlockPool(n_blocks, page_size)
    rng = np.random.default_rng(seed)
    live = {}                                 # sid -> (budget_tokens_left)
    for op in ops:
        if op == "admit":
            plen = int(rng.integers(1, 3 * page_size))
            max_new = int(rng.integers(1, 2 * page_size))
            if not pool.fits_ever(plen, max_new):
                continue
            prompt = _prompt(rng, plen, vocab)
            res = pool.admit(prompt, max_new)
            if res is not None:
                sid, reused = res
                assert 0 <= reused <= plen - 1
                # prefill the un-reused prompt tail immediately
                pool.append(sid, prompt[reused:])
                live[sid] = max_new - 1       # decode budget (first token
                                              # comes from prefill logits)
        elif op == "append" and live:
            sid = int(rng.choice(list(live)))
            if live[sid] > 0:
                pool.append(sid, _prompt(rng, 1, vocab))
                live[sid] -= 1
        elif op == "fork" and live:
            sid = int(rng.choice(list(live)))
            nsid = pool.fork(sid, page_size)
            if nsid is not None:
                live[nsid] = page_size - 1
        elif op in ("finish", "drop") and live:
            sid = int(rng.choice(list(live)))
            del live[sid]
            pool.release(sid, register=op == "finish")
        pool.check_integrity()
    for sid in list(live):
        pool.release(sid)
    pool.check_integrity()
    st = pool.stats()
    assert st["live_blocks"] == 0 and st["reserved_blocks"] == 0
    assert st["free_blocks"] + st["cached_blocks"] == n_blocks
    return pool


def test_pool_randomized_no_leak_no_double_free():
    rng = np.random.default_rng(42)
    total_cow = 0
    for trial in range(30):
        n_blocks = int(rng.integers(4, 24))
        page = int(rng.integers(2, 9))
        ops = list(rng.choice(["admit", "append", "append", "fork",
                               "finish", "drop"],
                              size=int(rng.integers(10, 80))))
        pool = _drive(n_blocks, page, ops, seed=trial)
        total_cow += pool.cow_count
    assert total_cow > 0, "random drive never exercised copy-on-write"


if HAVE_HYPOTHESIS:
    @given(st.integers(4, 24), st.integers(2, 8),
           st.lists(st.sampled_from(["admit", "append", "fork",
                                     "finish", "drop"]),
                    min_size=1, max_size=80),
           st.integers(0, 2 ** 16))
    @settings(max_examples=80, deadline=None)
    def test_pool_invariants_hypothesis(n_blocks, page, ops, seed):
        _drive(n_blocks, page, ops, seed)


@pytest.mark.parametrize("bad", [(0, 4), (4, 0)])
def test_pool_rejects_degenerate_shapes(bad):
    with pytest.raises(ValueError):
        BlockPool(*bad)
