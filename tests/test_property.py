"""Hypothesis property tests on the system's invariants:

* graph simplification preserves semantics on random op graphs,
* topological_order is a valid order for random DAGs,
* quantise/dequantise error is bounded by scale/2 and error feedback keeps
  the accumulated drift bounded,
* flash partial-combine is exact for any split of the KV axis,
* synthetic data is deterministic and shard-consistent,
* sequence packing conserves tokens.
"""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Executor, FixedPolicy, Graph, Node, TensorSpec,
                        infer_shapes, simplify, topological_order)
from repro.data.synthetic import SyntheticLM, pack_documents
from repro.kernels import ref as R
from repro.optim.compress import compress_decompress, dequantize, quantize

# --------------------------------------------------------------------------- #
# random graph generator: chain of unary/binary elementwise + dense ops
# --------------------------------------------------------------------------- #

_UNARY = ["relu", "gelu", "tanh", "sigmoid", "identity"]


@st.composite
def random_graph(draw):
    n_nodes = draw(st.integers(2, 12))
    dim = draw(st.sampled_from([4, 8]))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    g = Graph(name="rand", inputs={"x": TensorSpec((2, dim))}, outputs=[],
              nodes=[], params={})
    values = ["x"]
    for i in range(n_nodes):
        kind = draw(st.sampled_from(["unary", "add", "dense"]))
        vin = draw(st.sampled_from(values))
        out = f"v{i}"
        if kind == "unary":
            op = draw(st.sampled_from(_UNARY))
            g.nodes.append(Node(f"n{i}", op, [vin], [out]))
        elif kind == "add":
            vin2 = draw(st.sampled_from(values))
            g.nodes.append(Node(f"n{i}", "add", [vin, vin2], [out]))
        else:
            w = f"w{i}"
            g.params[w] = rng.standard_normal((dim, dim)).astype(np.float32) * 0.5
            g.nodes.append(Node(f"n{i}", "dense", [vin, w], [out]))
        values.append(out)
    g.outputs = [values[-1]]
    return g, rng.standard_normal((2, dim)).astype(np.float32)


@given(random_graph())
@settings(max_examples=25, deadline=None)
def test_simplify_preserves_semantics(gx):
    g, x = gx
    g.validate()
    y1 = np.asarray(Executor(infer_shapes(g), FixedPolicy(prefer=("ref",)))(x=x)[0])
    g2 = simplify(g)
    y2 = np.asarray(Executor(g2, FixedPolicy(prefer=("ref",)))(x=x)[0])
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


@given(random_graph())
@settings(max_examples=25, deadline=None)
def test_topological_order_valid(gx):
    g, _ = gx
    seen = set(g.inputs) | set(g.params)
    for node in topological_order(g):
        assert all(v in seen for v in node.inputs)
        seen.update(node.outputs)


# --------------------------------------------------------------------------- #
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_error_bounded(vals):
    g = jnp.asarray(np.asarray(vals, np.float32))
    q, s = quantize(g)
    err = np.abs(np.asarray(dequantize(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-6


@given(st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_error_feedback_drift_bounded(seed):
    """sum of decompressed grads ~= sum of true grads (EF property)."""
    rng = np.random.default_rng(seed)
    err = jnp.zeros((32,), jnp.float32)
    total_true = np.zeros((32,), np.float32)
    total_sent = np.zeros((32,), np.float32)
    scale_max = 0.0
    for _ in range(20):
        g = jnp.asarray(rng.standard_normal(32).astype(np.float32))
        sent, err = compress_decompress(g, err)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
        scale_max = max(scale_max, float(jnp.max(jnp.abs(g + 0))))
    # drift is at most one quantisation step (the residual still carried)
    drift = np.abs(total_true - total_sent).max()
    assert drift <= scale_max / 127 * 20 + 1e-4  # loose but meaningful bound


# --------------------------------------------------------------------------- #
@given(st.integers(2, 6), st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_partial_combine_exact_any_split(n_shards, seed):
    rng = np.random.default_rng(seed)
    b, skv, hq, hkv, d = 1, 8 * n_shards, 2, 1, 8
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
    lens = jnp.asarray([int(rng.integers(1, skv + 1))], jnp.int32)
    ref = R.decode_attention_ref(q, k, v, lens)
    from repro.kernels.ops import decode_attention_partial
    per = skv // n_shards
    parts = []
    for i in range(n_shards):
        local_len = jnp.clip(lens - i * per, 0, per)
        parts.append(decode_attention_partial(
            q, k[:, i*per:(i+1)*per], v[:, i*per:(i+1)*per], local_len,
            backend="ref"))
    comb = R.combine_partials_ref(jnp.stack([p[0] for p in parts]),
                                  jnp.stack([p[1] for p in parts]),
                                  jnp.stack([p[2] for p in parts]))
    np.testing.assert_allclose(np.asarray(comb), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------- #
@given(st.integers(0, 1000), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_synthetic_data_shard_consistency(step, log2_shards):
    num_shards = 2 ** (log2_shards - 1)
    ds = SyntheticLM(vocab=97, seq_len=16, batch=8, seed=3)
    full = ds.batch_at(step)
    if num_shards > 1 and 8 % num_shards == 0:
        parts = [ds.batch_at(step, shard=i, num_shards=num_shards)["tokens"]
                 for i in range(num_shards)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])
    # determinism
    np.testing.assert_array_equal(ds.batch_at(step)["tokens"], full["tokens"])
    assert full["tokens"].min() >= 0 and full["tokens"].max() < 97
    np.testing.assert_array_equal(full["labels"][:, :-1], full["tokens"][:, 1:])


@given(st.lists(st.lists(st.integers(2, 50), min_size=1, max_size=30),
                min_size=1, max_size=10), st.integers(4, 32))
@settings(max_examples=25, deadline=None)
def test_packing_conserves_tokens(docs, seq_len):
    rows = pack_documents(docs, seq_len)
    assert rows.shape[1] == seq_len
    n_tokens = sum(len(d) for d in docs)
    n_eos = len(docs)
    flat = rows.reshape(-1)
    # every doc token present in order (pad/eos are 0/1; docs use >=2)
    doc_stream = [t for d in docs for t in d]
    packed_stream = [int(t) for t in flat if t >= 2]
    assert packed_stream == doc_stream
    assert (flat == 1).sum() == n_eos
