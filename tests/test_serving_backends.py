"""Serving-op backend parity: ref vs xla vs pallas(interpret) must agree
across the edge shapes the engine actually produces — idle slots
(``n_new == 0``), chunks landing exactly at cache capacity
(``start + T == cap``), GQA head ratios, and the ``scale=0.0`` regression
(an explicit falsy scale must mean "uniform attention", not "use the
default") — plus the selection plumbing: graph-LM serving Programs
compile under cost-model and autotune policies, and serving-op shapes
land in the persistent autotune cache.
"""

import json
import math

import numpy as np
import pytest

import repro  # noqa: F401  (registers every op/backend)
from repro.core import (AutotunePolicy, CostModelPolicy, FixedPolicy,
                        backends_for, compile)
from repro.kernels.ops import decode_attention
from repro.kernels.serving_ops import cache_update, chunk_attention, embedding
from repro.models.graph_lm import (GraphLMConfig, build_decode_graph,
                                   build_prefill_graph, init_lm_params)

CFG = GraphLMConfig(vocab=37, d_model=16, n_layers=1, n_heads=4, n_kv_heads=2,
                    d_ff=32)


def _rng():
    return np.random.default_rng(7)


# --------------------------------------------------------------------------- #
# embedding
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("ids_shape", [(3, 5), (2, 1)])
def test_embedding_xla_exact(ids_shape):
    rng = _rng()
    ids = rng.integers(0, 11, size=ids_shape).astype(np.int32)
    table = rng.standard_normal((11, 8)).astype(np.float32)
    ref = np.asarray(embedding(ids, table, backend="ref"))
    xla = np.asarray(embedding(ids, table, backend="xla"))
    # a 0/1 one-hot matmul selects rows bit-for-bit
    np.testing.assert_array_equal(ref, xla)


# --------------------------------------------------------------------------- #
# cache_update — bitwise parity (pure data movement)
# --------------------------------------------------------------------------- #

def _cache_case(start, n_new, *, cap=16, t=4, b=None, hk=2, d=4):
    rng = _rng()
    b = b or len(start)
    cache = rng.standard_normal((b, cap, hk, d)).astype(np.float32)
    new = rng.standard_normal((b, t, hk, d)).astype(np.float32)
    return (cache, new, np.asarray(start, np.int32),
            np.asarray(n_new, np.int32))


@pytest.mark.parametrize("start,n_new", [
    ([0, 5, 12], [4, 4, 4]),     # last slot writes up to exactly cap
    ([0, 3, 7], [0, 0, 0]),      # all idle: exact no-op
    ([2, 12, 0], [1, 4, 3]),     # ragged chunk fills, one at capacity edge
])
def test_cache_update_xla_exact(start, n_new):
    cache, new, s, n = _cache_case(start, n_new)
    ref = np.asarray(cache_update(cache, new, s, n, backend="ref"))
    xla = np.asarray(cache_update(cache, new, s, n, backend="xla"))
    np.testing.assert_array_equal(ref, xla)


def test_cache_update_idle_slot_untouched():
    cache, new, s, n = _cache_case([0, 5], [4, 0])
    for backend in ("ref", "xla"):
        out = np.asarray(cache_update(cache, new, s, n, backend=backend))
        np.testing.assert_array_equal(out[1], cache[1])


# --------------------------------------------------------------------------- #
# chunk_attention — ref vs xla vs pallas(interpret)
# --------------------------------------------------------------------------- #

def _chunk_case(*, b=2, t=4, s=16, hq=4, hk=2, d=8, start=(0, 12)):
    rng = _rng()
    q = rng.standard_normal((b, t, hq, d)).astype(np.float32)
    k = rng.standard_normal((b, s, hk, d)).astype(np.float32)
    v = rng.standard_normal((b, s, hk, d)).astype(np.float32)
    return q, k, v, np.asarray(start, np.int32)


@pytest.mark.parametrize("hq,hk", [(1, 1), (2, 1), (4, 2), (4, 4)])
@pytest.mark.parametrize("scale", [None, 0.0])
def test_chunk_attention_backend_parity(hq, hk, scale):
    # start=12 with t=4 and s=16: the chunk's last query sits at the final
    # cache position (start + T == capacity)
    q, k, v, start = _chunk_case(hq=hq, hk=hk, start=(0, 12))
    ref = np.asarray(chunk_attention(q, k, v, start, scale=scale,
                                     backend="ref"))
    for backend in ("xla", "pallas"):
        assert backend in backends_for("chunk_attention")
        out = np.asarray(chunk_attention(q, k, v, start, scale=scale,
                                         backend=backend, interpret=True))
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{backend} vs ref")


def test_chunk_attention_scale_zero_is_uniform():
    """Regression for `attrs.get("scale") or default`: scale=0.0 must give
    uniform attention over the allowed positions, on every backend."""
    q, k, v, start = _chunk_case(b=1, t=2, s=8, hq=2, hk=2, start=(3,))
    outs = {b: np.asarray(chunk_attention(q, k, v, start, scale=0.0,
                                          backend=b, interpret=True))
            for b in ("ref", "xla", "pallas")}
    # expected: plain mean of v rows 0..start+t (per query position)
    for t_i in range(2):
        n_allowed = 3 + t_i + 1
        want = v[0, :n_allowed].mean(axis=0)        # (Hk, D) == (Hq, D) here
        for b, out in outs.items():
            np.testing.assert_allclose(out[0, t_i], want, rtol=2e-5,
                                       atol=2e-5, err_msg=b)
    # and it must differ from the default 1/sqrt(d) scaling
    default = np.asarray(chunk_attention(q, k, v, start, backend="ref"))
    assert not np.allclose(outs["ref"], default)


def test_chunk_attention_pallas_supports_guard():
    # T=3 with block_q=2 -> 3 % 2 != 0 -> pallas must be filtered out
    from repro.core.ir import TensorSpec
    specs = [TensorSpec((1, 3, 2, 8)), TensorSpec((1, 16, 1, 8)),
             TensorSpec((1, 16, 1, 8)), TensorSpec((1,), "int32")]
    avail = backends_for("chunk_attention", specs, {"block_q": 2})
    assert "pallas" not in avail and {"ref", "xla"} <= set(avail)


# --------------------------------------------------------------------------- #
# decode_attention — split-KV backend
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("hq,hk", [(2, 1), (4, 2)])
@pytest.mark.parametrize("n_splits", [2, 4])
def test_decode_split_parity(hq, hk, n_splits):
    rng = _rng()
    b, s, d = 3, 32, 8
    q = rng.standard_normal((b, hq, d)).astype(np.float32)
    k = rng.standard_normal((b, s, hk, d)).astype(np.float32)
    v = rng.standard_normal((b, s, hk, d)).astype(np.float32)
    # lengths straddling the split boundaries, incl. one shard fully empty
    lengths = np.asarray([3, s // n_splits, s], np.int32)
    ref = np.asarray(decode_attention(q, k, v, lengths, backend="ref"))
    split = np.asarray(decode_attention(q, k, v, lengths,
                                        backend="pallas_split",
                                        n_splits=n_splits, interpret=True))
    np.testing.assert_allclose(split, ref, rtol=2e-5, atol=2e-5)


def test_decode_split_supports_guard():
    from repro.core.ir import TensorSpec
    qs = TensorSpec((1, 2, 8))
    kv_ok = TensorSpec((1, 32, 1, 8))
    kv_small = TensorSpec((1, 8, 1, 8))     # 8/2=4 < 8-row minimum shard
    lens = TensorSpec((1,), "int32")
    assert "pallas_split" in backends_for(
        "decode_attention", [qs, kv_ok, kv_ok, lens], {})
    assert "pallas_split" not in backends_for(
        "decode_attention", [qs, kv_small, kv_small, lens], {})
    assert "pallas_split" not in backends_for(
        "decode_attention", [qs, kv_ok, kv_ok, lens], {"n_splits": 3})


# --------------------------------------------------------------------------- #
# selection plumbing: serving Programs under real policies
# --------------------------------------------------------------------------- #

def _serving_ops_in(graph):
    return {n.op for n in graph.nodes} & {"embedding", "cache_update",
                                          "chunk_attention",
                                          "decode_attention"}


def test_graph_lm_compiles_under_cost_model_policy():
    params = init_lm_params(CFG, 0)
    g = build_prefill_graph(CFG, params, batch=2, chunk=4, cache_cap=16)
    prog = compile(g, policy=CostModelPolicy())
    assert _serving_ops_in(prog.graph) == {"embedding", "cache_update",
                                           "chunk_attention"}
    for name, backend in prog.assignment.items():
        assert backend  # every node resolved
    rng = _rng()
    (logits, *_) = prog(
        tokens=rng.integers(0, CFG.vocab, size=(2, 4)).astype(np.int32),
        start=np.zeros((2,), np.int32), n_new=np.full((2,), 4, np.int32),
        cache_k0=np.zeros((2, 16, 2, 4), np.float32),
        cache_v0=np.zeros((2, 16, 2, 4), np.float32))
    assert np.isfinite(np.asarray(logits)).all()


def test_autotune_cache_keys_serving_op_shapes(tmp_path):
    """Compiling the serving graphs under AutotunePolicy must persist
    serving-op measurements; a fresh policy preloads them and re-compiles
    with zero new measurements."""
    cache = str(tmp_path / "autotune.json")
    params = init_lm_params(CFG, 0)
    dec = build_decode_graph(CFG, params, batch=2, cache_cap=16)
    pre = build_prefill_graph(CFG, params, batch=2, chunk=4, cache_cap=16)
    # "pallas" kept in the candidate set so decode_attention (ref/pallas/
    # pallas_split) has >1 candidate and actually gets measured
    cands = ("ref", "xla", "pallas")
    pol = AutotunePolicy(reps=1, candidates=cands, cache_path=cache)
    p_dec = compile(dec, policy=pol)
    p_pre = compile(pre, policy=pol)
    assert pol.n_measured > 0
    data = json.load(open(cache))
    keys = [k for fp in data["fingerprints"].values() for k in fp]
    for op in ("embedding", "cache_update", "chunk_attention",
               "decode_attention"):
        assert any(json.loads(k)[0] == op for k in keys), f"{op} not cached"
    # chosen serving-op backends are frozen into the Programs
    for prog in (p_dec, p_pre):
        for node in prog.graph.nodes:
            if node.op in ("embedding", "cache_update", "chunk_attention",
                           "decode_attention"):
                assert prog.assignment[node.name] in cands
    # second policy: everything preloads, nothing re-measured
    pol2 = AutotunePolicy(reps=1, candidates=cands, cache_path=cache)
    assert pol2.n_loaded > 0
    compile(dec, policy=pol2)
    assert pol2.n_measured == 0


def test_engine_runs_under_fixed_pallas_policy():
    """End-to-end: the engine serves traffic with the serving ops pinned
    to the fanciest supported backends (pallas chunk attention via
    interpret on CPU, xla elsewhere)."""
    from repro.runtime.engine import EngineRequest, build_lm_serving
    policy = FixedPolicy(
        prefer=("xla", "ref"),
        per_op={"chunk_attention": ("pallas", "xla", "ref"),
                "decode_attention": ("pallas", "ref")})
    engine, _ = build_lm_serving(CFG, n_slots=2, chunk=4, cache_cap=16,
                                 policy=policy)
    summary = engine.stepper.backend_summary()
    assert summary["prefill"]["chunk_attention"] == {"pallas": CFG.n_layers}
    rng = _rng()
    reqs = [EngineRequest(uid=i,
                          prompt=rng.integers(0, CFG.vocab, size=3 + i)
                          .astype(np.int32),
                          max_new_tokens=3)
            for i in range(3)]
    for r in reqs:
        assert engine.submit(r)
    engine.run(max_ticks=500)
    assert all(r.done and len(r.out_tokens) == 3 for r in reqs)


# --------------------------------------------------------------------------- #
# verify_attention family (speculative decoding) — backend parity and the
# explicit-zero attr sweep (the PR 4 `attrs.get(...) or default` bug class)
# --------------------------------------------------------------------------- #

def _paged_verify_case(*, b=2, t=4, n_blocks=8, page=4, mp=4, hq=4, hk=2,
                       d=8, start=(0, 7)):
    """int8 pages under a scrambled block layout + this call's fp32 rows,
    plus the patched DENSE fp32 equivalent the two-source op must match."""
    rng = _rng()
    start = np.asarray(start, np.int32)
    tables = rng.permutation(n_blocks)[:b * mp].reshape(b, mp).astype(np.int32)
    dense = rng.standard_normal((n_blocks, page, hk, d)).astype(np.float32)
    amax = np.abs(dense).max(axis=(1, 3))
    scales = (amax / 127.0).astype(np.float32)
    pages = np.clip(np.round(dense / np.where(scales > 0, scales, 1.0)
                             [:, None, :, None]), -127, 127).astype(np.int8)
    deq = pages.astype(np.float32) * scales[:, None, :, None]
    q = rng.standard_normal((b, t, hq, d)).astype(np.float32)
    k_new = rng.standard_normal((b, t, hk, d)).astype(np.float32)
    v_new = rng.standard_normal((b, t, hk, d)).astype(np.float32)
    k_dense = np.stack([deq[tables[bi]].reshape(mp * page, hk, d)
                        for bi in range(b)])
    v_dense = k_dense.copy()
    for bi in range(b):
        for ti in range(t):
            k_dense[bi, start[bi] + ti] = k_new[bi, ti]
            v_dense[bi, start[bi] + ti] = v_new[bi, ti]
    return (q, pages, scales, pages.copy(), scales.copy(), tables, start,
            k_new, v_new, k_dense, v_dense)


@pytest.mark.parametrize("scale", [None, 0.0, 2.0])
def test_verify_attention_matches_chunk_attention(scale):
    """verify_attention IS offset-causal chunk attention at T = K+1 — and
    an explicit scale=0.0 must survive to every backend (not be swallowed
    by a falsy-default fallback)."""
    from repro.kernels.serving_ops import verify_attention
    rng = _rng()
    q = rng.standard_normal((2, 4, 4, 8)).astype(np.float32)
    k = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    v = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    start = np.asarray([0, 12], np.int32)
    want = np.asarray(chunk_attention(q, k, v, start, scale=scale,
                                      backend="ref"))
    for backend in ("ref", "xla", "pallas"):
        assert backend in backends_for("verify_attention")
        out = np.asarray(verify_attention(q, k, v, start, scale=scale,
                                          backend=backend, interpret=True))
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{backend} scale={scale}")
    if scale == 0.0:
        dflt = np.asarray(verify_attention(q, k, v, start, backend="ref"))
        assert not np.allclose(want, dflt), \
            "scale=0.0 was swallowed by a falsy default"


@pytest.mark.parametrize("scale", [None, 0.0])
def test_paged_verify_attention_backend_parity(scale):
    from repro.kernels.serving_ops import paged_verify_attention
    rng = _rng()
    b, t, n_blocks, page, mp, hq, hk, d = 2, 4, 8, 4, 4, 4, 2, 8
    q = rng.standard_normal((b, t, hq, d)).astype(np.float32)
    pk = rng.standard_normal((n_blocks, page, hk, d)).astype(np.float32)
    pv = rng.standard_normal((n_blocks, page, hk, d)).astype(np.float32)
    tables = rng.permutation(n_blocks).reshape(b, mp).astype(np.int32)
    start = np.asarray([0, 7], np.int32)
    # dense oracle: gather each sequence's pages then offset-causal chunk
    kd = np.stack([pk[tables[bi]].reshape(mp * page, hk, d)
                   for bi in range(b)])
    vd = np.stack([pv[tables[bi]].reshape(mp * page, hk, d)
                   for bi in range(b)])
    want = np.asarray(chunk_attention(q, kd, vd, start, scale=scale,
                                      backend="ref"))
    for backend in ("ref", "xla", "pallas"):
        assert backend in backends_for("paged_verify_attention")
        out = np.asarray(paged_verify_attention(
            q, pk, pv, tables, start, scale=scale, backend=backend,
            interpret=True))
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{backend} scale={scale}")


@pytest.mark.parametrize("scale", [None, 0.0])
def test_paged_verify_attention_q_two_source_parity(scale):
    """The two-source kv8 verify op: committed prefix dequantized from the
    int8 pages, this call's K+1 rows patched in from fp32 — all backends
    must match the patched-dense fp32 oracle, scale=0.0 included."""
    from repro.kernels.serving_ops import paged_verify_attention_q
    (q, pk, ks, pv, vs, tables, start, k_new, v_new,
     k_dense, v_dense) = _paged_verify_case()
    want = np.asarray(chunk_attention(q, k_dense, v_dense, start,
                                      scale=scale, backend="ref"))
    for backend in ("ref", "xla", "pallas"):
        assert backend in backends_for("paged_verify_attention_q")
        out = np.asarray(paged_verify_attention_q(
            q, pk, ks, pv, vs, tables, start, k_new, v_new, scale=scale,
            backend=backend, interpret=True))
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{backend} scale={scale}")


def test_verify_attention_pallas_supports_guards():
    """Ragged T must filter the pallas paths out, never crash them."""
    from repro.core.ir import TensorSpec
    # T=3 with block_q=2: 3 % 2 != 0
    dense = [TensorSpec((1, 3, 2, 8)), TensorSpec((1, 16, 1, 8)),
             TensorSpec((1, 16, 1, 8)), TensorSpec((1,), "int32")]
    avail = backends_for("verify_attention", dense, {"block_q": 2})
    assert "pallas" not in avail and {"ref", "xla"} <= set(avail)
    qspecs = [TensorSpec((1, 3, 2, 8)), TensorSpec((8, 4, 1, 8), "int8"),
              TensorSpec((8, 1)), TensorSpec((8, 4, 1, 8), "int8"),
              TensorSpec((8, 1)), TensorSpec((1, 4), "int32"),
              TensorSpec((1,), "int32"), TensorSpec((1, 3, 1, 8)),
              TensorSpec((1, 3, 1, 8))]
    avail = backends_for("paged_verify_attention_q", qspecs, {"block_q": 2})
    assert "pallas" not in avail and {"ref", "xla"} <= set(avail)


def test_greedy_token_argmax():
    from repro.kernels.serving_ops import greedy_token
    rng = _rng()
    logits = rng.standard_normal((3, 37)).astype(np.float32)
    out = np.asarray(greedy_token(logits))
    # (B, 1) int32 — shaped to feed straight back as the next tokens column
    assert out.shape == (3, 1) and out.dtype == np.int32
    np.testing.assert_array_equal(out[:, 0], np.argmax(logits, axis=-1))
