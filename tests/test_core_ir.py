"""GraphIR, registry, passes, importer, Program, selector unit tests.

Execution goes through the staged ``compile()`` -> ``Program`` pipeline
(with ``pipeline=()`` where a test wants the graph run as-is, matching the
old ``Executor`` semantics the shim preserves for external callers)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (AutotunePolicy, CostModelPolicy, FixedPolicy, Graph,
                        GraphError, Node, TensorSpec, backends_for, compile,
                        eliminate_common_subexpr, eliminate_dead,
                        fold_batchnorm, fold_constants, fuse_bias_act,
                        get_impl, get_op, infer_shapes, load_graph,
                        registered_ops, save_graph, simplify,
                        topological_order)


def tiny_graph(rng):
    g = Graph(
        name="tiny",
        inputs={"x": TensorSpec((2, 8, 8, 3))},
        outputs=["y"],
        nodes=[
            Node("c1", "conv2d", ["x", "w1"], ["h1"], {"stride": 1, "padding": "SAME"}),
            Node("b1", "bias_add", ["h1", "bb"], ["h2"]),
            Node("r1", "relu", ["h2"], ["h3"]),
            Node("d1", "flatten", ["h3"], ["h4"]),
            Node("fc", "dense", ["h4", "w2"], ["y"]),
        ],
        params={
            "w1": rng.standard_normal((3, 3, 3, 4)).astype(np.float32),
            "bb": rng.standard_normal((4,)).astype(np.float32),
            "w2": rng.standard_normal((8 * 8 * 4, 10)).astype(np.float32),
        },
    )
    g.validate()
    return g


class TestIR:
    def test_topological_order_detects_cycle(self, rng):
        g = tiny_graph(rng)
        g.nodes[0].inputs[0] = "y"  # cycle
        with pytest.raises(GraphError):
            topological_order(g)

    def test_duplicate_value_def_rejected(self, rng):
        g = tiny_graph(rng)
        g.nodes[1].outputs = ["h1"]
        with pytest.raises(GraphError):
            g.producers()

    def test_undefined_input_rejected(self, rng):
        g = tiny_graph(rng)
        g.nodes[0].inputs[1] = "nonexistent"
        with pytest.raises(GraphError):
            g.validate()

    def test_shape_inference(self, rng):
        g = infer_shapes(tiny_graph(rng))
        assert g.value_info["h1"].shape == (2, 8, 8, 4)
        assert g.value_info["y"].shape == (2, 10)

    def test_spec_repr(self):
        assert repr(TensorSpec((1, 3), "float32")) == "f32[1,3]"


class TestPasses:
    def _run(self, g, x, backend="ref"):
        return np.asarray(compile(g, FixedPolicy(prefer=(backend,)),
                                  pipeline=())(x=x)[0])

    def test_fuse_bias_act(self, rng):
        g = tiny_graph(rng)
        fused = fuse_bias_act(g)
        ops = [n.op for n in fused.nodes]
        assert "conv2d_fused" in ops and "bias_add" not in ops

    def test_fusion_preserves_semantics(self, rng):
        g = tiny_graph(rng)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        np.testing.assert_allclose(self._run(g, x),
                                   self._run(fuse_bias_act(g), x),
                                   rtol=1e-5, atol=1e-5)

    def test_dce_removes_dead_nodes(self, rng):
        g = tiny_graph(rng)
        g.nodes.append(Node("dead", "relu", ["h1"], ["unused"]))
        g2 = eliminate_dead(g)
        assert all(n.name != "dead" for n in g2.nodes)

    def test_cse_merges_duplicates(self, rng):
        g = tiny_graph(rng)
        g.nodes.insert(1, Node("c1b", "conv2d", ["x", "w1"], ["h1b"],
                               {"stride": 1, "padding": "SAME"}))
        g.nodes.append(Node("add", "add", ["h1", "h1b"], ["z"]))
        g.outputs = ["z"]
        g2 = eliminate_common_subexpr(g)
        assert sum(1 for n in g2.nodes if n.op == "conv2d") == 1

    def test_fold_constants(self, rng):
        g = tiny_graph(rng)
        g.nodes.insert(0, Node("pre", "relu", ["w1"], ["w1r"]))
        g.nodes[1].inputs[1] = "w1r"
        g2 = fold_constants(g)
        assert all(n.name != "pre" for n in g2.nodes)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        np.testing.assert_allclose(self._run(g, x), self._run(g2, x),
                                   rtol=1e-5)

    def test_fold_batchnorm(self, rng):
        g = Graph(
            name="bn", inputs={"x": TensorSpec((1, 4, 4, 3))}, outputs=["y"],
            nodes=[
                Node("c", "conv2d", ["x", "w"], ["h"], {"padding": "SAME"}),
                Node("n", "batchnorm", ["h", "s", "b", "m", "v"], ["y"]),
            ],
            params={
                "w": rng.standard_normal((3, 3, 3, 4)).astype(np.float32),
                "s": rng.standard_normal((4,)).astype(np.float32),
                "b": rng.standard_normal((4,)).astype(np.float32),
                "m": rng.standard_normal((4,)).astype(np.float32),
                "v": (np.abs(rng.standard_normal((4,))) + 0.5).astype(np.float32),
            })
        g2 = fold_batchnorm(g)
        assert all(n.op != "batchnorm" for n in g2.nodes)
        x = rng.standard_normal((1, 4, 4, 3)).astype(np.float32)
        np.testing.assert_allclose(self._run(g, x), self._run(g2, x),
                                   rtol=1e-4, atol=1e-4)

    def test_simplify_pipeline(self, rng):
        g = tiny_graph(rng)
        g2 = simplify(g)
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        np.testing.assert_allclose(self._run(g, x), self._run(g2, x),
                                   rtol=1e-4, atol=1e-4)
        assert len(g2.nodes) < len(g.nodes)


class TestRegistry:
    def test_every_op_has_ref(self):
        for op in registered_ops():
            assert "ref" in backends_for(op), f"{op} missing ref backend"

    def test_conv_backends_registered(self):
        assert set(backends_for("conv2d")) >= {"ref", "xla", "winograd", "pallas"}

    def test_unknown_backend_raises(self):
        with pytest.raises(KeyError):
            get_impl("conv2d", "nope")

    def test_winograd_supports_predicate(self):
        specs = [TensorSpec((1, 8, 8, 3)), TensorSpec((3, 3, 3, 4))]
        assert "winograd" in backends_for("conv2d", specs, {"stride": 1})
        assert "winograd" not in backends_for("conv2d", specs, {"stride": 2})

    def test_cost_models_positive(self):
        specs = [TensorSpec((1, 8, 8, 3)), TensorSpec((3, 3, 3, 4))]
        cost = get_op("conv2d").cost_fn(specs, {"stride": 1, "padding": "SAME"})
        assert cost.flops > 0 and cost.bytes > 0
        wino = get_impl("conv2d", "winograd").cost(specs, {"stride": 1,
                                                           "padding": "SAME"})
        assert wino.flops < cost.flops  # fewer multiplies is the point


class TestSelectorProgram:
    def test_fixed_policy_per_op(self, rng):
        g = infer_shapes(tiny_graph(rng))
        prog = compile(g, FixedPolicy(per_op={"conv2d": ("winograd",)},
                                      prefer=("ref",)), pipeline=())
        assert prog.assignment["c1"] == "winograd"

    def test_pinned_backend_wins(self, rng):
        g = infer_shapes(tiny_graph(rng))
        g.nodes[0].backend = "xla"
        prog = compile(g, FixedPolicy(prefer=("ref",)), pipeline=())
        assert prog.assignment["c1"] == "xla"

    def test_cost_model_policy_runs(self, rng):
        g = infer_shapes(tiny_graph(rng))
        prog = compile(g, CostModelPolicy(), pipeline=())
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        (y,) = prog(x=x)
        assert np.isfinite(np.asarray(y)).all()

    def test_autotune_policy_picks_measured_best(self, rng):
        g = infer_shapes(tiny_graph(rng))
        pol = AutotunePolicy(reps=2)
        prog = compile(g, pol, pipeline=())
        assert prog.assignment["c1"] in backends_for("conv2d")
        assert pol._timings  # measurements cached

    def test_instrumented_run_reports_all_nodes(self, rng):
        g = infer_shapes(tiny_graph(rng))
        prog = compile(g, FixedPolicy(prefer=("ref",)), pipeline=())
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        outs, reports = prog.run_instrumented(x=x)
        assert len(reports) == len(g.nodes)
        assert all(r.seconds >= 0 for r in reports)

    def test_program_backend_equivalence(self, rng):
        """The Orpheus guarantee: same graph, any backend, same numbers."""
        g = infer_shapes(tiny_graph(rng))
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        outs = {}
        for b in ("ref", "xla", "pallas"):
            outs[b] = np.asarray(
                compile(g, FixedPolicy(prefer=(b, "ref")), pipeline=())(x=x)[0])
        np.testing.assert_allclose(outs["xla"], outs["ref"], rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(outs["pallas"], outs["ref"], rtol=1e-4,
                                   atol=1e-4)

    def test_lower_compile_cost(self, rng):
        g = infer_shapes(tiny_graph(rng))
        co = compile(g, FixedPolicy(prefer=("ref",)),
                     pipeline=()).lower().compile()
        ca = co.cost_analysis()
        if isinstance(ca, list):  # older jaxlib returns one dict per device
            ca = ca[0]
        assert ca.get("flops", 0) > 0


class TestImporter:
    def test_roundtrip(self, rng, tmp_path):
        g = simplify(tiny_graph(rng))
        save_graph(g, str(tmp_path / "m"))
        g2 = load_graph(str(tmp_path / "m"))
        x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
        y1 = compile(g, FixedPolicy(prefer=("ref",)), pipeline=())(x=x)[0]
        y2 = compile(infer_shapes(g2), FixedPolicy(prefer=("ref",)),
                     pipeline=())(x=x)[0]
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_version_check(self, rng, tmp_path):
        import json, os
        g = tiny_graph(rng)
        save_graph(g, str(tmp_path / "m"))
        meta = json.load(open(tmp_path / "m" / "model.json"))
        meta["format_version"] = 999
        json.dump(meta, open(tmp_path / "m" / "model.json", "w"))
        with pytest.raises(GraphError):
            load_graph(str(tmp_path / "m"))
