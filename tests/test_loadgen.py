"""Trace-driven load generator properties (repro.runtime.loadgen).

* determinism — the same :class:`TraceConfig` yields a byte-identical
  trace (equal sha256 digests, equal prompt arrays), for both arrival
  processes; different seeds diverge;
* distribution shape — empirical interarrival / prompt-length /
  output-length means land within a CLT-scaled tolerance of the
  configured means (hypothesis sweeps seeds and burstiness);
* conservation through the scheduler — every trace request reaches
  exactly one terminal state; tier and population counts are preserved;
  FIFO among equal priorities; shed requests are reported, not lost;
* SLO scoring and the ``run_load`` report: per-tier sections sum to the
  overall section, goodput counts only SLO-met requests.
"""

import numpy as np
import pytest

from repro.models.graph_lm import GraphLMConfig
from repro.runtime.batching import SlotScheduler
from repro.runtime.engine import EngineRequest, build_lm_serving
from repro.runtime.loadgen import (SLO, PrefixPopulation, TierSpec, Trace,
                                   TraceConfig, generate_trace, run_load)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

TINY = GraphLMConfig(vocab=61, d_model=32, n_layers=2, n_heads=4,
                     n_kv_heads=2, d_ff=64)

CFG = TraceConfig(
    seed=3, n_requests=40, mean_interarrival_ticks=2.0,
    prompt_len_mean=8.0, prompt_len_max=24,
    new_tokens_mean=5.0, new_tokens_max=10,
    tiers=(TierSpec("interactive", priority=1, weight=0.6,
                    deadline_ticks=500),
           TierSpec("batch", priority=0, weight=0.4)),
    prefix_populations=(PrefixPopulation("sys", prefix_len=8),
                        PrefixPopulation("fewshot", prefix_len=12)),
    prefix_share_p=0.5)


# --------------------------------------------------------------------------- #
# determinism
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arrival", ["gamma", "mmpp"])
def test_same_seed_byte_identical(arrival):
    cfg = TraceConfig(seed=11, n_requests=64, arrival=arrival,
                      prefix_populations=CFG.prefix_populations,
                      prefix_share_p=0.4)
    a, b = generate_trace(cfg), generate_trace(cfg)
    assert a.digest() == b.digest()
    for ra, rb in zip(a.requests, b.requests):
        assert ra.arrival_tick == rb.arrival_tick
        assert ra.tier == rb.tier and ra.population == rb.population
        assert np.array_equal(ra.prompt, rb.prompt)
    for name in a.prefixes:
        assert np.array_equal(a.prefixes[name], b.prefixes[name])


def test_different_seeds_diverge():
    a = generate_trace(TraceConfig(seed=0, n_requests=32))
    b = generate_trace(TraceConfig(seed=1, n_requests=32))
    assert a.digest() != b.digest()


def test_digest_covers_prompts():
    t = generate_trace(TraceConfig(seed=5, n_requests=8))
    mutated = Trace(config=t.config, requests=list(t.requests),
                    prefixes=t.prefixes)
    r0 = mutated.requests[0]
    bent = np.array(r0.prompt, np.int32)
    bent[0] = (bent[0] + 1) % 61
    mutated.requests[0] = type(r0)(
        uid=r0.uid, arrival_tick=r0.arrival_tick, prompt=bent,
        max_new_tokens=r0.max_new_tokens, tier=r0.tier,
        priority=r0.priority, deadline_ticks=r0.deadline_ticks,
        population=r0.population)
    assert mutated.digest() != t.digest()


def test_config_validation():
    with pytest.raises(ValueError, match="tier"):
        generate_trace(TraceConfig(tiers=()))
    with pytest.raises(ValueError, match="arrival"):
        generate_trace(TraceConfig(arrival="nope"))


# --------------------------------------------------------------------------- #
# distribution shape
# --------------------------------------------------------------------------- #

def _shape_ok(cfg):
    trace = generate_trace(cfg)
    s = trace.stats()
    n = cfg.n_requests
    # CLT bound on the sample mean of gamma interarrivals: relative sd is
    # sqrt(cv^2 / n); mmpp's per-state cv is 1 but state runs correlate,
    # so give it the same burstiness-scaled slack
    tol = 6.0 * np.sqrt(max(cfg.burstiness, cfg.mmpp_burst_factor) / n)
    assert abs(s["mean_interarrival_ticks"] - cfg.mean_interarrival_ticks) \
        <= max(tol * cfg.mean_interarrival_ticks, 1.0), s
    # int-rounding + clipping shift lognormal means a little; 25% covers it
    assert abs(s["mean_prompt_len"] - cfg.prompt_len_mean) \
        <= 0.25 * cfg.prompt_len_mean + 6.0 / np.sqrt(n), s
    assert abs(s["mean_new_tokens"] - cfg.new_tokens_mean) \
        <= 0.25 * cfg.new_tokens_mean + 6.0 / np.sqrt(n), s
    # every request landed in a configured tier
    assert sum(s["tiers"].values()) == n
    assert set(s["tiers"]) <= {t.name for t in cfg.tiers}
    assert s["shared_prefix_requests"] == sum(s["populations"].values())


def test_distribution_means_default():
    _shape_ok(TraceConfig(seed=0, n_requests=600))
    _shape_ok(TraceConfig(seed=1, n_requests=600, arrival="mmpp"))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000),
           burst=st.floats(1.0, 6.0),
           mean=st.floats(0.5, 8.0),
           arrival=st.sampled_from(["gamma", "mmpp"]))
    def test_distribution_means_property(seed, burst, mean, arrival):
        _shape_ok(TraceConfig(seed=seed, n_requests=600, arrival=arrival,
                              burstiness=burst,
                              mean_interarrival_ticks=mean))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), share=st.floats(0.0, 1.0))
    def test_population_membership_property(seed, share):
        cfg = TraceConfig(seed=seed, n_requests=120,
                          prefix_populations=CFG.prefix_populations,
                          prefix_share_p=share)
        trace = generate_trace(cfg)
        for r in trace.requests:
            if r.population is not None:
                head = trace.prefixes[r.population]
                assert np.array_equal(r.prompt[:len(head)], head)
            assert len(r.prompt) >= 1
            assert r.max_new_tokens >= 1


# --------------------------------------------------------------------------- #
# conservation through SlotScheduler (no model — pure scheduling)
# --------------------------------------------------------------------------- #

def _to_engine_req(tr):
    return EngineRequest(uid=tr.uid, prompt=tr.prompt,
                         max_new_tokens=tr.max_new_tokens,
                         priority=tr.priority, tier=tr.tier)


def test_trace_conserved_through_scheduler():
    """Feed a whole trace through SlotScheduler with a synthetic service
    loop: nothing lost, nothing duplicated, tier counts preserved, and
    shed (queue-full) requests are visible — not silently gone."""
    trace = generate_trace(CFG)
    sched = SlotScheduler(n_slots=3, max_queue=6)
    accepted, shed = [], []
    for tr in trace.requests:
        req = _to_engine_req(tr)
        (accepted if sched.submit(req) else shed).append(req)
        # drain one admission + completion round every few submissions so
        # the queue oscillates around the cap
        if tr.uid % 3 == 0:
            for slot, _ in sched.admit():
                sched.finish(slot)
    while sched.has_work():
        admitted = sched.admit()
        if not admitted:
            break
        for slot, _ in admitted:
            sched.finish(slot)
    sched.check_conservation()
    assert len(accepted) + len(shed) == len(trace.requests)
    assert sched.n_rejected == len(shed)
    assert sched.n_finished == len(accepted)
    # tier conservation across the accepted/shed split
    want = trace.stats()["tiers"]
    got = {}
    for r in accepted + shed:
        got[r.tier] = got.get(r.tier, 0) + 1
    assert got == want


def test_fifo_among_equal_priority():
    trace = generate_trace(TraceConfig(
        seed=9, n_requests=30, tiers=(TierSpec("only", priority=0),)))
    sched = SlotScheduler(n_slots=1)
    for tr in trace.requests:
        assert sched.submit(_to_engine_req(tr))
    served = []
    while sched.has_work():
        for slot, req in sched.admit():
            served.append(req.uid)
            sched.finish(slot)
    assert served == sorted(served), "equal-priority FIFO violated"


def test_priority_tiers_preempt_queue_order():
    """Interactive (priority 1) requests queued after batch ones are still
    admitted first; FIFO holds within each tier."""
    sched = SlotScheduler(n_slots=1)
    batch = [EngineRequest(uid=i, prompt=np.ones(1, np.int32),
                           max_new_tokens=1, priority=0) for i in range(3)]
    inter = [EngineRequest(uid=10 + i, prompt=np.ones(1, np.int32),
                           max_new_tokens=1, priority=1) for i in range(3)]
    for r in batch + inter:
        sched.submit(r)
    served = []
    while sched.has_work():
        for slot, req in sched.admit():
            served.append(req.uid)
            sched.finish(slot)
    assert served == [10, 11, 12, 0, 1, 2]


# --------------------------------------------------------------------------- #
# run_load end-to-end (one tiny engine)
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def paged_engine():
    return build_lm_serving(TINY, n_slots=2, chunk=4, cache_cap=48,
                            paged=True, max_queue=3)[0]


def test_run_load_report(paged_engine):
    cfg = TraceConfig(
        seed=21, n_requests=18, mean_interarrival_ticks=1.0, burstiness=5.0,
        prompt_len_mean=7.0, prompt_len_max=20,
        new_tokens_mean=4.0, new_tokens_max=8,
        tiers=CFG.tiers,
        prefix_populations=(PrefixPopulation("sys", prefix_len=8),),
        prefix_share_p=0.5)
    trace = generate_trace(cfg)
    slo = SLO(ttft_ticks=30, gap_ticks=6)
    report = run_load(paged_engine, trace, slo)
    ov = report["overall"]
    assert ov["n_offered"] == cfg.n_requests
    # conservation: asserted inside run_load too, re-checked here
    assert (ov["n_finished"] + ov["n_shed"] + ov["n_dropped"]
            + ov["n_incomplete"] == ov["n_offered"])
    # a 1-tick-mean burst against 2 slots + queue of 3 must shed
    assert ov["n_shed"] > 0, "overload did not shed — queue bound inert"
    # per-tier sections partition the overall one
    for key in ("n_offered", "n_finished", "n_shed", "n_dropped",
                "n_slo_met"):
        assert sum(t[key] for t in report["tiers"].values()) == ov[key], key
    assert ov["n_slo_met"] <= ov["n_finished"]
    if ov["n_finished"]:
        assert 0.0 <= ov["slo_attainment"] <= 1.0
    assert report["pool"]["hit_rate"] > 0, "prefix population never hit"
    assert report["trace"]["digest"] == trace.digest()
    # goodput counts SLO-met requests only
    if report["wall_s"] > 0:
        assert ov["goodput_requests_per_s"] == pytest.approx(
            ov["n_slo_met"] / report["wall_s"])


def test_slo_met_logic():
    r = EngineRequest(uid=0, prompt=np.ones(1, np.int32), max_new_tokens=4)
    slo = SLO(ttft_ticks=10, gap_ticks=3)
    assert not slo.met(r)                      # not done
    r.done = True
    r.submit_tick, r.first_token_tick = 5, 14  # ttft 9 <= 10
    r.max_gap_ticks = 3
    assert slo.met(r)
    r.max_gap_ticks = 4
    assert not slo.met(r)
    r.first_token_tick = 16                    # ttft 11 > 10
    r.max_gap_ticks = 0
    assert not slo.met(r)
