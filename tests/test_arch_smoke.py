"""Per-architecture smoke tests: REDUCED configs (same block structure,
tiny dims) run one forward/train step on CPU asserting output shapes and
no NaNs — one test per assigned architecture, as required.

Full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and tests/test_dryrun_machinery.py.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_reduced, list_configs
from repro.models.encdec import EncDec
from repro.models.lm import LM

ALL_ARCHS = list_configs()


def _build(cfg):
    return EncDec(cfg) if cfg.n_encoder_layers else LM(cfg)


def _batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_encoder_layers:
        batch["src_embeds"] = jax.random.normal(key, (b, s, cfg.d_model),
                                                jnp.float32)
    elif cfg.frontend == "embeds":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_dims_exact(arch):
    """The assigned numbers, verbatim."""
    cfg = get_config(arch)
    expected = {
        "zamba2-7b": (3584, 32, 32, 14336, 32000, 81),
        "seamless-m4t-medium": (1024, 16, 16, 4096, 256206, 24),
        "qwen2-moe-a2.7b": (2048, 16, 16, 1408, 151936, 24),
        "deepseek-v2-lite-16b": (2048, 16, 16, 1408, 102400, 27),
        "phi3-mini-3.8b": (3072, 32, 32, 8192, 32064, 32),
        "stablelm-12b": (5120, 32, 8, 13824, 100352, 40),
        "minitron-4b": (3072, 24, 8, 9216, 256000, 32),
        "gemma3-1b": (1152, 4, 1, 6912, 262144, 26),
        "pixtral-12b": (5120, 32, 8, 14336, 131072, 40),
        "mamba2-370m": (1024, 1, 1, 0, 50280, 48),
    }[arch]
    got = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab,
           cfg.n_layers)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_arch_specials():
    assert get_config("zamba2-7b").ssm.state == 64
    assert get_config("mamba2-370m").ssm.state == 128
    qw = get_config("qwen2-moe-a2.7b").moe
    assert (qw.n_routed, qw.top_k, qw.n_routed_padded) == (60, 4, 64)
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.moe.top_k == 6 and ds.mla.kv_lora_rank == 512
    g3 = get_config("gemma3-1b")
    assert g3.window is not None and g3.plan.period.count(
        g3.plan.period[-1]) == 1  # 5 local : 1 global
    # long_500k runs only for sub-quadratic archs
    runs_long = {a for a in ALL_ARCHS
                 if "long_500k" not in get_config(a).skip_shapes}
    assert runs_long == {"zamba2-7b", "gemma3-1b", "mamba2-370m"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = _build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # one grad step moves params and stays finite
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_prefill_decode_match_forward(arch):
    """Teacher-forcing consistency: prefill + step-by-step decode must equal
    the full causal forward at every position (exact for no-drop MoE)."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    b, s, s0 = 2, 24, 16
    if cfg.n_encoder_layers:
        model = EncDec(cfg)
        params = model.init_params(key)
        src = jax.random.normal(key, (b, 8, cfg.d_model), jnp.float32)
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
        enc_out = model.encode(params, src, remat=False)
        h, _, _ = model._decode_trunk(
            params, params["embed"][toks].astype(jnp.float32), mode="train",
            caches=None, lengths=None, enc_out=enc_out, enc_lengths=None,
            cache_cap=None, remat=False)
        full_logits = jnp.einsum("bsd,dv->bsv", h,
                                 params["lm_head"].astype(h.dtype))
        lg, caches, lengths = model.prefill(
            params, {"src_embeds": src, "tokens": toks[:, :s0]}, cache_cap=s)
        errs = [float(jnp.abs(lg - full_logits[:, s0 - 1]).max())]
        enc_lengths = jnp.full((b,), 8, jnp.int32)
        for t in range(s0, s):
            lg, caches = model.decode_step(params, toks[:, t], caches,
                                           lengths, enc_lengths)
            lengths = lengths + 1
            errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    else:
        model = LM(cfg)
        params = model.init_params(key)
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
        batch = {"tokens": toks}
        if cfg.frontend == "embeds":
            batch["embeds"] = params["embed"][toks].astype(jnp.float32)
        h, _, _ = model.forward(params, batch, mode="train", remat=False)
        full_logits = model._head(params, h)
        pre = {"tokens": toks[:, :s0]}
        if cfg.frontend == "embeds":
            pre["embeds"] = batch["embeds"][:, :s0]
        lg, caches, lengths = model.prefill(params, pre, cache_cap=s)
        errs = [float(jnp.abs(lg - full_logits[:, s0 - 1]).max())]
        for t in range(s0, s):
            lg, caches = model.decode_step(params, toks[:, t], caches, lengths)
            lengths = lengths + 1
            errs.append(float(jnp.abs(lg - full_logits[:, t]).max()))
    assert max(errs) < 5e-3, f"{arch}: decode diverges ({max(errs):.2e})"


@pytest.mark.parametrize("arch", ["gemma3-1b", "zamba2-7b", "mamba2-370m"])
def test_reduced_long_context_decode_constant_state(arch):
    """The long_500k-capable archs: cache/state size must not grow with
    decode steps (rolling local windows, O(1) SSM state)."""
    cfg = get_reduced(arch)
    model = LM(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init_params(key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    _, caches, lengths = model.prefill(params, {"tokens": toks}, cache_cap=64)
    size0 = sum(x.size for x in jax.tree.leaves(caches))
    for t in range(5):
        lg, caches = model.decode_step(
            params, jnp.asarray([t % cfg.vocab]), caches, lengths)
        lengths = lengths + 1
        assert jnp.all(jnp.isfinite(lg))
    assert sum(x.size for x in jax.tree.leaves(caches)) == size0


def test_param_counts_plausible():
    """Sanity: headline param counts within 40% of the names."""
    expect = {"zamba2-7b": 7e9, "phi3-mini-3.8b": 3.8e9, "stablelm-12b": 12e9,
              "minitron-4b": 4e9, "pixtral-12b": 12e9, "mamba2-370m": 370e6,
              "gemma3-1b": 1e9, "deepseek-v2-lite-16b": 16e9,
              "qwen2-moe-a2.7b": 14e9}
    for arch, n in expect.items():
        total = get_config(arch).param_count()["total"]
        assert 0.6 * n < total < 1.65 * n, f"{arch}: {total:.2e} vs {n:.2e}"
