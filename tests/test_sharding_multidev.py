"""Multi-device sharding tests — run in SUBPROCESSES with
--xla_force_host_platform_device_count (the main test process keeps the
real single CPU device, per the dry-run isolation rule).

Covers: TP/DP train-step numerics vs single-device, tree-decode
(sequence-parallel) vs dense decode, compressed DP all-reduce, ring
all-gather matmul, and the dry-run cell machinery on a small mesh.
"""

from conftest import multidev, run_sub


PREAMBLE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
"""


@multidev
def test_sharded_train_step_matches_single_device():
    run_sub(PREAMBLE + """
from repro.configs import get_reduced
from repro.models.lm import LM
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.runtime.train import make_train_step, train_state_shardings
from repro.data import SyntheticLM

cfg = get_reduced("stablelm-12b")
model = LM(cfg)
opt_cfg = AdamWConfig(lr=1e-3)
params = model.init_params(jax.random.PRNGKey(0))
state = adamw.init(params, opt_cfg)
ds = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=4, seed=2)
batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

# single-device reference
step1 = make_train_step(model, cfg, opt_cfg, donate=False)
p1, s1, m1 = step1(params, state, batch)

# sharded
with mesh:
    stepN = make_train_step(model, cfg, opt_cfg, mesh=mesh,
                            batch_example=batch, donate=False)
    pN, sN, mN = stepN(params, state, batch)
assert abs(float(m1["loss"]) - float(mN["loss"])) < 1e-4, (m1["loss"], mN["loss"])
err = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
          for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)))
assert err < 1e-4, err
# moments actually sharded (ZeRO-1): some leaf has a non-trivial sharding
sharded = [x for x in jax.tree.leaves(sN["mu"])
           if not x.sharding.is_fully_replicated]
assert sharded, "no optimizer moment is sharded"
print("OK train", err)
""")


@multidev
def test_tree_decode_matches_dense():
    run_sub(PREAMBLE + """
from repro.sharding.collectives import tree_decode_attention
from repro.kernels.ref import decode_attention_ref
rng = np.random.default_rng(0)
b, skv, hq, hkv, d = 2, 64, 4, 2, 16
q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
v = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), jnp.float32)
lens = jnp.asarray([40, 64], jnp.int32)
ref = decode_attention_ref(q, k, v, lens)
with mesh:
    out = tree_decode_attention(mesh, q, k, v, lens, axis="data", backend="ref")
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err
print("OK tree-decode", err)
""")


@multidev
def test_compressed_psum_and_ring_matmul():
    run_sub(PREAMBLE + """
from repro.optim.compress import compressed_psum_mean
from repro.sharding.collectives import ring_allgather_matmul
rng = np.random.default_rng(1)
grads = {"a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((4,)), jnp.float32)}
errs = jax.tree.map(jnp.zeros_like, grads)
with mesh:
    fn = compressed_psum_mean(mesh, axis="data")
    mean, new_err = fn(grads, errs)
# all shards identical input => mean == dequantised input, err small
for k in grads:
    rel = float(jnp.abs(mean[k] - grads[k]).max() / jnp.abs(grads[k]).max())
    assert rel < 0.02, (k, rel)

x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
w = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
with mesh:
    y = ring_allgather_matmul(mesh, x, w, axis="model")
err = float(jnp.abs(y - x @ w).max())
assert err < 1e-4, err
print("OK compress+ring", err)
""")


@multidev
def test_dryrun_cell_machinery_small_mesh():
    """build_cell -> lower -> compile -> cost/memory/collective parse, on a
    (2,4) mesh with reduced configs — the dry-run pipeline end-to-end."""
    run_sub(PREAMBLE + """
import dataclasses
from repro.configs import get_reduced
from repro.configs.base import ShapeCfg
from repro.launch.cells import build_cell
from repro.tools.roofline import analyze, collective_bytes, model_flops_for

for name in ["stablelm-12b", "qwen2-moe-a2.7b", "mamba2-370m"]:
    cfg = get_reduced(name)
    cfg = dataclasses.replace(cfg, shapes=(ShapeCfg("t", "train", 32, 4),))
    with mesh:
        cell = build_cell(name, "t", mesh, cfg=cfg)
        co = cell.step.lower(*cell.args).compile()
        cost = co.cost_analysis()
        hlo = co.as_text()
    wire, per_type, counts = collective_bytes(hlo, 8)
    assert cost.get("flops", 0) > 0
    assert wire > 0, "expected collectives in a sharded train step"
    rep = analyze(cell.name, "test", 8, cost, hlo,
                  model_flops=model_flops_for(cfg, "train", 32, 4))
    assert rep.bottleneck in ("compute", "memory", "collective")
    print("OK", name, rep.bottleneck, counts)
""")


@multidev
def test_elastic_reshard_across_meshes():
    """Save on a (2,4) mesh, restore onto (4,2) and (8,1) — values equal."""
    run_sub(PREAMBLE + """
import tempfile, os
from repro.checkpoint import io as ckpt_io
rng = np.random.default_rng(2)
state = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
sh1 = {"w": NamedSharding(mesh, P("data", "model"))}
state1 = jax.device_put(state, sh1)
with tempfile.TemporaryDirectory() as td:
    ckpt_io.save(td, 1, state1)
    for shape, axes in [((4, 2), ("data", "model")), ((8, 1), ("data", "model"))]:
        mesh2 = jax.make_mesh(shape, axes,
                              axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
        target = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
        r = ckpt_io.restore(td, target, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(state["w"]))
        assert r["w"].sharding == sh2["w"]
print("OK elastic")
""")
