"""Fused RMSNorm (+ optional residual add) Pallas kernel.

RMSNorm is bandwidth-bound; unfused XLA lowering reads x twice (once for the
mean-square reduction, once for the scale) and writes the residual sum
separately.  The kernel does residual-add + reduce + normalise + scale in one
VMEM pass: each grid step owns a (rows, D) block, so every HBM byte is
touched exactly once.

Grid = (R / block_rows,); the full feature dim D stays resident (all our
archs have D ≤ 5120 → ≤ 2.6 MB f32 per 128-row block, fine for VMEM).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, r_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            residual: Optional[jax.Array] = None, block_rows: int = 256,
            interpret: bool = False) -> jax.Array:
    """x (..., D), w (D,) -> (..., D); optionally normalises (x + residual)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    # pad rows to a block multiple (cheap; avoids ragged grids)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    r2 = None
    if residual is not None:
        r2 = residual.reshape(rows, d)
        if pad:
            r2 = jnp.pad(r2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // br

    row_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    w_spec = pl.BlockSpec((d,), lambda i: (0,))
    if residual is None:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=(n_blocks,),
            in_specs=[row_spec, w_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
            interpret=interpret, name="rmsnorm",
        )(x2, w)
    else:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_res_kernel, eps=eps),
            grid=(n_blocks,),
            in_specs=[row_spec, row_spec, w_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
            interpret=interpret, name="rmsnorm_residual",
        )(x2, r2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
