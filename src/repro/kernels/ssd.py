"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight (Dao & Gu, 2024): a scalar-decay SSM over a chunk of Q steps
equals a small masked "attention" inside the chunk plus a rank-stable state
carried between chunks.  That maps perfectly onto the TPU: the intra-chunk
part is three MXU matmuls of shape (Q,N)x(N,Q), (Q,Q)x(Q,P), (Q,N)^T x (Q,P),
and the inter-chunk carry is a sequential grid axis with the (P,N) state
held in VMEM scratch — no HBM round-trip for the state, ever.

Grid = (B, H, S/Q); the chunk axis is innermost/sequential.  B/C projections
are shared across heads in a group (G groups) and are read through index
maps — never materialised per-head in HBM.

Decay math is done in log space: the kernel receives la = dt * A (negative)
and uses exp(cumsum) differences, which is exact and underflow-safe.

All compute f32; inputs may be bf16.  The final SSM state (B,H,P,N) is also
emitted so prefill can hand off to step-wise decode.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["ssd_scan"]


def _ssd_kernel(xbar_ref, la_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xb = xbar_ref[0, :, 0].astype(jnp.float32)    # (Q, P)
    la = la_ref[0, :, 0:1].astype(jnp.float32)    # (Q, 1) log-decay
    Bc = b_ref[0, :, 0].astype(jnp.float32)       # (Q, N)
    Cc = c_ref[0, :, 0].astype(jnp.float32)       # (Q, N)

    cs = jnp.cumsum(la, axis=0)                   # (Q, 1) inclusive log decay
    # intra-chunk: y[i] = sum_{j<=i} exp(cs_i - cs_j) (C_i . B_j) xbar_j
    smat = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (Q,Q)
    dec = cs - cs.T                               # (Q, Q): cs_i - cs_j
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(cols <= rows, jnp.exp(dec), 0.0)
    y_intra = jax.lax.dot_general(smat * L, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q,P)
    # inter-chunk: y[i] += exp(cs_i) * C_i @ state^T   (state: (P,N))
    y_inter = jax.lax.dot_general(Cc, state_ref[...], (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * jnp.exp(cs)
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state' = exp(cs_last) * state + sum_j exp(cs_last - cs_j) xbar_j B_j^T
    w = jnp.exp(cs[-1:] - cs)                     # (Q, 1)
    state_ref[...] = (state_ref[...] * jnp.exp(cs[-1, 0])
                      + jax.lax.dot_general(xb * w, Bc, (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, D: Optional[jax.Array] = None, *,
             chunk: int = 128, interpret: bool = False
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Shapes as in ``ref.ssd_ref``:
    x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N) -> y (B,S,H,P),
    final state (B,H,P,N)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0
    hpg = h // g
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} must divide chunk {q}"
    nc = s // q

    # precompute in plain JAX (cheap, elementwise): log-decay & dt-scaled x
    la = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]  # (B,S,H)
    xbar = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]    # (B,S,H,P)

    kernel = functools.partial(_ssd_kernel, chunk=q, n_chunks=nc)
    # grid (B, H, nc); chunk axis innermost => sequential state carry
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, q, 1, n), lambda bi, hi, ci, _hpg=hpg: (bi, ci, hi // _hpg, 0)),
            pl.BlockSpec((1, q, 1, n), lambda bi, hi, ci, _hpg=hpg: (bi, ci, hi // _hpg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        name="ssd_scan",
    )(xbar, la, B, C)
    if D is not None:
        y = (y.astype(jnp.float32)
             + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
             ).astype(x.dtype)
    return y, state
