"""Flash attention (prefill/training-forward) as a Pallas TPU kernel.

Blockwise-softmax attention tiled for VMEM: the query block, one KV block,
and the f32 accumulator live in VMEM; the (Sq x Skv) score matrix is never
materialised in HBM.  Grid = (batch*q_heads, Sq/bq, Skv/bkv) with the KV
axis innermost — TPU grid iteration is sequential, so the running max /
sum-of-exp / accumulator scratch carries across KV blocks of one query block
(the classic online-softmax recurrence).

GQA is handled by index maps (each q head reads its kv head h // group);
KV is never materialised repeated.  Causal and sliding-window masks skip
fully-masked KV blocks with ``pl.when`` (no MXU work issued for them).

Block sizes default to (bq, bkv) = (256, 512), clamped to the sequence
lengths; head_dim is used as-is (Mosaic pads the lane dim to 128 — full MXU
efficiency needs D % 128 == 0, true for 7/10 assigned archs; see DESIGN.md).

VMEM budget at defaults, D=128, f32 scratch: q 256x128x4 + kv 2x512x128x4
+ acc 256x128x4 + m/l 2x256x128x4 ≈ 1.0 MB — comfortably inside 16 MB, and
Pallas double-buffers the KV streams automatically.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["flash_attention", "flash_chunk_attention",
           "flash_paged_chunk_attention"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bkv: int, n_kv_blocks: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this block's first row/col
    row0 = qi * bq + q_offset
    col0 = ki * bkv
    # any (row, col) pair live in this block?
    live = jnp.bool_(True)
    if causal:
        live &= (row0 + bq - 1) >= col0          # max row reaches min col
    if window is not None:
        live &= (col0 + bkv - 1) > (row0 - window)  # max col inside min row's window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        v = v_ref[0].astype(jnp.float32)                  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bkv)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (bq, bkv)
        l_ref[:, :1] = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 256, block_kv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, Hq, D), k/v (B, Skv, Hkv, D) -> (B, Sq, Hq, D).

    Query row i sits at absolute position (Skv - Sq + i), matching
    ``ref.attention_ref`` (relevant for chunked prefill where Sq < Skv).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[3]  # may differ from d (MLA: qk_dim 192 vs v_dim 128)
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (
        f"seq lengths must divide block sizes: {sq}%{bq}, {skv}%{bkv}")
    nq, nkv = sq // bq, skv // bkv

    # (B, S, H, D) -> (B*H, S, D): head-major layout keeps index maps trivial.
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dv)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bkv=bkv, n_kv_blocks=nkv, q_offset=skv - sq)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bkv, d), kv_map),
            pl.BlockSpec((1, bkv, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (col 0 used)
            pltpu.VMEM((bq, 128), jnp.float32),  # running sum-of-exp
        ],
        interpret=interpret,
        name="flash_attention",
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, dv).transpose(0, 2, 1, 3)


def _chunk_flash_kernel(start_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *,
                        scale: float, bq: int, bkv: int, n_kv_blocks: int,
                        ksc_ref=None, vsc_ref=None):
    """Same online-softmax recurrence as :func:`_flash_kernel`, with the
    query offset a per-sequence runtime value: query row t of the chunk
    sits at absolute position ``start + t`` and attends cache columns
    ``<= start + t`` (offset-causal).  ``start`` arrives via SMEM."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[0]
    row0 = start + qi * bq                      # abs position of block row 0
    col0 = ki * bkv
    # the block is live iff its max row position reaches its min column
    live = (row0 + bq - 1) >= col0

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        if ksc_ref is not None:
            # int8 tiles: dequantize in-register with the per-(page, head)
            # scalar that rode along in SMEM — no fp32 cache copy exists
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bkv)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(cols <= rows, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[:, :1] = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          start: jax.Array, *,
                          scale: Optional[float] = None,
                          block_q: int = 256, block_kv: int = 512,
                          interpret: bool = False) -> jax.Array:
    """Chunked-prefill flash attention against a KV cache.

    q (B, T, Hq, D), k/v (B, S, Hk, D), start (B,) int32 -> (B, T, Hq, D).
    Query row t of sequence b is at absolute position ``start[b] + t`` and
    attends cache keys at positions ``<= start[b] + t`` — the contract of
    the ``chunk_attention`` serving op.  Every query sees at least column
    0 (start >= 0), so the softmax is never empty.
    """
    b, t, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    dv = v.shape[3]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    bq = min(block_q, t)
    bkv = min(block_kv, s)
    assert t % bq == 0 and s % bkv == 0, (
        f"chunk/cache lengths must divide block sizes: {t}%{bq}, {s}%{bkv}")
    nq, nkv = t // bq, s // bkv

    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, t, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, dv)
    start_r = jnp.repeat(start.astype(jnp.int32), hq)        # (B*Hq,)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    kernel = functools.partial(_chunk_flash_kernel, scale=scale,
                               bq=bq, bkv=bkv, n_kv_blocks=nkv)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, qi, ki: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bkv, d), kv_map),
            pl.BlockSpec((1, bkv, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, t, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (col 0 used)
            pltpu.VMEM((bq, 128), jnp.float32),  # running sum-of-exp
        ],
        interpret=interpret,
        name="flash_chunk_attention",
    )(start_r, qr, kr, vr)
    return out.reshape(b, hq, t, dv).transpose(0, 2, 1, 3)


# --------------------------------------------------------------------------- #
# Paged chunk-prefill flash attention — the KV "block" of grid step pi is
# PHYSICAL page block_tables[b, pi], reached via a scalar-prefetched index
# map (same trick as flash_decode.flash_paged_decode); the dense gather
# copy the ref/xla paged backends pay never exists here.  Optional int8
# mode: per-(page, head) scales ride along in SMEM through the same table
# indices and dequant happens in-register inside the online-softmax loop.
# --------------------------------------------------------------------------- #

def _paged_chunk_kernel(bt_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *,
                        scale: float, bq: int, page: int, n_pages: int):
    del bt_ref                     # consumed by the index maps
    _chunk_flash_kernel(start_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, scale=scale, bq=bq,
                        bkv=page, n_kv_blocks=n_pages)


def _paged_chunk_q_kernel(bt_ref, start_ref, q_ref, k_ref, ksc_ref, v_ref,
                          vsc_ref, o_ref, acc_ref, m_ref, l_ref, *,
                          scale: float, bq: int, page: int, n_pages: int):
    del bt_ref
    _chunk_flash_kernel(start_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, scale=scale, bq=bq,
                        bkv=page, n_kv_blocks=n_pages,
                        ksc_ref=ksc_ref, vsc_ref=vsc_ref)


def flash_paged_chunk_attention(q: jax.Array, pages_k: jax.Array,
                                pages_v: jax.Array, block_tables: jax.Array,
                                start: jax.Array, *,
                                k_scales: Optional[jax.Array] = None,
                                v_scales: Optional[jax.Array] = None,
                                scale: Optional[float] = None,
                                block_q: int = 256,
                                interpret: bool = False) -> jax.Array:
    """Chunked-prefill flash attention reading K/V through block tables.

    q (B, T, Hq, D), pages_k/v (N, P, Hk, D), block_tables (B, MP) int32,
    start (B,) int32 -> (B, T, Hq, D).  Query row t of sequence b sits at
    absolute position ``start[b] + t`` and attends cache positions
    ``<= start[b] + t`` — the ``paged_chunk_attention`` op contract.
    Offset-causal masking covers garbage table entries: logical pages past
    the chunk's frontier are wholly masked, so they may hold any valid
    block id.

    With ``k_scales``/``v_scales`` ((N, Hk) float32) the pages are int8
    and each (page, head) tile is dequantized in-register."""
    b, t, hq, d = q.shape
    n_blocks, page, hkv = pages_k.shape[0], pages_k.shape[1], pages_k.shape[2]
    dv = pages_v.shape[3]
    n_pages = block_tables.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    quant = k_scales is not None
    assert quant == (v_scales is not None), "need both k_scales and v_scales"
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    bq = min(block_q, t)
    assert t % bq == 0, f"chunk length must divide block_q: {t} % {bq}"
    nq = t // bq

    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, t, d)
    # pages: (N, P, Hk, D) -> head-major (N*Hk, P, D) so one (block, head)
    # pair is a contiguous (P, D) tile the index map can address directly
    kr = pages_k.transpose(0, 2, 1, 3).reshape(n_blocks * hkv, page, d)
    vr = pages_v.transpose(0, 2, 1, 3).reshape(n_blocks * hkv, page, dv)
    start_r = jnp.repeat(start.astype(jnp.int32), hq)           # (B*Hq,)
    tables = jnp.clip(block_tables, 0, n_blocks - 1).astype(jnp.int32)

    def q_map(bh, qi, pi, bt):
        return (bh, qi, 0)

    def kv_map(bh, qi, pi, bt):
        # physical (block, head) row: sequence bh//Hq, kv head of q head
        return (bt[bh // hq, pi] * hkv + (bh % hq) // group, 0, 0)

    def sc_map(bh, qi, pi, bt):
        return (bt[bh // hq, pi] * hkv + (bh % hq) // group,)

    in_specs = [
        pl.BlockSpec((1,), lambda bh, qi, pi, bt: (bh,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, bq, d), q_map),
        pl.BlockSpec((1, page, d), kv_map),
        pl.BlockSpec((1, page, dv), kv_map),
    ]
    operands = [start_r, qr, kr, vr]
    if quant:
        sc_spec = pl.BlockSpec((1,), sc_map, memory_space=pltpu.SMEM)
        in_specs = in_specs[:3] + [sc_spec, in_specs[3], sc_spec]
        operands = [start_r, qr, kr,
                    jnp.asarray(k_scales, jnp.float32).reshape(-1),
                    vr, jnp.asarray(v_scales, jnp.float32).reshape(-1)]
        body = _paged_chunk_q_kernel
    else:
        body = _paged_chunk_kernel

    kernel = functools.partial(body, scale=scale, bq=bq, page=page,
                               n_pages=n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # the block table
        grid=(b * hq, nq, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (col 0 used)
            pltpu.VMEM((bq, 128), jnp.float32),  # running sum-of-exp
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, t, dv), q.dtype),
        interpret=interpret,
        name=("flash_paged_chunk_attention_q" if quant
              else "flash_paged_chunk_attention"),
    )(tables, *operands)
    return out.reshape(b, hq, t, dv).transpose(0, 2, 1, 3)
