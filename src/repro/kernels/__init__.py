"""Pallas TPU kernels for the performance-critical ops, each with a pure-jnp
oracle (:mod:`repro.kernels.ref`) and a registry-integrated jit'd wrapper
(:mod:`repro.kernels.ops`).

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU via ``interpret=True``.
"""

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_partial
from repro.kernels.gemm import batched_gemm, gemm
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.ssd import ssd_scan

__all__ = [
    "flash_attention", "flash_decode", "flash_decode_partial",
    "batched_gemm", "gemm", "rmsnorm_kernel", "ssd_scan",
]
