"""MXU-tiled GEMM Pallas kernels (plain and batched).

This is the compute backbone of the paper's "GEMM convolution" backend
re-thought for the TPU: blocking is chosen for the 128x128x128 MXU and the
HBM->VMEM pipeline instead of ARM L1 tiles.

* ``gemm``:          (M, K) @ (K, N), grid (M/bm, N/bn, K/bk), f32 accumulator
                     in VMEM scratch, K innermost so the accumulator stays
                     resident while A/B tiles stream (Pallas double-buffers).
* ``batched_gemm``:  (E, M, K) @ (E, K, N) — one extra grid axis; used for
                     MoE expert GEMMs after capacity-bucketed dispatch.

Defaults (bm, bn, bk) = (256, 256, 512): A tile 512 KB + B tile 512 KB +
acc 256 KB (f32) ≈ 1.3 MB live, x2 for double buffering — well within the
16 MB VMEM of a v5e core, while every matmul dim is a multiple of 128.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["gemm", "batched_gemm"]


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, k_axis: int):
    ki = pl.program_id(k_axis)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...] if x_ref.ndim == 2 else x_ref[0]
    w = w_ref[...] if w_ref.ndim == 2 else w_ref[0]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        if o_ref.ndim == 2:
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)
        else:
            o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _round_block(dim: int, block: int) -> int:
    return min(block, dim)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, pad)
    return jnp.pad(x, pads)


def gemm(x: jax.Array, w: jax.Array, *, block_m: int = 256,
         block_n: int = 256, block_k: int = 512,
         interpret: bool = False) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) in x.dtype, f32 accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = (_round_block(m, block_m), _round_block(n, block_n),
                  _round_block(k, block_k))
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=gk, k_axis=2),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret, name="gemm",
    )(xp, wp)
    return out[:m, :n]


def batched_gemm(x: jax.Array, w: jax.Array, *, block_m: int = 256,
                 block_n: int = 256, block_k: int = 512,
                 interpret: bool = False) -> jax.Array:
    """(E, M, K) @ (E, K, N) -> (E, M, N). Grid (E, M/bm, N/bn, K/bk)."""
    e, m, k = x.shape
    e2, k2, n = w.shape
    assert e == e2 and k == k2, (x.shape, w.shape)
    bm, bn, bk = (_round_block(m, block_m), _round_block(n, block_n),
                  _round_block(k, block_k))
    xp = _pad_to(_pad_to(x, 1, bm), 2, bk)
    wp = _pad_to(_pad_to(w, 1, bk), 2, bn)
    gm, gn, gk = xp.shape[1] // bm, wp.shape[2] // bn, xp.shape[2] // bk

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=gk, k_axis=3),
        grid=(e, gm, gn, gk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda ee, i, j, kk: (ee, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda ee, i, j, kk: (ee, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda ee, i, j, kk: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, xp.shape[1], wp.shape[2]), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret, name="batched_gemm",
    )(xp, wp)
    return out[:, :m, :n]
