"""Kernel wrappers + registry integration — ties Pallas kernels into the
Orpheus backend registry.

This module (imported by ``import repro``):

1. declares the LM "macro ops" (attention, decode_attention, rmsnorm, ssd,
   moe_gemm, swiglu) with shape + analytic cost models,
2. registers their ``ref`` backends (the jnp oracles — differentiable, used
   by training and by the dry-run) and their ``pallas`` backends (the TPU
   kernels — the inference hot path, validated in interpret mode on CPU),
3. registers ``pallas`` backends for the existing graph ops ``conv2d`` /
   ``dense`` (im2col + MXU-tiled GEMM — the paper's GEMM convolution), and
4. exposes plain-function dispatchers (``attention(...)``,
   ``rmsnorm(...)``, …) used by :mod:`repro.layers`.

Pallas kernels execute via ``interpret=True`` automatically when the
default JAX backend is CPU (this container); on TPU they compile to Mosaic.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ir import TensorSpec
from repro.core.registry import Cost, defop, get_impl, impl
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_partial
from repro.kernels.gemm import batched_gemm as _batched_gemm_kernel
from repro.kernels.gemm import gemm as _gemm_kernel
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm_kernel
from repro.kernels.ssd import ssd_scan as _ssd_kernel

__all__ = [
    "attention", "decode_attention", "decode_attention_partial", "rmsnorm",
    "ssd", "ssd_step", "moe_gemm", "swiglu", "pallas_interpret",
]


def pallas_interpret() -> bool:
    """Interpret Pallas on CPU (this container); compile on TPU."""
    return jax.default_backend() == "cpu"


def _bytes(specs: Sequence[TensorSpec]) -> float:
    return float(sum(s.nbytes for s in specs))


# --------------------------------------------------------------------------- #
# attention (prefill / training forward)
# inputs: q (B,Sq,Hq,D), k (B,Skv,Hkv,D), v — attrs: causal, window, scale
# --------------------------------------------------------------------------- #

def _attn_shape(specs, attrs):
    q = specs[0]
    return [q]


def _attn_cost(specs, attrs):
    q, k = specs[0], specs[1]
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    causal_frac = 0.5 if attrs.get("causal", True) and sq == skv else 1.0
    if attrs.get("window") and attrs["window"] < skv:
        causal_frac = min(causal_frac, attrs["window"] / skv)
    flops = 4.0 * b * hq * sq * skv * d * causal_frac
    out_b = q.nbytes
    return Cost(flops=flops, bytes=_bytes(specs) + out_b)


defop("attention", _attn_shape, _attn_cost,
      doc="GQA flash-style attention; attrs: causal, window, scale")


@impl("attention", "ref")
def _attention_ref_impl(inputs, attrs):
    q, k, v = inputs
    return [R.attention_ref(q, k, v, causal=attrs.get("causal", True),
                            window=attrs.get("window"),
                            scale=attrs.get("scale"))]


def _attn_pallas_supports(specs, attrs):
    q, k = specs[0], specs[1]
    bq = min(int(attrs.get("block_q", 256)), q.shape[1])
    bkv = min(int(attrs.get("block_kv", 512)), k.shape[1])
    return q.shape[1] % bq == 0 and k.shape[1] % bkv == 0


@impl("attention", "pallas", supports=_attn_pallas_supports,
      note="blockwise online-softmax flash kernel; masked blocks skipped")
def _attention_pallas_impl(inputs, attrs):
    q, k, v = inputs
    return [flash_attention(
        q, k, v, causal=attrs.get("causal", True), window=attrs.get("window"),
        scale=attrs.get("scale"), block_q=int(attrs.get("block_q", 256)),
        block_kv=int(attrs.get("block_kv", 512)),
        interpret=attrs.get("interpret", pallas_interpret()))]


def attention(q, k, v, *, causal=True, window=None, scale=None,
              backend="ref", **kw):
    return get_impl("attention", backend)(
        [q, k, v], {"causal": causal, "window": window, "scale": scale, **kw})[0]


# --------------------------------------------------------------------------- #
# decode_attention — one token vs KV cache
# inputs: q (B,Hq,D), k/v (B,Skv,Hkv,D), lengths (B,)
# --------------------------------------------------------------------------- #

def _dec_shape(specs, attrs):
    return [specs[0]]


def _dec_cost(specs, attrs):
    q, k = specs[0], specs[1]
    b, hq, d = q.shape
    skv = k.shape[1]
    # memory term dominates: whole cache streamed once
    return Cost(flops=4.0 * b * hq * skv * d,
                bytes=_bytes(specs) + q.nbytes)


defop("decode_attention", _dec_shape, _dec_cost,
      doc="single-token attention vs KV cache; inputs (q, k, v, lengths)")


@impl("decode_attention", "ref")
def _decode_ref_impl(inputs, attrs):
    q, k, v, lengths = inputs
    return [R.decode_attention_ref(q, k, v, lengths, scale=attrs.get("scale"))]


def _dec_pallas_supports(specs, attrs):
    """Skv % block_kv == 0 (block clamped to the cache length)."""
    k = specs[1]
    bkv = min(int(attrs.get("block_kv", 512)), k.shape[1])
    return k.shape[1] % bkv == 0


@impl("decode_attention", "pallas", supports=_dec_pallas_supports,
      note="streaming flash-decode; GQA group shares one KV read")
def _decode_pallas_impl(inputs, attrs):
    q, k, v, lengths = inputs
    return [flash_decode(q, k, v, lengths, scale=attrs.get("scale"),
                         block_kv=int(attrs.get("block_kv", 512)),
                         interpret=attrs.get("interpret", pallas_interpret()))]


def _dec_split_supports(specs, attrs):
    """n_splits >= 2 dividing Skv into >= 8-row shards, each shard a
    multiple of its (clamped) block_kv."""
    k = specs[1]
    n_splits = int(attrs.get("n_splits", 2))
    skv = k.shape[1]
    if n_splits < 2 or skv % n_splits or skv // n_splits < 8:
        return False
    part = skv // n_splits
    return part % min(int(attrs.get("block_kv", 512)), part) == 0


def _dec_split_cost(specs, attrs):
    """Adds the combine overhead: per-split (acc, m, l) partials written
    then re-read by the exact merge."""
    q = specs[0]
    n_splits = int(attrs.get("n_splits", 2))
    base = _dec_cost(specs, attrs)
    partials = n_splits * (q.nbytes + 8.0 * q.shape[0] * q.shape[1])
    return Cost(flops=base.flops, bytes=base.bytes + 2.0 * partials)


@impl("decode_attention", "pallas_split", supports=_dec_split_supports,
      cost_fn=_dec_split_cost,
      note="split-KV flash-decode for long caches: per-shard partials via "
           "flash_decode_partial, combined exactly (ref.combine_partials_ref)")
def _decode_split_impl(inputs, attrs):
    q, k, v, lengths = inputs
    n_splits = int(attrs.get("n_splits", 2))
    skv = k.shape[1]
    part = skv // n_splits
    if lengths is None:
        lengths = jnp.full((q.shape[0],), skv, jnp.int32)
    outs, ms, ls = [], [], []
    for i in range(n_splits):
        ks = jax.lax.slice_in_dim(k, i * part, (i + 1) * part, axis=1)
        vs = jax.lax.slice_in_dim(v, i * part, (i + 1) * part, axis=1)
        len_i = jnp.clip(lengths - i * part, 0, part)
        o, m, l = flash_decode_partial(
            q, ks, vs, len_i, scale=attrs.get("scale"),
            block_kv=int(attrs.get("block_kv", 512)),
            interpret=attrs.get("interpret", pallas_interpret()))
        outs.append(o)
        ms.append(m)
        ls.append(l)
    combined = R.combine_partials_ref(
        jnp.stack(outs).astype(jnp.float32), jnp.stack(ms), jnp.stack(ls))
    return [combined.astype(q.dtype)]


def decode_attention(q, k, v, lengths=None, *, scale=None, backend="ref", **kw):
    return get_impl("decode_attention", backend)(
        [q, k, v, lengths], {"scale": scale, **kw})[0]


def decode_attention_partial(q, k, v, lengths=None, *, scale=None,
                             backend="pallas", **kw):
    """(acc, m, l) partials for cross-shard combination (tree decode)."""
    if backend == "pallas":
        return flash_decode_partial(
            q, k, v, lengths, scale=scale,
            block_kv=int(kw.get("block_kv", 512)),
            interpret=kw.get("interpret", pallas_interpret()))
    # ref partial: full softmax stats computed densely
    b, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale_ = (1.0 / math.sqrt(d)) if scale is None else scale
    kf = R._repeat_kv(k, hq).astype(jnp.float32)
    vf = R._repeat_kv(v, hq).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32) * scale_, kf)
    if lengths is not None:
        s = jnp.where(jnp.arange(skv)[None, None, :] < lengths[:, None, None],
                      s, R._NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhk,bkhd->bhd", p, vf).astype(q.dtype)
    return acc, m, l


# --------------------------------------------------------------------------- #
# rmsnorm — attrs: eps; inputs (x, w) or (x, w, residual)
# --------------------------------------------------------------------------- #

def _rms_shape(specs, attrs):
    return [specs[0]]


def _rms_cost(specs, attrs):
    x = specs[0]
    extra = specs[2].nbytes if len(specs) > 2 else 0
    return Cost(flops=3.0 * x.nelems, bytes=2.0 * x.nbytes + specs[1].nbytes + extra)


defop("rmsnorm", _rms_shape, _rms_cost,
      doc="RMSNorm with optional fused residual; inputs (x, w[, residual])")


@impl("rmsnorm", "ref")
def _rms_ref_impl(inputs, attrs):
    x, w = inputs[0], inputs[1]
    res = inputs[2] if len(inputs) > 2 else None
    return [R.rmsnorm_ref(x, w, eps=float(attrs.get("eps", 1e-6)), residual=res)]


@impl("rmsnorm", "pallas", note="single-pass fused residual+norm+scale")
def _rms_pallas_impl(inputs, attrs):
    x, w = inputs[0], inputs[1]
    res = inputs[2] if len(inputs) > 2 else None
    return [_rmsnorm_kernel(x, w, eps=float(attrs.get("eps", 1e-6)),
                            residual=res,
                            block_rows=int(attrs.get("block_rows", 256)),
                            interpret=attrs.get("interpret", pallas_interpret()))]


def rmsnorm(x, w, *, eps=1e-6, residual=None, backend="ref", **kw):
    inputs = [x, w] if residual is None else [x, w, residual]
    return get_impl("rmsnorm", backend)(inputs, {"eps": eps, **kw})[0]


# --------------------------------------------------------------------------- #
# ssd (Mamba2) — inputs (x, dt, A, B, C, D) -> (y, final_state)
# --------------------------------------------------------------------------- #

def _ssd_shape(specs, attrs):
    x, _, _, B = specs[0], specs[1], specs[2], specs[3]
    b, s, h, p = x.shape
    n = B.shape[3]
    return [x, TensorSpec((b, h, p, n), "float32")]


def _ssd_cost(specs, attrs):
    x, _, _, B = specs[0], specs[1], specs[2], specs[3]
    b, s, h, p = x.shape
    n = B.shape[3]
    q = int(attrs.get("chunk", 128))
    # intra: (Q,N)x(N,Q) + (Q,Q)x(Q,P); inter: (Q,N)x(N,P); state: (Q,P)x(Q,N)
    per_chunk = 2.0 * q * q * n + 2.0 * q * q * p + 4.0 * q * n * p
    flops = b * h * (s / q) * per_chunk
    return Cost(flops=flops, bytes=_bytes(specs) + x.nbytes)


defop("ssd", _ssd_shape, _ssd_cost,
      doc="Mamba2 SSD scan -> (y, final_state); attrs: chunk")


@impl("ssd", "ref", note="exact sequential recurrence (lax.scan)")
def _ssd_ref_impl(inputs, attrs):
    x, dt, A, B, C, D = inputs
    y, st = R.ssd_ref(x, dt, A, B, C, D)
    return [y, st]


def _ssd_pad_chunk(x, dt, B, C, q):
    """Pad seq to a chunk multiple with dt=0 steps — exactly state-preserving
    (decay exp(0·A)=1, contribution dt·x=0); padded outputs are discarded."""
    s = x.shape[1]
    pad = (-s) % q
    if pad == 0:
        return x, dt, B, C, s
    pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
    return (jnp.pad(x, pad4), jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            jnp.pad(B, pad4), jnp.pad(C, pad4), s)


@impl("ssd", "chunked", note="chunked SSD in jnp (matmul-form; XLA-fused)")
def _ssd_chunked_impl(inputs, attrs):
    x, dt, A, B, C, D = inputs
    q = min(int(attrs.get("chunk", 128)), x.shape[1])
    xp, dtp, Bp, Cp, s = _ssd_pad_chunk(x, dt, B, C, q)
    y, st = R.ssd_chunked_ref(xp, dtp, A, Bp, Cp, None, chunk=q)
    y = y[:, :s]
    if D is not None:
        y = (y.astype(jnp.float32)
             + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
             ).astype(x.dtype)
    return [y, st]


@impl("ssd", "pallas", note="chunked SSD kernel; state carried in VMEM across chunks")
def _ssd_pallas_impl(inputs, attrs):
    x, dt, A, B, C, D = inputs
    q = min(int(attrs.get("chunk", 128)), x.shape[1])
    xp, dtp, Bp, Cp, s = _ssd_pad_chunk(x, dt, B, C, q)
    y, st = _ssd_kernel(xp, dtp, A, Bp, Cp, None, chunk=q,
                        interpret=attrs.get("interpret", pallas_interpret()))
    y = y[:, :s]
    if D is not None:
        y = (y.astype(jnp.float32)
             + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
             ).astype(x.dtype)
    return [y, st]


def ssd(x, dt, A, B, C, D=None, *, chunk=128, backend="ref", **kw):
    y, st = get_impl("ssd", backend)([x, dt, A, B, C, D], {"chunk": chunk, **kw})
    return y, st


def ssd_step(x, dt, A, B, C, D, state):
    """Single decode step (always jnp; O(1) work, no kernel needed)."""
    return R.ssd_step_ref(x, dt, A, B, C, D, state)


# --------------------------------------------------------------------------- #
# moe_gemm — (E, C, d) @ (E, d, f): expert GEMMs after dispatch
# --------------------------------------------------------------------------- #

def _moe_gemm_shape(specs, attrs):
    x, w = specs
    return [TensorSpec((x.shape[0], x.shape[1], w.shape[2]), x.dtype)]


def _moe_gemm_cost(specs, attrs):
    x, w = specs
    e, c, d = x.shape
    f = w.shape[2]
    out_b = e * c * f * np.dtype(x.dtype).itemsize
    return Cost(flops=2.0 * e * c * d * f, bytes=_bytes(specs) + out_b)


defop("moe_gemm", _moe_gemm_shape, _moe_gemm_cost,
      doc="batched expert GEMM (E,C,d)@(E,d,f)")


@impl("moe_gemm", "ref")
def _moe_gemm_ref_impl(inputs, attrs):
    return [R.batched_gemm_ref(*inputs)]


@impl("moe_gemm", "pallas", note="grid (E, M/bm, N/bn, K/bk) batched MXU GEMM")
def _moe_gemm_pallas_impl(inputs, attrs):
    x, w = inputs
    return [_batched_gemm_kernel(
        x, w, block_m=int(attrs.get("block_m", 256)),
        block_n=int(attrs.get("block_n", 256)),
        block_k=int(attrs.get("block_k", 512)),
        interpret=attrs.get("interpret", pallas_interpret()))]


def moe_gemm(x, w, *, backend="ref", **kw):
    return get_impl("moe_gemm", backend)([x, w], kw)[0]


# --------------------------------------------------------------------------- #
# swiglu — elementwise silu(gate) * up (XLA fuses this well; ref only)
# --------------------------------------------------------------------------- #

defop("swiglu", lambda s, a: [s[0]],
      lambda s, a: Cost(flops=5.0 * s[0].nelems, bytes=_bytes(s) + s[0].nbytes),
      doc="silu(gate) * up")


@impl("swiglu", "ref")
def _swiglu_ref_impl(inputs, attrs):
    return [R.swiglu_ref(*inputs)]


def swiglu(gate, up, *, backend="ref", **kw):
    return get_impl("swiglu", backend)([gate, up], kw)[0]


# --------------------------------------------------------------------------- #
# pallas backends for the graph ops (conv2d / dense) — the paper's GEMM conv
# --------------------------------------------------------------------------- #

from repro.core import nnops as _nnops  # noqa: E402  (op declarations)


def _conv_pallas_supports(specs, attrs):
    return int(attrs.get("groups", 1)) == 1


@impl("conv2d", "pallas", supports=_conv_pallas_supports,
      note="GEMM convolution: im2col + MXU-tiled Pallas GEMM")
def _conv2d_pallas_impl(inputs, attrs):
    x, w = inputs
    kh, kw_, ci, co = w.shape
    stride = _nnops._pair(attrs.get("stride", 1))
    dilation = _nnops._pair(attrs.get("dilation", 1))
    pads = _nnops._conv_pads(attrs.get("padding", "SAME"), x.shape[1:3],
                             (kh, kw_), stride, dilation)
    cols = _nnops._im2col(x, (kh, kw_), stride, pads, dilation)
    n, oh, ow, kk = cols.shape
    out = _gemm_kernel(cols.reshape(n * oh * ow, kk), w.reshape(kk, co),
                       interpret=attrs.get("interpret", pallas_interpret()))
    return [out.reshape(n, oh, ow, co)]


impl("conv2d_fused", "pallas",
     supports=lambda specs, attrs: _conv_pallas_supports(specs[:2], attrs),
     note="GEMM conv + bias + act (epilogue in jnp)")(
         lambda inputs, attrs: [_nnops._act(
             _conv2d_pallas_impl(inputs[:2], attrs)[0] + inputs[2],
             attrs.get("act", "none"))])


@impl("dense", "pallas", note="MXU-tiled GEMM")
def _dense_pallas_impl(inputs, attrs):
    x, w = inputs
    lead = x.shape[:-1]
    out = _gemm_kernel(x.reshape(-1, x.shape[-1]), w,
                       interpret=attrs.get("interpret", pallas_interpret()))
    return [out.reshape(*lead, w.shape[-1])]
