"""Flash-decode: single-new-token attention against a long KV cache.

Decode attention is memory-bound (read the whole KV cache per step, O(1)
compute per byte), so the kernel's job is purely streaming: iterate KV
blocks through VMEM, maintain the online-softmax state, touch each cache
byte exactly once.

TPU adaptation of GPU "flash decoding":

* One grid row handles a whole **GQA group** — the ``group = Hq/Hkv`` query
  heads that share a kv head form the (group, D) q block, so the KV stream
  is read once per kv head, not once per q head, and the q rows give the
  MXU/VPU some sublane parallelism (group is 1..32 across our archs).
* Grid = (B * Hkv, Skv/bkv), KV axis innermost and sequential; acc/m/l
  scratch carries across KV blocks.
* Variable cache lengths are masked via a per-sequence length operand
  (block (1,1) int32 in SMEM).
* The kernel also emits its running (m, l) so callers can combine partials
  across devices — this is the building block of the sequence-parallel
  "tree decode" in ``repro/sharding/collectives.py`` (KV cache sharded over
  the data axis for long_500k; partials merged with a cheap psum).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["flash_decode", "flash_decode_partial", "flash_paged_decode"]

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, bkv: int, n_kv_blocks: int, emit_stats: bool,
                   ksc_ref=None, vsc_ref=None):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    live = (ki * bkv) < length  # block has at least one valid entry

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (group, D)
        k = k_ref[0].astype(jnp.float32)                # (bkv, D)
        v = v_ref[0].astype(jnp.float32)
        if ksc_ref is not None:
            # int8 tiles: dequantize in-register with the per-(page, head)
            # scalar that rode along in SMEM — no fp32 cache copy exists
            k = k * ksc_ref[0]
            v = v * vsc_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (group,bkv)
        cols = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < length, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[:, :1] = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        if emit_stats:
            # unnormalised partials: caller combines across KV shards
            o_ref[0] = acc_ref[...].astype(o_ref.dtype)
            m_out_ref[0] = m_ref[:, :1].astype(m_out_ref.dtype)
            l_out_ref[0] = l_ref[:, :1].astype(l_out_ref.dtype)
        else:
            l = jnp.maximum(l_ref[:, :1], 1e-30)
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
            m_out_ref[0] = m_ref[:, :1].astype(m_out_ref.dtype)
            l_out_ref[0] = l_ref[:, :1].astype(l_out_ref.dtype)


def _flash_decode(q, k, v, lengths, scale, block_kv, interpret, emit_stats):
    b, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[3]  # may differ from d (MLA absorbed decode: 576 vs 512)
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    bkv = min(block_kv, skv)
    assert skv % bkv == 0, (skv, bkv)
    nkv = skv // bkv

    if lengths is None:
        lengths = jnp.full((b,), skv, jnp.int32)
    # q: (B, Hq, D) -> (B*Hkv, group, D); kv: (B, Skv, Hkv, D) -> (B*Hkv, Skv, D)
    qr = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dv)
    len_r = jnp.repeat(lengths.astype(jnp.int32), hkv)  # (B*Hkv,)

    kernel = functools.partial(_decode_kernel, scale=scale, bkv=bkv,
                               n_kv_blocks=nkv, emit_stats=emit_stats)
    grid = (b * hkv, nkv)
    out, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ki: (bh,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, group, d), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bkv, dv), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, group, dv), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, group, 1), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, group, 1), lambda bh, ki: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, group, dv), q.dtype),
            jax.ShapeDtypeStruct((b * hkv, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, group, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, dv), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
        ],
        interpret=interpret,
        name="flash_decode",
    )(len_r, qr, kr, vr)
    out = out.reshape(b, hq, dv)
    m = m.reshape(b, hq)
    l = l.reshape(b, hq)
    return out, m, l


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: Optional[jax.Array] = None, *,
                 scale: Optional[float] = None, block_kv: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q (B, Hq, D), k/v (B, Skv, Hkv, D) -> (B, Hq, D), softmax-normalised."""
    out, _, _ = _flash_decode(q, k, v, lengths, scale, block_kv, interpret,
                              emit_stats=False)
    return out


def flash_decode_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: Optional[jax.Array] = None, *,
                         scale: Optional[float] = None, block_kv: int = 512,
                         interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalised flash partials (acc, m, l) over THIS device's KV shard —
    combine shards with ``ref.combine_partials_ref`` (exact)."""
    return _flash_decode(q, k, v, lengths, scale, block_kv, interpret,
                         emit_stats=True)


# --------------------------------------------------------------------------- #
# Paged flash decode — KV pages reached through a scalar-prefetched block
# table.  Same streaming recurrence as _decode_kernel, but the KV "block"
# of grid step pi is PHYSICAL page block_tables[b, pi]: the index map reads
# the prefetched table, so pages are DMA'd straight from wherever they live
# in the pool — the dense gather copy the ref/xla paged backends pay never
# exists here.  Garbage table entries (logical pages past a sequence's
# length, filled with 0 by the engine) are masked by the per-sequence
# length operand exactly like short caches in plain flash_decode.
# --------------------------------------------------------------------------- #

def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, m_out_ref, l_out_ref, acc_ref, m_ref, l_ref,
                         *, scale: float, page: int, n_pages: int):
    # bt_ref (the scalar-prefetched block table) is consumed by the index
    # maps; the compute body is the stock online-softmax recurrence with
    # one page per KV step.
    del bt_ref
    _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, scale=scale, bkv=page,
                   n_kv_blocks=n_pages, emit_stats=False)


def _paged_decode_q_kernel(bt_ref, len_ref, q_ref, k_ref, ksc_ref, v_ref,
                           vsc_ref, o_ref, m_out_ref, l_out_ref,
                           acc_ref, m_ref, l_ref,
                           *, scale: float, page: int, n_pages: int):
    # int8 variant: the per-(page, head) dequant scales follow the same
    # scalar-prefetched table indices as the K/V tiles, one SMEM scalar
    # per grid step; dequant happens inside _decode_kernel's compute body.
    del bt_ref
    _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, scale=scale, bkv=page,
                   n_kv_blocks=n_pages, emit_stats=False,
                   ksc_ref=ksc_ref, vsc_ref=vsc_ref)


def flash_paged_decode(q: jax.Array, pages_k: jax.Array, pages_v: jax.Array,
                       block_tables: jax.Array,
                       lengths: Optional[jax.Array] = None, *,
                       k_scales: Optional[jax.Array] = None,
                       v_scales: Optional[jax.Array] = None,
                       scale: Optional[float] = None,
                       interpret: bool = False) -> jax.Array:
    """q (B, Hq, D), pages_k/v (N, P, Hkv, D), block_tables (B, MP) int32,
    lengths (B,) -> (B, Hq, D), softmax-normalised.

    Logical position ``pi * P + r`` of sequence b lives at physical row
    ``(pages[block_tables[b, pi]], r)``; positions >= lengths[b] are
    masked (so unallocated table entries may hold any valid block id).

    With ``k_scales``/``v_scales`` ((N, Hkv) float32) the pages are int8
    and each (page, head) tile is dequantized in-register — the int8
    bytes are all that ever stream through VMEM."""
    b, hq, d = q.shape
    n_blocks, page, hkv = pages_k.shape[0], pages_k.shape[1], pages_k.shape[2]
    dv = pages_v.shape[3]
    n_pages = block_tables.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    quant = k_scales is not None
    assert quant == (v_scales is not None), "need both k_scales and v_scales"
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    if lengths is None:
        lengths = jnp.full((b,), n_pages * page, jnp.int32)

    # q: (B, Hq, D) -> (B*Hkv, group, D); pages: (N, P, Hkv, D) -> head-major
    # (N*Hkv, P, D) so one (block, head) pair is a contiguous (P, D) tile.
    qr = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    kr = pages_k.transpose(0, 2, 1, 3).reshape(n_blocks * hkv, page, d)
    vr = pages_v.transpose(0, 2, 1, 3).reshape(n_blocks * hkv, page, dv)
    len_r = jnp.repeat(lengths.astype(jnp.int32), hkv)          # (B*Hkv,)
    tables = jnp.clip(block_tables, 0, n_blocks - 1).astype(jnp.int32)

    def kv_map(bh, pi, bt):
        # physical (block, head) row of logical page pi of sequence bh//Hkv
        return (bt[bh // hkv, pi] * hkv + bh % hkv, 0, 0)

    def sc_map(bh, pi, bt):
        # the matching scalar in the flattened (N*Hkv,) scale sidecar
        return (bt[bh // hkv, pi] * hkv + bh % hkv,)

    in_specs = [
        pl.BlockSpec((1,), lambda bh, pi, bt: (bh,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, group, d), lambda bh, pi, bt: (bh, 0, 0)),
        pl.BlockSpec((1, page, d), kv_map),
        pl.BlockSpec((1, page, dv), kv_map),
    ]
    operands = [len_r, qr, kr, vr]
    if quant:
        sc_spec = pl.BlockSpec((1,), sc_map, memory_space=pltpu.SMEM)
        in_specs = in_specs[:3] + [sc_spec, in_specs[3], sc_spec]
        operands = [len_r, qr, kr,
                    jnp.asarray(k_scales, jnp.float32).reshape(-1),
                    vr, jnp.asarray(v_scales, jnp.float32).reshape(-1)]
        body = _paged_decode_q_kernel
    else:
        body = _paged_decode_kernel

    kernel = functools.partial(body, scale=scale, page=page,
                               n_pages=n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # the block table
        grid=(b * hkv, n_pages),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, group, dv), lambda bh, pi, bt: (bh, 0, 0)),
            pl.BlockSpec((1, group, 1), lambda bh, pi, bt: (bh, 0, 0)),
            pl.BlockSpec((1, group, 1), lambda bh, pi, bt: (bh, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, dv), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
        ],
    )
    out, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, group, dv), q.dtype),
            jax.ShapeDtypeStruct((b * hkv, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((b * hkv, group, 1), jnp.float32),
        ],
        interpret=interpret,
        name="flash_paged_decode_q" if quant else "flash_paged_decode",
    )(tables, *operands)
    return out.reshape(b, hq, dv)
