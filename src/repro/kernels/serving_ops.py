"""Serving graph ops — the pieces that let a decoder LM's prefill and
decode steps be expressed as GraphIR and compiled into
:class:`~repro.core.program.Program` artifacts (the serving engine's step
functions in :mod:`repro.runtime.engine`).

Three ops, each with an explicit functional-state contract (caches are
graph inputs AND outputs, so a Program stays a pure function):

* ``embedding``       — token id -> row lookup.
* ``cache_update``    — length-aware scatter of new K/V rows into a
  fixed-capacity cache at per-sequence offsets.  ``n_new`` rows are
  written starting at ``start``; slots with ``n_new == 0`` are untouched,
  which is how one fixed-batch Program serves a mix of active and idle
  slots.
* ``chunk_attention`` — chunked-prefill attention: a chunk of T queries
  at absolute positions ``start .. start+T-1`` attends to cache keys at
  positions ``<= start + t`` (offset-causal).  With T=1 this degenerates
  to single-token decode; the decode graph instead uses the existing
  ``decode_attention`` op so the flash-decode Pallas backend stays
  selectable on the hot path.

Each op carries *multiple* backends — that is the point of running the
serving hot path through the registry at all: the selector, the cost
models and the autotuner finally have something to choose from under
sustained traffic.

* ``ref``    — jnp oracle (vmap'd masked gather/scatter, dense fp32
  attention with the GQA heads materialised).
* ``xla``    — fused lowerings: one-hot-matmul embedding (MXU instead of
  gather), per-slot ``dynamic_update_slice`` cache writes, GQA attention
  grouped in the einsum so the repeated K/V expansion is never
  materialised.
* ``pallas`` — flash-style ``chunk_attention`` reusing the online-softmax
  machinery of :mod:`repro.kernels.flash_attention` with per-sequence
  offset-causal masking (``supports()`` guards block divisibility).

``decode_attention`` additionally gains a ``pallas_split`` split-KV
backend (registered in :mod:`repro.kernels.ops`) for long caches.

All shapes are static (fixed batch = engine slots, fixed chunk size,
fixed cache capacity), so each serving step jits exactly once.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.ir import TensorSpec
from repro.core.registry import Cost, defop, get_impl, get_op, impl
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_chunk_attention
from repro.kernels.ops import pallas_interpret

__all__ = ["embedding", "cache_update", "chunk_attention", "greedy_token",
           "verify_attention", "paged_verify_attention",
           "paged_verify_attention_q", "serving_mesh",
           "current_serving_mesh"]

Attrs = Dict[str, Any]


def _bytes(specs: Sequence[TensorSpec]) -> float:
    return float(sum(s.nbytes for s in specs))


# --------------------------------------------------------------------------- #
# Serving mesh context — how the ``tp`` backends learn about the mesh.
# supports()/cost() run at compile time and impl bodies at trace time, both
# with only (specs/inputs, attrs) in hand, so the engine publishes its mesh
# through this module-level context instead of threading it per call.
# --------------------------------------------------------------------------- #

_SERVING_MESH: Optional[Any] = None


@contextmanager
def serving_mesh(mesh):
    """Make ``mesh`` visible to the ``tp`` serving backends.

    The engine wraps both ``compile(mesh=...)`` (so ``supports()`` sees the
    mesh during backend selection) and every Program call (so the shard_map
    bodies see it at trace time) in this context."""
    global _SERVING_MESH
    prev = _SERVING_MESH
    _SERVING_MESH = mesh
    try:
        yield mesh
    finally:
        _SERVING_MESH = prev


def current_serving_mesh():
    """The mesh published by the innermost :func:`serving_mesh` (or None)."""
    return _SERVING_MESH


def _tp_state():
    """(mesh, degree) when a serving mesh with a >1 "model" axis is active,
    else (None, 1)."""
    mesh = current_serving_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None, 1
    tp = int(mesh.shape["model"])
    return (mesh, tp) if tp > 1 else (None, 1)


# --------------------------------------------------------------------------- #
# embedding — inputs (ids (B,T) int32, table (V,D))
# --------------------------------------------------------------------------- #

def _embedding_shape(specs, attrs):
    ids, table = specs
    return [TensorSpec(tuple(ids.shape) + (table.shape[1],), table.dtype)]


def _embedding_cost(specs, attrs):
    out = _embedding_shape(specs, attrs)[0]
    # gather: reads one table row per token + writes the output
    return Cost(flops=0.0, bytes=2.0 * out.nbytes + specs[0].nbytes)


defop("embedding", _embedding_shape, _embedding_cost,
      doc="token embedding lookup; inputs (ids (B,T) int32, table (V,D))")


@impl("embedding", "ref")
def _embedding_ref(inputs, attrs):
    ids, table = inputs
    return [jnp.take(table, ids, axis=0)]


def _embedding_xla_cost(specs, attrs):
    """One-hot matmul: 2*N*V*D flops plus the materialised (N, V)
    one-hot, traded against the gather's pure byte cost."""
    ids, table = specs
    v, d = table.shape
    n = ids.nelems
    out = _embedding_shape(specs, attrs)[0]
    return Cost(flops=2.0 * n * v * d,
                bytes=table.nbytes + out.nbytes + 4.0 * n * v)


@impl("embedding", "xla", cost_fn=_embedding_xla_cost,
      note="fused one-hot matmul: row select on the MXU instead of a gather "
           "(exact — 0/1 weights select rows bit-for-bit)")
def _embedding_xla(inputs, attrs):
    ids, table = inputs
    # clamp like jit-mode jnp.take does, so out-of-range ids pick the
    # nearest valid row instead of one_hot's all-zero row
    ids = jnp.clip(ids, 0, table.shape[0] - 1)
    onehot = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    return [jnp.tensordot(onehot, table, axes=1)]


def embedding(ids, table, *, backend: str = "ref", **kw):
    return get_impl("embedding", backend)([ids, table], kw)[0]


# --------------------------------------------------------------------------- #
# cache_update — inputs (cache (B,S,H,D), new (B,T,H,D), start (B,), n_new (B,))
# --------------------------------------------------------------------------- #

def _cache_update_shape(specs, attrs):
    cache, new = specs[0], specs[1]
    if cache.shape[0] != new.shape[0] or cache.shape[2:] != new.shape[2:]:
        raise ValueError(f"cache_update mismatch: {cache.shape} vs {new.shape}")
    if new.shape[1] > cache.shape[1]:
        raise ValueError(f"chunk {new.shape[1]} exceeds cache cap {cache.shape[1]}")
    return [cache]


def _cache_update_cost(specs, attrs):
    new = specs[1]
    # read-modify-write of T rows per sequence; the rest of the cache is
    # untouched (aliasing is XLA's job under jit)
    return Cost(flops=0.0, bytes=3.0 * new.nbytes + _bytes(specs[2:]))


defop("cache_update", _cache_update_shape, _cache_update_cost,
      doc="scatter n_new K/V rows into a cache at per-sequence offsets; "
          "inputs (cache (B,S,H,D), new (B,T,H,D), start (B,), n_new (B,))")


@impl("cache_update", "ref",
      note="vmap'd row scatter with masked rows dropped; n_new==0 slots "
           "are exact no-ops.  (Masked rows used to clip to cap-1 and "
           "re-write it — a duplicate-index scatter that corrupted the "
           "last cache row when a ragged final chunk ended exactly at "
           "capacity.)")
def _cache_update_ref(inputs, attrs):
    cache, new, start, n_new = inputs
    t = new.shape[1]
    cap = cache.shape[1]

    def one(c, x, s, n):
        idx = s + jnp.arange(t)
        # rows at or past n are padding: send them out of bounds so the
        # scatter drops them instead of clipping onto a real row
        idx = jnp.where(jnp.arange(t) < n, jnp.clip(idx, 0, cap - 1), cap)
        return c.at[idx].set(x, mode="drop")

    return [jax.vmap(one)(cache, new, start, n_new)]


@impl("cache_update", "xla",
      note="per-slot lax.dynamic_update_slice of the mask-merged chunk; "
           "matches ref exactly on the engine contract 0 <= start and "
           "start + n_new <= cap (a final ragged chunk may start past "
           "cap - T — the slice is shifted back and the chunk re-aligned)")
def _cache_update_xla(inputs, attrs):
    cache, new, start, n_new = inputs
    t = new.shape[1]
    cap = cache.shape[1]

    def one(c, x, s, n):
        # a ragged final chunk can have s > cap - t while still writing
        # only n <= cap - s valid rows; shift the fixed-size slice window
        # back into bounds and place the chunk at its offset inside it
        s_c = jnp.clip(s, 0, cap - t)
        shift = s - s_c
        cur = jax.lax.dynamic_slice_in_dim(c, s_c, t, axis=0)
        j = jnp.arange(t)
        src = jnp.take(x, jnp.clip(j - shift, 0, t - 1), axis=0)
        mask = ((j >= shift) & (j < shift + n)).reshape(
            (t,) + (1,) * (x.ndim - 1))
        return jax.lax.dynamic_update_slice_in_dim(
            c, jnp.where(mask, src, cur), s_c, axis=0)

    return [jax.vmap(one)(cache, new, start, n_new)]


def cache_update(cache, new, start, n_new, *, backend: str = "ref", **kw):
    return get_impl("cache_update", backend)([cache, new, start, n_new], kw)[0]


# --------------------------------------------------------------------------- #
# chunk_attention — inputs (q (B,T,Hq,D), k (B,S,Hk,D), v (B,S,Hk,D), start (B,))
# --------------------------------------------------------------------------- #

def _chunk_attn_shape(specs, attrs):
    return [specs[0]]


def _chunk_attn_cost(specs, attrs):
    q, k = specs[0], specs[1]
    b, t, hq, d = q.shape
    s = k.shape[1]
    return Cost(flops=4.0 * b * hq * t * s * d, bytes=_bytes(specs) + q.nbytes)


defop("chunk_attention", _chunk_attn_shape, _chunk_attn_cost,
      doc="chunked-prefill attention: query t (absolute position start+t) "
          "attends cache keys at positions <= start+t; "
          "inputs (q (B,T,Hq,D), k (B,S,Hk,D), v, start (B,)); attrs: scale")


def _chunk_attn_scale(attrs, d: int) -> float:
    # NOT `attrs.get("scale") or default`: an explicit scale=0.0 is falsy
    # but meaningful (uniform attention over the allowed positions)
    scale = attrs.get("scale")
    return (1.0 / math.sqrt(d)) if scale is None else scale


def _chunk_attn_ref_cost(specs, attrs):
    """Adds the oracle's materialisation traffic: GQA-repeated K/V in
    fp32 plus the dense (B, Hq, T, S) logits and probability tensors."""
    q, k = specs[0], specs[1]
    b, t, hq, d = q.shape
    s = k.shape[1]
    base = _chunk_attn_cost(specs, attrs)
    extra = 4.0 * (2.0 * b * s * hq * d + 2.0 * b * hq * t * s)
    return Cost(flops=base.flops, bytes=base.bytes + extra)


@impl("chunk_attention", "ref", cost_fn=_chunk_attn_ref_cost,
      note="dense offset-causal masked attention in fp32 (the oracle)")
def _chunk_attention_ref(inputs, attrs):
    q, k, v, start = inputs
    b, t, hq, d = q.shape
    s = k.shape[1]
    scale = _chunk_attn_scale(attrs, d)
    kf = R._repeat_kv(k, hq).astype(jnp.float32)
    vf = R._repeat_kv(v, hq).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    qpos = start[:, None] + jnp.arange(t)[None, :]            # (B, T)
    allowed = jnp.arange(s)[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    logits = jnp.where(allowed[:, None, :, :], logits, R._NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return [o.astype(q.dtype)]


@impl("chunk_attention", "xla",
      note="GQA grouped inside the einsum — the repeated-KV expansion is "
           "never materialised; XLA fuses mask+softmax")
def _chunk_attention_xla(inputs, attrs):
    q, k, v, start = inputs
    b, t, hq, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    assert hq % hk == 0, (hq, hk)
    g = hq // hk
    scale = _chunk_attn_scale(attrs, d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, t, hk, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    qpos = start[:, None] + jnp.arange(t)[None, :]              # (B, T)
    allowed = jnp.arange(s)[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    logits = jnp.where(allowed[:, None, None, :, :], logits, R._NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return [o.reshape(b, t, hq, d).astype(q.dtype)]


def _chunk_attn_pallas_supports(specs, attrs):
    """T % block_q == 0, S % block_kv == 0 (blocks clamped to the sequence
    lengths) and Hq divisible by Hk (whole GQA groups)."""
    q, k = specs[0], specs[1]
    bq = min(int(attrs.get("block_q", 256)), q.shape[1])
    bkv = min(int(attrs.get("block_kv", 512)), k.shape[1])
    return (q.shape[1] % bq == 0 and k.shape[1] % bkv == 0
            and q.shape[2] % k.shape[2] == 0)


@impl("chunk_attention", "pallas", supports=_chunk_attn_pallas_supports,
      note="flash-style online-softmax kernel; per-sequence offset-causal "
           "masking, fully-masked KV blocks skipped")
def _chunk_attention_pallas(inputs, attrs):
    q, k, v, start = inputs
    return [flash_chunk_attention(
        q, k, v, start, scale=attrs.get("scale"),
        block_q=int(attrs.get("block_q", 256)),
        block_kv=int(attrs.get("block_kv", 512)),
        interpret=attrs.get("interpret", pallas_interpret()))]


def chunk_attention(q, k, v, start, *, scale=None, backend: str = "ref", **kw):
    return get_impl("chunk_attention", backend)(
        [q, k, v, start], {"scale": scale, **kw})[0]


# --------------------------------------------------------------------------- #
# Paged serving ops — K/V rows live in a shared page pool
# (n_blocks, page_size, Hk, D) and each sequence reaches its rows through an
# int32 block table (B, max_pages): logical page -> physical block.  The
# engine side of the contract (allocation, refcounts, prefix reuse, CoW)
# lives in repro.runtime.kv_cache; these ops only move and read rows.
# Garbage table entries (unallocated logical pages, filled with 0) are
# harmless: reads of those positions are masked by start/lengths, writes
# never target them (start .. start+n_new-1 always lies in allocated pages).
# --------------------------------------------------------------------------- #

def _gather_pages(pages, tables):
    """(N, P, H, D) pages + (B, MP) tables -> dense (B, MP*P, H, D) view."""
    n, p = pages.shape[0], pages.shape[1]
    g = jnp.take(pages, jnp.clip(tables, 0, n - 1), axis=0)  # (B, MP, P, H, D)
    return g.reshape(tables.shape[0], tables.shape[1] * p, *pages.shape[2:])


def _gathered_bytes(pages_spec, tables_spec) -> float:
    """HBM bytes of one gathered dense K or V view."""
    n, p, h, d = pages_spec.shape
    b, mp = tables_spec.shape
    itemsize = pages_spec.nbytes / max(pages_spec.nelems, 1)
    return float(b * mp * p * h * d) * itemsize


# ---- paged_cache_update --------------------------------------------------- #
# inputs (pages (N,P,H,D), new (B,T,H,D), tables (B,MP) i32, start, n_new)

def _paged_update_shape(specs, attrs):
    pages, new, tables = specs[0], specs[1], specs[2]
    if pages.shape[2:] != new.shape[2:]:
        raise ValueError(f"page/new head mismatch: {pages.shape} vs {new.shape}")
    if new.shape[0] != tables.shape[0]:
        raise ValueError(f"batch mismatch: {new.shape} vs {tables.shape}")
    return [pages]


def _paged_update_cost(specs, attrs):
    new = specs[1]
    # read-modify-write of T rows per sequence through the table
    return Cost(flops=0.0, bytes=3.0 * new.nbytes + _bytes(specs[2:]))


defop("paged_cache_update", _paged_update_shape, _paged_update_cost,
      doc="scatter n_new K/V rows into a shared page pool through per-"
          "sequence block tables; inputs (pages (N,P,H,D), new (B,T,H,D), "
          "tables (B,MP) int32, start (B,), n_new (B,))")


def _paged_rows(tables, start, t, p, n_blocks):
    """Physical (block, row) targets for T rows per slot from ``start``;
    rows at or past ``n_new`` get block index N (dropped by the scatter)."""
    mp = tables.shape[1]
    pos = start[:, None] + jnp.arange(t)[None, :]              # (B, T)
    blk = jnp.take_along_axis(tables, jnp.clip(pos // p, 0, mp - 1), axis=1)
    return jnp.clip(blk, 0, n_blocks - 1), pos % p


@impl("paged_cache_update", "ref",
      note="per-slot python loop of masked row scatters (the oracle); "
          "n_new==0 slots are exact no-ops")
def _paged_cache_update_ref(inputs, attrs):
    pages, new, tables, start, n_new = inputs
    n_blocks, p = pages.shape[0], pages.shape[1]
    b, t = new.shape[0], new.shape[1]
    blk, row = _paged_rows(tables, start, t, p, n_blocks)
    out = jnp.asarray(pages)
    for bi in range(b):
        valid = jnp.arange(t) < n_new[bi]
        tgt = jnp.where(valid, blk[bi], n_blocks)      # OOB rows are dropped
        out = out.at[tgt, row[bi]].set(new[bi], mode="drop")
    return [out]


@impl("paged_cache_update", "xla",
      note="one flat (B*T)-row scatter; bit-identical to ref because write "
           "targets are unique (each writable page belongs to one sequence)")
def _paged_cache_update_xla(inputs, attrs):
    pages, new, tables, start, n_new = inputs
    n_blocks, p = pages.shape[0], pages.shape[1]
    b, t = new.shape[0], new.shape[1]
    blk, row = _paged_rows(tables, start, t, p, n_blocks)
    valid = jnp.arange(t)[None, :] < jnp.asarray(n_new)[:, None]
    tgt = jnp.where(valid, blk, n_blocks)
    return [jnp.asarray(pages).at[tgt.reshape(-1), row.reshape(-1)].set(
        jnp.asarray(new).reshape((b * t,) + new.shape[2:]), mode="drop")]


def paged_cache_update(pages, new, tables, start, n_new, *,
                       backend: str = "ref", **kw):
    return get_impl("paged_cache_update", backend)(
        [pages, new, tables, start, n_new], kw)[0]


# ---- paged_chunk_attention ------------------------------------------------ #
# inputs (q (B,T,Hq,D), pages_k (N,P,Hk,D), pages_v, tables (B,MP), start)

def _paged_chunk_shape(specs, attrs):
    return [specs[0]]


def _paged_chunk_cost(specs, attrs):
    q, pk, tables = specs[0], specs[1], specs[3]
    b, t, hq, d = q.shape
    s = tables.shape[1] * pk.shape[1]
    gathered = 2.0 * _gathered_bytes(pk, tables)      # stream K and V once
    return Cost(flops=4.0 * b * hq * t * s * d,
                bytes=2.0 * q.nbytes + tables.nbytes + gathered)


defop("paged_chunk_attention", _paged_chunk_shape, _paged_chunk_cost,
      doc="chunked-prefill attention reading K/V through block tables; "
          "inputs (q (B,T,Hq,D), pages_k (N,P,Hk,D), pages_v, "
          "tables (B,MP) int32, start (B,)); attrs: scale")


def _paged_chunk_ref_cost(specs, attrs):
    """Charges the materialised dense gather plus the ref oracle's
    GQA-repeated K/V and dense logits/probability tensors."""
    q, pk, tables = specs[0], specs[1], specs[3]
    b, t, hq, d = q.shape
    s = tables.shape[1] * pk.shape[1]
    base = _paged_chunk_cost(specs, attrs)
    extra = 2.0 * 2.0 * _gathered_bytes(pk, tables)   # written then re-read
    extra += 4.0 * (2.0 * b * s * hq * d + 2.0 * b * hq * t * s)
    return Cost(flops=base.flops, bytes=base.bytes + extra)


@impl("paged_chunk_attention", "ref", cost_fn=_paged_chunk_ref_cost,
      note="gather pages to a dense view, then the dense fp32 offset-"
           "causal oracle")
def _paged_chunk_attention_ref(inputs, attrs):
    q, pk, pv, tables, start = inputs
    return _chunk_attention_ref(
        [q, _gather_pages(pk, tables), _gather_pages(pv, tables), start],
        attrs)


def _paged_chunk_xla_cost(specs, attrs):
    """Charges the materialised dense gather; attention itself stays
    GQA-grouped (no repeated-KV expansion)."""
    q, pk, tables = specs[0], specs[1], specs[3]
    base = _paged_chunk_cost(specs, attrs)
    return Cost(flops=base.flops,
                bytes=base.bytes + 2.0 * 2.0 * _gathered_bytes(pk, tables))


@impl("paged_chunk_attention", "xla", cost_fn=_paged_chunk_xla_cost,
      note="gather pages to a dense view + the GQA-grouped einsum "
           "(repeated-KV never materialised)")
def _paged_chunk_attention_xla(inputs, attrs):
    q, pk, pv, tables, start = inputs
    return _chunk_attention_xla(
        [q, _gather_pages(pk, tables), _gather_pages(pv, tables), start],
        attrs)


def _paged_chunk_pallas_supports(specs, attrs):
    """T % block_q == 0 (block clamped to T), page_size % 8 == 0 (TPU
    sublane tiling of one page per KV step) and Hq divisible by Hk."""
    q, pk = specs[0], specs[1]
    bq = min(int(attrs.get("block_q", 256)), q.shape[1])
    return (q.shape[1] % bq == 0 and pk.shape[1] % 8 == 0
            and q.shape[2] % pk.shape[2] == 0)


@impl("paged_chunk_attention", "pallas",
      supports=_paged_chunk_pallas_supports,
      note="flash kernel reading pages in place via the scalar-prefetched "
           "block table — the dense gather copy never exists "
           "(flash_paged_chunk_attention)")
def _paged_chunk_attention_pallas(inputs, attrs):
    from repro.kernels.flash_attention import flash_paged_chunk_attention
    q, pk, pv, tables, start = inputs
    return [flash_paged_chunk_attention(
        q, pk, pv, tables, start, scale=attrs.get("scale"),
        block_q=int(attrs.get("block_q", 256)),
        interpret=attrs.get("interpret", pallas_interpret()))]


def paged_chunk_attention(q, pages_k, pages_v, tables, start, *, scale=None,
                          backend: str = "ref", **kw):
    return get_impl("paged_chunk_attention", backend)(
        [q, pages_k, pages_v, tables, start], {"scale": scale, **kw})[0]


# ---- paged_decode_attention ----------------------------------------------- #
# inputs (q (B,Hq,D), pages_k (N,P,Hk,D), pages_v, tables (B,MP), lengths)

def _paged_dec_shape(specs, attrs):
    return [specs[0]]


def _paged_dec_cost(specs, attrs):
    q, pk, tables = specs[0], specs[1], specs[3]
    b, hq, d = q.shape
    s = tables.shape[1] * pk.shape[1]
    gathered = 2.0 * _gathered_bytes(pk, tables)
    return Cost(flops=4.0 * b * hq * s * d,
                bytes=2.0 * q.nbytes + tables.nbytes + gathered)


defop("paged_decode_attention", _paged_dec_shape, _paged_dec_cost,
      doc="single-token attention reading the KV cache through block "
          "tables; inputs (q (B,Hq,D), pages_k (N,P,Hk,D), pages_v, "
          "tables (B,MP) int32, lengths (B,)); attrs: scale")


def _paged_dec_ref_cost(specs, attrs):
    """Adds the materialised dense gather and the oracle's GQA-repeated
    K/V to the op's streaming cost."""
    q, pk, tables = specs[0], specs[1], specs[3]
    b, hq, d = q.shape
    s = tables.shape[1] * pk.shape[1]
    base = _paged_dec_cost(specs, attrs)
    extra = 2.0 * 2.0 * _gathered_bytes(pk, tables)
    extra += 4.0 * (2.0 * b * s * hq * d)
    return Cost(flops=base.flops, bytes=base.bytes + extra)


@impl("paged_decode_attention", "ref", cost_fn=_paged_dec_ref_cost,
      note="gather pages to a dense view + the dense fp32 decode oracle")
def _paged_decode_attention_ref(inputs, attrs):
    q, pk, pv, tables, lengths = inputs
    k = _gather_pages(pk, tables)
    v = _gather_pages(pv, tables)
    return [R.decode_attention_ref(q, k, v, lengths,
                                   scale=attrs.get("scale"))]


def _paged_dec_xla_cost(specs, attrs):
    """Charges the materialised dense gather on top of the op's
    streaming cost; GQA stays grouped in the einsum."""
    q, pk, tables = specs[0], specs[1], specs[3]
    base = _paged_dec_cost(specs, attrs)
    return Cost(flops=base.flops,
                bytes=base.bytes + 2.0 * 2.0 * _gathered_bytes(pk, tables))


def _decode_attention_xla_dense(q, k, v, lengths, attrs):
    """GQA-grouped einsum decode over dense (already gathered) K/V."""
    b, hq, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    assert hq % hk == 0, (hq, hk)
    g = hq // hk
    scale = _chunk_attn_scale(attrs, d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, hk, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    allowed = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(allowed, logits, R._NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)


@impl("paged_decode_attention", "xla", cost_fn=_paged_dec_xla_cost,
      note="gather pages to a dense view + GQA-grouped einsum over the "
           "length-masked positions")
def _paged_decode_attention_xla(inputs, attrs):
    q, pk, pv, tables, lengths = inputs
    k = _gather_pages(pk, tables)
    v = _gather_pages(pv, tables)
    return [_decode_attention_xla_dense(q, k, v, lengths, attrs)]


def _paged_dec_pallas_supports(specs, attrs):
    """page_size % 8 == 0 (TPU sublane tiling of one page per KV step) and
    Hq divisible by Hk (whole GQA groups)."""
    q, pk = specs[0], specs[1]
    return pk.shape[1] % 8 == 0 and q.shape[1] % pk.shape[2] == 0


@impl("paged_decode_attention", "pallas", supports=_paged_dec_pallas_supports,
      note="block-table-aware flash decode: pages streamed via scalar-"
           "prefetched table indices, online softmax, one KV read per GQA "
           "group (repro.kernels.flash_decode.flash_paged_decode)")
def _paged_decode_attention_pallas(inputs, attrs):
    from repro.kernels.flash_decode import flash_paged_decode
    q, pk, pv, tables, lengths = inputs
    return [flash_paged_decode(
        q, pk, pv, tables, lengths, scale=attrs.get("scale"),
        interpret=attrs.get("interpret", pallas_interpret()))]


def paged_decode_attention(q, pages_k, pages_v, tables, lengths, *,
                           scale=None, backend: str = "ref", **kw):
    return get_impl("paged_decode_attention", backend)(
        [q, pages_k, pages_v, tables, lengths], {"scale": scale, **kw})[0]


# --------------------------------------------------------------------------- #
# Quantized paged ops — pages stored int8 with a per-(page, kv-head) float32
# scale sidecar (N, Hk).  Symmetric scheme: scale = absmax / 127, row = q *
# scale.  Scales only ever GROW (running per-page max): a write that raises a
# page's absmax requantizes that page's existing rows by old/new; pages whose
# scale did not change requantize by exactly 1.0, which is bit-exact, so
# prefix-shared pages keep identical bits across sequences.  An all-zero page
# keeps scale 0.0 and quantizes via a `scale > 0` guard (`x / 0` would be
# inf; `attrs.get("scale") or`-style falsy fallbacks are exactly the PR 4
# bug class this guard avoids).  The fp32 cache is never materialised: the
# attention backends dequantize after the gather (ref/xla) or in-register
# inside the online-softmax loop (pallas).
# --------------------------------------------------------------------------- #

_Q_MAX = 127.0


def _gather_pages_q(pages_q, scales, tables):
    """int8 (N,P,H,D) pages + (N,H) scales + (B,MP) tables -> dense fp32
    (B, MP*P, H, D) view, dequantized after the gather (per-sequence
    working set, never the whole pool)."""
    n, p = pages_q.shape[0], pages_q.shape[1]
    idx = jnp.clip(tables, 0, n - 1)
    g = jnp.take(pages_q, idx, axis=0).astype(jnp.float32)   # (B,MP,P,H,D)
    sc = jnp.take(scales, idx, axis=0)                       # (B,MP,H)
    g = g * sc[:, :, None, :, None]
    return g.reshape(tables.shape[0], tables.shape[1] * p, *pages_q.shape[2:])


def _scale_bytes(specs) -> float:
    return float(sum(s.nbytes for s in specs if len(s.shape) == 2
                     and s.dtype == "float32"))


# ---- paged_cache_update_q ------------------------------------------------- #
# inputs (pages (N,P,H,D) int8, scales (N,H) f32, new (B,T,H,D) f32,
#         tables (B,MP) i32, start (B,), n_new (B,)) -> [pages, scales]

def _paged_update_q_shape(specs, attrs):
    pages, scales, new, tables = specs[0], specs[1], specs[2], specs[3]
    if pages.dtype != "int8":
        raise ValueError(f"quantized pages must be int8, got {pages.dtype}")
    if scales.shape != (pages.shape[0], pages.shape[2]):
        raise ValueError(f"scales {scales.shape} != (N, Hk) "
                         f"({pages.shape[0]}, {pages.shape[2]})")
    if pages.shape[2:] != new.shape[2:]:
        raise ValueError(f"page/new head mismatch: {pages.shape} vs {new.shape}")
    if new.shape[0] != tables.shape[0]:
        raise ValueError(f"batch mismatch: {new.shape} vs {tables.shape}")
    return [pages, scales]


def _paged_update_q_cost(specs, attrs):
    """int8-honest traffic: RMW of the written rows at 1 byte/elem, the
    fp32 chunk read once, plus the full-pool requantize pass (read+write
    every int8 page and both scale sidecar states)."""
    pages, scales, new = specs[0], specs[1], specs[2]
    return Cost(flops=2.0 * pages.nelems,
                bytes=(2.0 * pages.nbytes + 3.0 * new.nelems + new.nbytes
                       + 3.0 * scales.nbytes + _bytes(specs[3:])))


defop("paged_cache_update_q", _paged_update_q_shape, _paged_update_q_cost,
      doc="quantize-on-write scatter into an int8 page pool with running "
          "per-(page, kv-head) max scales; inputs (pages (N,P,H,D) int8, "
          "scales (N,Hk) f32, new (B,T,H,D), tables (B,MP) int32, "
          "start (B,), n_new (B,)); outputs [pages, scales]")


def _quantize_rows(x, scale):
    """fp32 rows -> int8 given a broadcastable scale; scale==0 rows are
    all-zero by construction (scale is their absmax / 127)."""
    q = jnp.where(scale > 0, x / jnp.where(scale > 0, scale, 1.0), 0.0)
    return jnp.clip(jnp.round(q), -_Q_MAX, _Q_MAX).astype(jnp.int8)


def _paged_update_q_common(inputs):
    """Shared scale bookkeeping: returns (requantized pages fp32-exact in
    int8, new scales, int8 rows to scatter, blk, row, valid).  Order-
    independent: scales use a scatter-max, write targets are unique."""
    pages, scales, new, tables, start, n_new = inputs
    n_blocks, p = pages.shape[0], pages.shape[1]
    b, t = new.shape[0], new.shape[1]
    blk, row = _paged_rows(tables, start, t, p, n_blocks)
    valid = jnp.arange(t)[None, :] < jnp.asarray(n_new)[:, None]   # (B, T)
    tgt = jnp.where(valid, blk, n_blocks)                          # (B, T)
    # running per-(page, head) max: only written pages can grow
    row_amax = jnp.max(jnp.abs(new), axis=-1)                      # (B, T, H)
    row_scale = jnp.where(valid[..., None], row_amax / _Q_MAX, 0.0)
    new_scales = jnp.asarray(scales).at[tgt.reshape(-1)].max(
        row_scale.reshape(b * t, -1), mode="drop")
    # requantize the pool by old/new; untouched pages have ratio exactly
    # 1.0, so round(q * 1.0) == q and shared pages stay bit-identical
    ratio = jnp.where(new_scales > 0, jnp.asarray(scales) / new_scales, 1.0)
    pages_rq = jnp.clip(
        jnp.round(pages.astype(jnp.float32) * ratio[:, None, :, None]),
        -_Q_MAX, _Q_MAX).astype(jnp.int8)
    # quantize the incoming rows with their target page's final scale
    tgt_scale = jnp.take(new_scales, jnp.clip(tgt, 0, n_blocks - 1),
                         axis=0)                                   # (B, T, H)
    q_rows = _quantize_rows(jnp.asarray(new), tgt_scale[..., None])
    return pages_rq, new_scales, q_rows, blk, row, valid, tgt


@impl("paged_cache_update_q", "ref",
      note="per-slot python loop of masked int8 row scatters after the "
           "shared scale-growth/requantize pass (the oracle)")
def _paged_cache_update_q_ref(inputs, attrs):
    pages = inputs[0]
    n_blocks = pages.shape[0]
    b, t = inputs[2].shape[0], inputs[2].shape[1]
    pages_rq, new_scales, q_rows, blk, row, valid, _ = \
        _paged_update_q_common(inputs)
    out = pages_rq
    for bi in range(b):
        tgt = jnp.where(valid[bi], blk[bi], n_blocks)   # OOB rows dropped
        out = out.at[tgt, row[bi]].set(q_rows[bi], mode="drop")
    return [out, new_scales]


@impl("paged_cache_update_q", "xla",
      note="one flat (B*T)-row int8 scatter; bit-identical to ref because "
           "write targets are unique and the scale pass is a scatter-max")
def _paged_cache_update_q_xla(inputs, attrs):
    new = inputs[2]
    b, t = new.shape[0], new.shape[1]
    pages_rq, new_scales, q_rows, blk, row, valid, tgt = \
        _paged_update_q_common(inputs)
    out = pages_rq.at[tgt.reshape(-1), row.reshape(-1)].set(
        q_rows.reshape((b * t,) + new.shape[2:]), mode="drop")
    return [out, new_scales]


def paged_cache_update_q(pages, scales, new, tables, start, n_new, *,
                         backend: str = "ref", **kw):
    return get_impl("paged_cache_update_q", backend)(
        [pages, scales, new, tables, start, n_new], kw)


# ---- paged_chunk_attention_q ---------------------------------------------- #
# inputs (q (B,T,Hq,D), pages_k (N,P,Hk,D) i8, k_scales (N,Hk) f32,
#         pages_v i8, v_scales, tables (B,MP) i32, start (B,))

def _paged_chunk_q_shape(specs, attrs):
    pk, ks = specs[1], specs[2]
    if pk.dtype != "int8":
        raise ValueError(f"quantized pages must be int8, got {pk.dtype}")
    if ks.shape != (pk.shape[0], pk.shape[2]):
        raise ValueError(f"k_scales {ks.shape} != (N, Hk)")
    return [specs[0]]


def _paged_chunk_q_cost(specs, attrs):
    """Streams the gathered K/V once at 1 byte/elem (int8) plus the scale
    sidecars — the whole point of quantized pages on the memory-bound
    serving path."""
    q, pk, tables = specs[0], specs[1], specs[5]
    b, t, hq, d = q.shape
    s = tables.shape[1] * pk.shape[1]
    gathered = 2.0 * _gathered_bytes(pk, tables)      # int8 itemsize
    return Cost(flops=4.0 * b * hq * t * s * d,
                bytes=2.0 * q.nbytes + tables.nbytes + gathered
                      + _scale_bytes(specs))


defop("paged_chunk_attention_q", _paged_chunk_q_shape, _paged_chunk_q_cost,
      doc="chunked-prefill attention over int8 pages, dequantized with "
          "per-(page, kv-head) scales; inputs (q (B,T,Hq,D), pages_k int8, "
          "k_scales (N,Hk), pages_v int8, v_scales, tables (B,MP) int32, "
          "start (B,)); attrs: scale")


def _paged_chunk_q_gather_cost(specs, attrs):
    """Adds the materialised fp32 dequantized gather (written then re-read)
    on top of the int8 streaming cost."""
    q, pk, tables = specs[0], specs[1], specs[5]
    base = _paged_chunk_q_cost(specs, attrs)
    b, mp = tables.shape
    n, p, h, d = pk.shape
    dense_f32 = 4.0 * b * mp * p * h * d
    return Cost(flops=base.flops, bytes=base.bytes + 2.0 * 2.0 * dense_f32)


@impl("paged_chunk_attention_q", "ref", cost_fn=_paged_chunk_q_gather_cost,
      note="dequantize after the gather, then the dense fp32 offset-causal "
           "oracle")
def _paged_chunk_attention_q_ref(inputs, attrs):
    q, pk, ks, pv, vs, tables, start = inputs
    return _chunk_attention_ref(
        [q, _gather_pages_q(pk, ks, tables),
         _gather_pages_q(pv, vs, tables), start], attrs)


@impl("paged_chunk_attention_q", "xla", cost_fn=_paged_chunk_q_gather_cost,
      note="dequantize after the gather + the GQA-grouped einsum "
           "(repeated-KV never materialised)")
def _paged_chunk_attention_q_xla(inputs, attrs):
    q, pk, ks, pv, vs, tables, start = inputs
    return _chunk_attention_xla(
        [q, _gather_pages_q(pk, ks, tables),
         _gather_pages_q(pv, vs, tables), start], attrs)


def _paged_chunk_q_pallas_supports(specs, attrs):
    """T % block_q == 0 (block clamped to T), page_size % 8 == 0 and Hq
    divisible by Hk (whole GQA groups)."""
    q, pk = specs[0], specs[1]
    bq = min(int(attrs.get("block_q", 256)), q.shape[1])
    return (q.shape[1] % bq == 0 and pk.shape[1] % 8 == 0
            and q.shape[2] % pk.shape[2] == 0)


@impl("paged_chunk_attention_q", "pallas",
      supports=_paged_chunk_q_pallas_supports,
      note="fused flash kernel: int8 K/V tiles stream through the scalar-"
           "prefetched block table and dequantize in-register inside the "
           "online-softmax loop (flash_paged_chunk_attention)")
def _paged_chunk_attention_q_pallas(inputs, attrs):
    from repro.kernels.flash_attention import flash_paged_chunk_attention
    q, pk, ks, pv, vs, tables, start = inputs
    return [flash_paged_chunk_attention(
        q, pk, pv, tables, start, k_scales=ks, v_scales=vs,
        scale=attrs.get("scale"),
        block_q=int(attrs.get("block_q", 256)),
        interpret=attrs.get("interpret", pallas_interpret()))]


def paged_chunk_attention_q(q, pages_k, k_scales, pages_v, v_scales, tables,
                            start, *, scale=None, backend: str = "ref", **kw):
    return get_impl("paged_chunk_attention_q", backend)(
        [q, pages_k, k_scales, pages_v, v_scales, tables, start],
        {"scale": scale, **kw})[0]


# ---- paged_decode_attention_q --------------------------------------------- #
# inputs (q (B,Hq,D), pages_k (N,P,Hk,D) i8, k_scales (N,Hk) f32,
#         pages_v i8, v_scales, tables (B,MP) i32, lengths (B,))

def _paged_dec_q_shape(specs, attrs):
    pk, ks = specs[1], specs[2]
    if pk.dtype != "int8":
        raise ValueError(f"quantized pages must be int8, got {pk.dtype}")
    if ks.shape != (pk.shape[0], pk.shape[2]):
        raise ValueError(f"k_scales {ks.shape} != (N, Hk)")
    return [specs[0]]


def _paged_dec_q_cost(specs, attrs):
    """Streams the gathered K/V once at 1 byte/elem (int8) plus the
    scale sidecars."""
    q, pk, tables = specs[0], specs[1], specs[5]
    b, hq, d = q.shape
    s = tables.shape[1] * pk.shape[1]
    gathered = 2.0 * _gathered_bytes(pk, tables)
    return Cost(flops=4.0 * b * hq * s * d,
                bytes=2.0 * q.nbytes + tables.nbytes + gathered
                      + _scale_bytes(specs))


defop("paged_decode_attention_q", _paged_dec_q_shape, _paged_dec_q_cost,
      doc="single-token attention over int8 pages, dequantized with "
          "per-(page, kv-head) scales; inputs (q (B,Hq,D), pages_k int8, "
          "k_scales (N,Hk), pages_v int8, v_scales, tables (B,MP) int32, "
          "lengths (B,)); attrs: scale")


def _paged_dec_q_gather_cost(specs, attrs):
    """Adds the materialised fp32 dequantized gather on top of the int8
    streaming cost."""
    q, pk, tables = specs[0], specs[1], specs[5]
    base = _paged_dec_q_cost(specs, attrs)
    b, mp = tables.shape
    n, p, h, d = pk.shape
    dense_f32 = 4.0 * b * mp * p * h * d
    return Cost(flops=base.flops, bytes=base.bytes + 2.0 * 2.0 * dense_f32)


@impl("paged_decode_attention_q", "ref", cost_fn=_paged_dec_q_gather_cost,
      note="dequantize after the gather + the dense fp32 decode oracle")
def _paged_decode_attention_q_ref(inputs, attrs):
    q, pk, ks, pv, vs, tables, lengths = inputs
    k = _gather_pages_q(pk, ks, tables)
    v = _gather_pages_q(pv, vs, tables)
    return [R.decode_attention_ref(q, k, v, lengths,
                                   scale=attrs.get("scale"))]


@impl("paged_decode_attention_q", "xla", cost_fn=_paged_dec_q_gather_cost,
      note="dequantize after the gather + GQA-grouped einsum over the "
           "length-masked positions")
def _paged_decode_attention_q_xla(inputs, attrs):
    q, pk, ks, pv, vs, tables, lengths = inputs
    k = _gather_pages_q(pk, ks, tables)
    v = _gather_pages_q(pv, vs, tables)
    return [_decode_attention_xla_dense(q, k, v, lengths, attrs)]


def _paged_dec_q_pallas_supports(specs, attrs):
    """page_size % 8 == 0 (TPU sublane tiling of one page per KV step) and
    Hq divisible by Hk (whole GQA groups)."""
    q, pk = specs[0], specs[1]
    return pk.shape[1] % 8 == 0 and q.shape[1] % pk.shape[2] == 0


@impl("paged_decode_attention_q", "pallas",
      supports=_paged_dec_q_pallas_supports,
      note="fused flash decode: int8 pages stream via scalar-prefetched "
           "table indices, per-(page, head) scales ride along in SMEM and "
           "dequant happens in-register (flash_paged_decode)")
def _paged_decode_attention_q_pallas(inputs, attrs):
    from repro.kernels.flash_decode import flash_paged_decode
    q, pk, ks, pv, vs, tables, lengths = inputs
    return [flash_paged_decode(
        q, pk, pv, tables, lengths, k_scales=ks, v_scales=vs,
        scale=attrs.get("scale"),
        interpret=attrs.get("interpret", pallas_interpret()))]


def paged_decode_attention_q(q, pages_k, k_scales, pages_v, v_scales, tables,
                             lengths, *, scale=None, backend: str = "ref",
                             **kw):
    return get_impl("paged_decode_attention_q", backend)(
        [q, pages_k, k_scales, pages_v, v_scales, tables, lengths],
        {"scale": scale, **kw})[0]


# --------------------------------------------------------------------------- #
# Speculative-decoding ops.
#
# ``verify_attention`` scores K+1 tokens (the committed next token plus K
# draft proposals) against the cache in ONE call — shape-identical to
# ``chunk_attention`` (a verify step IS a prefill chunk of T = K+1 rows at
# per-sequence offsets), but registered as a distinct op so the selector /
# autotuner can pick a backend for the verify shape independently of the
# prefill chunk shape, and so the generated op-reference tables document
# the speculative path.  The backends delegate to the chunk-attention
# implementations (same offset-causal math, bit-for-bit); the ``supports``
# guards mirror chunk_attention's (ragged K is handled above the op by
# ``n_new`` masking, exactly like ragged prefill chunks).
#
# ``greedy_token`` is the in-graph argmax that lets the DRAFT Program feed
# its own greedy output back as the next step's input token — the K-step
# autoregressive draft then runs as one compiled Program call instead of K
# dispatches.
# --------------------------------------------------------------------------- #

def _greedy_token_shape(specs, attrs):
    logits = specs[0]
    if len(logits.shape) != 2:
        raise ValueError(f"greedy_token wants (B, V) logits, got {logits.shape}")
    return [TensorSpec((logits.shape[0], 1), "int32")]


def _greedy_token_cost(specs, attrs):
    # stream the logits once; the output is negligible
    return Cost(flops=float(specs[0].nelems), bytes=_bytes(specs))


defop("greedy_token", _greedy_token_shape, _greedy_token_cost,
      doc="greedy sampling inside a graph: (B, V) logits -> (B, 1) int32 "
          "argmax token ids (ties break to the lowest id, matching "
          "np.argmax on the host)")


@impl("greedy_token", "ref",
      note="jnp.argmax over the vocab axis; ties break to the lowest id, "
           "bit-identical to the engine's host-side np.argmax")
def _greedy_token_ref(inputs, attrs):
    return [jnp.argmax(inputs[0], axis=-1, keepdims=True).astype(jnp.int32)]


def greedy_token(logits, *, backend: str = "ref", **kw):
    return get_impl("greedy_token", backend)([logits], kw)[0]


# ---- verify_attention (dense) --------------------------------------------- #
# inputs (q (B,T,Hq,D), k (B,S,Hk,D), v (B,S,Hk,D), start (B,)); T = K+1

defop("verify_attention", _chunk_attn_shape, _chunk_attn_cost,
      doc="speculative-verify attention: score K+1 tokens (committed next "
          "token + K draft proposals) against the dense cache in one call; "
          "offset-causal exactly like chunk_attention (row t attends "
          "positions <= start+t); inputs (q (B,T,Hq,D), k (B,S,Hk,D), v, "
          "start (B,)); attrs: scale")


@impl("verify_attention", "ref", cost_fn=_chunk_attn_ref_cost,
      note="dense offset-causal masked attention in fp32 (delegates to the "
           "chunk_attention oracle — a verify step is a T=K+1 chunk)")
def _verify_attention_ref(inputs, attrs):
    return _chunk_attention_ref(inputs, attrs)


@impl("verify_attention", "xla",
      note="GQA grouped inside the einsum (chunk_attention's fused "
           "lowering at the verify shape)")
def _verify_attention_xla(inputs, attrs):
    return _chunk_attention_xla(inputs, attrs)


@impl("verify_attention", "pallas", supports=_chunk_attn_pallas_supports,
      note="flash online-softmax kernel at the T=K+1 verify shape "
           "(block_q clamps to T, so any K passes the divisibility guard)")
def _verify_attention_pallas(inputs, attrs):
    return _chunk_attention_pallas(inputs, attrs)


def verify_attention(q, k, v, start, *, scale=None, backend: str = "ref",
                     **kw):
    return get_impl("verify_attention", backend)(
        [q, k, v, start], {"scale": scale, **kw})[0]


# ---- paged_verify_attention ----------------------------------------------- #
# inputs (q (B,T,Hq,D), pages_k (N,P,Hk,D), pages_v, tables (B,MP), start)

defop("paged_verify_attention", _paged_chunk_shape, _paged_chunk_cost,
      doc="speculative-verify attention reading K/V through block tables "
          "(paged_chunk_attention semantics at T = K+1); inputs "
          "(q (B,T,Hq,D), pages_k (N,P,Hk,D), pages_v, tables (B,MP) "
          "int32, start (B,)); attrs: scale")


@impl("paged_verify_attention", "ref", cost_fn=_paged_chunk_ref_cost,
      note="gather pages to a dense view, then the dense fp32 offset-"
           "causal oracle")
def _paged_verify_attention_ref(inputs, attrs):
    return _paged_chunk_attention_ref(inputs, attrs)


@impl("paged_verify_attention", "xla", cost_fn=_paged_chunk_xla_cost,
      note="gather pages to a dense view + the GQA-grouped einsum")
def _paged_verify_attention_xla(inputs, attrs):
    return _paged_chunk_attention_xla(inputs, attrs)


@impl("paged_verify_attention", "pallas",
      supports=_paged_chunk_pallas_supports,
      note="flash kernel reading pages in place via the scalar-prefetched "
           "block table (flash_paged_chunk_attention at the verify shape)")
def _paged_verify_attention_pallas(inputs, attrs):
    return _paged_chunk_attention_pallas(inputs, attrs)


def paged_verify_attention(q, pages_k, pages_v, tables, start, *, scale=None,
                           backend: str = "ref", **kw):
    return get_impl("paged_verify_attention", backend)(
        [q, pages_k, pages_v, tables, start], {"scale": scale, **kw})[0]


# ---- paged_verify_attention_q --------------------------------------------- #
# inputs (q (B,T,Hq,D), pages_k i8, k_scales (N,Hk), pages_v i8, v_scales,
#         tables (B,MP), start, k_new (B,T,Hk,D) f32, v_new (B,T,Hk,D) f32)
#
# TWO-SOURCE on purpose: the committed prefix streams from the int8 pages,
# but this call's own K+1 speculative rows come in as fp32 ``k_new/v_new``
# and are NEVER written to the pages here.  Quantize-on-write page scales
# only ever GROW, and a scale raise requantizes the whole page — so writing
# draft rows that later get REJECTED would permanently (and lossily) perturb
# committed rows sharing their page, breaking token-exactness vs the
# reference.  Accepted rows are committed afterwards by a separate
# ``paged_cache_update_q`` Program call with ``n_new`` = accepted count.

def _paged_verify_q_shape(specs, attrs):
    q, pk, ks, kn, vn = specs[0], specs[1], specs[2], specs[7], specs[8]
    if pk.dtype != "int8":
        raise ValueError(f"quantized pages must be int8, got {pk.dtype}")
    if ks.shape != (pk.shape[0], pk.shape[2]):
        raise ValueError(f"k_scales {ks.shape} != (N, Hk)")
    want = (q.shape[0], q.shape[1], pk.shape[2], pk.shape[3])
    for name, spec in (("k_new", kn), ("v_new", vn)):
        if spec.shape != want:
            raise ValueError(f"{name} {spec.shape} != (B, T, Hk, D) {want}")
    return [specs[0]]


def _paged_verify_q_cost(specs, attrs):
    base = _paged_chunk_q_cost(specs[:7], attrs)
    return Cost(flops=base.flops, bytes=base.bytes + _bytes(specs[7:]))


def _paged_verify_q_gather_cost(specs, attrs):
    base = _paged_chunk_q_gather_cost(specs[:7], attrs)
    return Cost(flops=base.flops, bytes=base.bytes + _bytes(specs[7:]))


defop("paged_verify_attention_q", _paged_verify_q_shape,
      _paged_verify_q_cost,
      doc="speculative-verify attention over int8 pages: the committed "
          "prefix dequantizes from the pages, this call's K+1 rows read "
          "from fp32 k_new/v_new (two-source — speculative rows are never "
          "quantized into pages before acceptance); inputs (q (B,T,Hq,D), "
          "pages_k int8, k_scales (N,Hk), pages_v int8, v_scales, tables "
          "(B,MP) int32, start (B,), k_new (B,T,Hk,D), v_new); attrs: scale")


def _patch_new_rows(dense, new, start):
    """Overlay this call's fp32 rows onto the dequantized gather at rows
    ``start + 0..T-1`` (per batch); rows past the dense view drop."""
    b, t = new.shape[0], new.shape[1]
    pos = jnp.asarray(start)[:, None] + jnp.arange(t)[None, :]
    bi = jnp.arange(b)[:, None]
    return jnp.asarray(dense).at[bi, pos].set(jnp.asarray(new), mode="drop")


def _paged_verify_q_sources(inputs):
    q, pk, ks, pv, vs, tables, start, kn, vn = inputs
    k = _patch_new_rows(_gather_pages_q(pk, ks, tables), kn, start)
    v = _patch_new_rows(_gather_pages_q(pv, vs, tables), vn, start)
    return q, k, v, start


@impl("paged_verify_attention_q", "ref", cost_fn=_paged_verify_q_gather_cost,
      note="dequantize after the gather, patch in the fp32 speculative "
           "rows, then the dense fp32 offset-causal oracle")
def _paged_verify_attention_q_ref(inputs, attrs):
    q, k, v, start = _paged_verify_q_sources(inputs)
    return _chunk_attention_ref([q, k, v, start], attrs)


@impl("paged_verify_attention_q", "xla", cost_fn=_paged_verify_q_gather_cost,
      note="dequantize after the gather, patch in the fp32 speculative "
           "rows + the GQA-grouped einsum")
def _paged_verify_attention_q_xla(inputs, attrs):
    q, k, v, start = _paged_verify_q_sources(inputs)
    return _chunk_attention_xla([q, k, v, start], attrs)


def _paged_verify_q_pallas_supports(specs, attrs):
    """The dense flash kernel runs on the patched gather: T % block_q == 0
    (block clamped to T) and whole GQA groups."""
    q, pk = specs[0], specs[1]
    bq = min(int(attrs.get("block_q", 256)), q.shape[1])
    return q.shape[1] % bq == 0 and q.shape[2] % pk.shape[2] == 0


@impl("paged_verify_attention_q", "pallas",
      supports=_paged_verify_q_pallas_supports,
      note="XLA gather/dequant/patch feeding the dense flash online-"
           "softmax kernel at the verify shape (the two-source patch "
           "cannot stream pages in place)")
def _paged_verify_attention_q_pallas(inputs, attrs):
    q, k, v, start = _paged_verify_q_sources(inputs)
    return _chunk_attention_pallas([q, k, v, start], attrs)


def paged_verify_attention_q(q, pages_k, k_scales, pages_v, v_scales, tables,
                             start, k_new, v_new, *, scale=None,
                             backend: str = "ref", **kw):
    return get_impl("paged_verify_attention_q", backend)(
        [q, pages_k, k_scales, pages_v, v_scales, tables, start,
         k_new, v_new], {"scale": scale, **kw})[0]


# --------------------------------------------------------------------------- #
# ``tp`` backends — tensor-parallel attention over the head dim via
# shard_map.  Heads are independent through the whole softmax, so each
# device runs the stock xla lowering on its head slice with NO inner
# collective and bit-identical arithmetic to the single-device run; the
# only collective is the (exact, pure-data-movement) all-gather handing
# the head-sharded output back to the replicated half of the Program.
# supports() requires the serving mesh context (see serving_mesh above)
# and whole GQA groups per device: tp must divide both Hq and Hk — a
# GQA-small model falls back to the replicated backends instead.
# --------------------------------------------------------------------------- #

_HS4 = P(None, None, "model", None)   # (B,T,H,D) / (B,S,H,D) / (N,P,H,D)
_HS3 = P(None, "model", None)         # decode q (B,H,D)
_SS2 = P(None, "model")               # scale sidecar (N,Hk)
_REP = P()


def _tp_attn_supports(specs, attrs):
    """serving mesh active with a "model" axis of size tp > 1 dividing
    both Hq and Hk (whole GQA groups per device)"""
    mesh, tp = _tp_state()
    if mesh is None:
        return False
    hq = specs[0].shape[-2]
    hk = specs[1].shape[2]
    return hq % tp == 0 and hk % tp == 0


def _tp_cost_fn(op: str):
    base_cost, shape_fn = get_op(op).cost_fn, get_op(op).shape_fn

    def cost(specs, attrs):
        """op streaming cost plus the (tp-1)/tp all-gather returning the
        head-sharded output to the replicated Program (collectives.
        allgather_bytes)"""
        from repro.sharding.collectives import allgather_bytes
        _, tp = _tp_state()
        base = base_cost(specs, attrs)
        out = shape_fn(specs, attrs)[0]
        return Cost(flops=base.flops,
                    bytes=base.bytes + allgather_bytes(out.nbytes, tp))
    return cost


def _tp_call(local_fn, args, in_specs, out_spec):
    from repro.sharding.collectives import replicate, shard_map_compat
    mesh, _ = _tp_state()
    out = shard_map_compat(local_fn, mesh, tuple(in_specs), out_spec)(*args)
    return replicate(out, mesh)


_TP_NOTE = ("shard_map over heads on the serving mesh; per-device xla "
            "lowering, output all-gathered back to replicated")


@impl("chunk_attention", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("chunk_attention"), note=_TP_NOTE)
def _chunk_attention_tp(inputs, attrs):
    q, k, v, start = inputs
    def local(q_, k_, v_, s_):
        return _chunk_attention_xla([q_, k_, v_, s_], attrs)[0]
    return [_tp_call(local, (q, k, v, start),
                     (_HS4, _HS4, _HS4, _REP), _HS4)]


@impl("decode_attention", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("decode_attention"), note=_TP_NOTE)
def _decode_attention_tp(inputs, attrs):
    q, k, v, lengths = inputs
    def local(q_, k_, v_, l_):
        return _decode_attention_xla_dense(q_, k_, v_, l_, attrs)
    return [_tp_call(local, (q, k, v, lengths),
                     (_HS3, _HS4, _HS4, _REP), _HS3)]


@impl("verify_attention", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("verify_attention"), note=_TP_NOTE)
def _verify_attention_tp(inputs, attrs):
    q, k, v, start = inputs
    def local(q_, k_, v_, s_):
        return _verify_attention_xla([q_, k_, v_, s_], attrs)[0]
    return [_tp_call(local, (q, k, v, start),
                     (_HS4, _HS4, _HS4, _REP), _HS4)]


@impl("paged_chunk_attention", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("paged_chunk_attention"), note=_TP_NOTE)
def _paged_chunk_attention_tp(inputs, attrs):
    q, pk, pv, tables, start = inputs
    def local(q_, pk_, pv_, t_, s_):
        return _paged_chunk_attention_xla([q_, pk_, pv_, t_, s_], attrs)[0]
    return [_tp_call(local, (q, pk, pv, tables, start),
                     (_HS4, _HS4, _HS4, _REP, _REP), _HS4)]


@impl("paged_decode_attention", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("paged_decode_attention"), note=_TP_NOTE)
def _paged_decode_attention_tp(inputs, attrs):
    q, pk, pv, tables, lengths = inputs
    def local(q_, pk_, pv_, t_, l_):
        return _paged_decode_attention_xla([q_, pk_, pv_, t_, l_], attrs)[0]
    return [_tp_call(local, (q, pk, pv, tables, lengths),
                     (_HS3, _HS4, _HS4, _REP, _REP), _HS3)]


@impl("paged_verify_attention", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("paged_verify_attention"), note=_TP_NOTE)
def _paged_verify_attention_tp(inputs, attrs):
    q, pk, pv, tables, start = inputs
    def local(q_, pk_, pv_, t_, s_):
        return _paged_verify_attention_xla([q_, pk_, pv_, t_, s_], attrs)[0]
    return [_tp_call(local, (q, pk, pv, tables, start),
                     (_HS4, _HS4, _HS4, _REP, _REP), _HS4)]


@impl("paged_chunk_attention_q", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("paged_chunk_attention_q"), note=_TP_NOTE)
def _paged_chunk_attention_q_tp(inputs, attrs):
    q, pk, ks, pv, vs, tables, start = inputs
    def local(q_, pk_, ks_, pv_, vs_, t_, s_):
        return _paged_chunk_attention_q_xla(
            [q_, pk_, ks_, pv_, vs_, t_, s_], attrs)[0]
    return [_tp_call(local, (q, pk, ks, pv, vs, tables, start),
                     (_HS4, _HS4, _SS2, _HS4, _SS2, _REP, _REP), _HS4)]


@impl("paged_decode_attention_q", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("paged_decode_attention_q"), note=_TP_NOTE)
def _paged_decode_attention_q_tp(inputs, attrs):
    q, pk, ks, pv, vs, tables, lengths = inputs
    def local(q_, pk_, ks_, pv_, vs_, t_, l_):
        return _paged_decode_attention_q_xla(
            [q_, pk_, ks_, pv_, vs_, t_, l_], attrs)[0]
    return [_tp_call(local, (q, pk, ks, pv, vs, tables, lengths),
                     (_HS3, _HS4, _SS2, _HS4, _SS2, _REP, _REP), _HS3)]


@impl("paged_verify_attention_q", "tp", supports=_tp_attn_supports,
      cost_fn=_tp_cost_fn("paged_verify_attention_q"), note=_TP_NOTE)
def _paged_verify_attention_q_tp(inputs, attrs):
    q, pk, ks, pv, vs, tables, start, kn, vn = inputs
    def local(q_, pk_, ks_, pv_, vs_, t_, s_, kn_, vn_):
        return _paged_verify_attention_q_xla(
            [q_, pk_, ks_, pv_, vs_, t_, s_, kn_, vn_], attrs)[0]
    return [_tp_call(local, (q, pk, ks, pv, vs, tables, start, kn, vn),
                     (_HS4, _HS4, _SS2, _HS4, _SS2, _REP, _REP, _HS4, _HS4),
                     _HS4)]


# the ops whose ``tp`` backend the engine prefers when given a mesh
TP_ATTENTION_OPS = (
    "chunk_attention", "decode_attention", "verify_attention",
    "paged_chunk_attention", "paged_decode_attention",
    "paged_verify_attention", "paged_chunk_attention_q",
    "paged_decode_attention_q", "paged_verify_attention_q")
