"""Serving graph ops — the pieces that let a decoder LM's prefill and
decode steps be expressed as GraphIR and compiled into
:class:`~repro.core.program.Program` artifacts (the serving engine's step
functions in :mod:`repro.runtime.engine`).

Three ops, each with an explicit functional-state contract (caches are
graph inputs AND outputs, so a Program stays a pure function):

* ``embedding``       — token id -> row lookup.
* ``cache_update``    — length-aware scatter of new K/V rows into a
  fixed-capacity cache at per-sequence offsets.  ``n_new`` rows are
  written starting at ``start``; slots with ``n_new == 0`` are untouched,
  which is how one fixed-batch Program serves a mix of active and idle
  slots.
* ``chunk_attention`` — chunked-prefill attention: a chunk of T queries
  at absolute positions ``start .. start+T-1`` attends to cache keys at
  positions ``<= start + t`` (offset-causal).  With T=1 this degenerates
  to single-token decode; the decode graph instead uses the existing
  ``decode_attention`` op so the flash-decode Pallas backend stays
  selectable on the hot path.

Each op carries *multiple* backends — that is the point of running the
serving hot path through the registry at all: the selector, the cost
models and the autotuner finally have something to choose from under
sustained traffic.

* ``ref``    — jnp oracle (vmap'd masked gather/scatter, dense fp32
  attention with the GQA heads materialised).
* ``xla``    — fused lowerings: one-hot-matmul embedding (MXU instead of
  gather), per-slot ``dynamic_update_slice`` cache writes, GQA attention
  grouped in the einsum so the repeated K/V expansion is never
  materialised.
* ``pallas`` — flash-style ``chunk_attention`` reusing the online-softmax
  machinery of :mod:`repro.kernels.flash_attention` with per-sequence
  offset-causal masking (``supports()`` guards block divisibility).

``decode_attention`` additionally gains a ``pallas_split`` split-KV
backend (registered in :mod:`repro.kernels.ops`) for long caches.

All shapes are static (fixed batch = engine slots, fixed chunk size,
fixed cache capacity), so each serving step jits exactly once.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from repro.core.ir import TensorSpec
from repro.core.registry import Cost, defop, get_impl, impl
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_chunk_attention
from repro.kernels.ops import pallas_interpret

__all__ = ["embedding", "cache_update", "chunk_attention"]

Attrs = Dict[str, Any]


def _bytes(specs: Sequence[TensorSpec]) -> float:
    return float(sum(s.nbytes for s in specs))


# --------------------------------------------------------------------------- #
# embedding — inputs (ids (B,T) int32, table (V,D))
# --------------------------------------------------------------------------- #

def _embedding_shape(specs, attrs):
    ids, table = specs
    return [TensorSpec(tuple(ids.shape) + (table.shape[1],), table.dtype)]


def _embedding_cost(specs, attrs):
    out = _embedding_shape(specs, attrs)[0]
    # gather: reads one table row per token + writes the output
    return Cost(flops=0.0, bytes=2.0 * out.nbytes + specs[0].nbytes)


defop("embedding", _embedding_shape, _embedding_cost,
      doc="token embedding lookup; inputs (ids (B,T) int32, table (V,D))")


@impl("embedding", "ref")
def _embedding_ref(inputs, attrs):
    ids, table = inputs
    return [jnp.take(table, ids, axis=0)]


def _embedding_xla_cost(specs, attrs):
    ids, table = specs
    v, d = table.shape
    n = ids.nelems
    out = _embedding_shape(specs, attrs)[0]
    # one-hot matmul: 2*N*V*D flops and a materialised (N, V) one-hot
    return Cost(flops=2.0 * n * v * d,
                bytes=table.nbytes + out.nbytes + 4.0 * n * v)


@impl("embedding", "xla", cost_fn=_embedding_xla_cost,
      note="fused one-hot matmul: row select on the MXU instead of a gather "
           "(exact — 0/1 weights select rows bit-for-bit)")
def _embedding_xla(inputs, attrs):
    ids, table = inputs
    # clamp like jit-mode jnp.take does, so out-of-range ids pick the
    # nearest valid row instead of one_hot's all-zero row
    ids = jnp.clip(ids, 0, table.shape[0] - 1)
    onehot = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    return [jnp.tensordot(onehot, table, axes=1)]


def embedding(ids, table, *, backend: str = "ref", **kw):
    return get_impl("embedding", backend)([ids, table], kw)[0]


# --------------------------------------------------------------------------- #
# cache_update — inputs (cache (B,S,H,D), new (B,T,H,D), start (B,), n_new (B,))
# --------------------------------------------------------------------------- #

def _cache_update_shape(specs, attrs):
    cache, new = specs[0], specs[1]
    if cache.shape[0] != new.shape[0] or cache.shape[2:] != new.shape[2:]:
        raise ValueError(f"cache_update mismatch: {cache.shape} vs {new.shape}")
    if new.shape[1] > cache.shape[1]:
        raise ValueError(f"chunk {new.shape[1]} exceeds cache cap {cache.shape[1]}")
    return [cache]


def _cache_update_cost(specs, attrs):
    new = specs[1]
    # read-modify-write of T rows per sequence; the rest of the cache is
    # untouched (aliasing is XLA's job under jit)
    return Cost(flops=0.0, bytes=3.0 * new.nbytes + _bytes(specs[2:]))


defop("cache_update", _cache_update_shape, _cache_update_cost,
      doc="scatter n_new K/V rows into a cache at per-sequence offsets; "
          "inputs (cache (B,S,H,D), new (B,T,H,D), start (B,), n_new (B,))")


@impl("cache_update", "ref",
      note="vmap'd masked gather/scatter; n_new==0 slots are exact no-ops")
def _cache_update_ref(inputs, attrs):
    cache, new, start, n_new = inputs
    t = new.shape[1]
    cap = cache.shape[1]

    def one(c, x, s, n):
        idx = jnp.clip(s + jnp.arange(t), 0, cap - 1)
        rows = c[idx]
        mask = (jnp.arange(t) < n).reshape((t,) + (1,) * (x.ndim - 1))
        return c.at[idx].set(jnp.where(mask, x, rows))

    return [jax.vmap(one)(cache, new, start, n_new)]


@impl("cache_update", "xla",
      note="per-slot lax.dynamic_update_slice of the mask-merged chunk; "
           "matches ref exactly on the engine contract 0 <= start <= cap-T "
           "(ref's per-row index clip only differs outside it)")
def _cache_update_xla(inputs, attrs):
    cache, new, start, n_new = inputs
    t = new.shape[1]
    cap = cache.shape[1]

    def one(c, x, s, n):
        s = jnp.clip(s, 0, cap - t)
        cur = jax.lax.dynamic_slice_in_dim(c, s, t, axis=0)
        mask = (jnp.arange(t) < n).reshape((t,) + (1,) * (x.ndim - 1))
        return jax.lax.dynamic_update_slice_in_dim(
            c, jnp.where(mask, x, cur), s, axis=0)

    return [jax.vmap(one)(cache, new, start, n_new)]


def cache_update(cache, new, start, n_new, *, backend: str = "ref", **kw):
    return get_impl("cache_update", backend)([cache, new, start, n_new], kw)[0]


# --------------------------------------------------------------------------- #
# chunk_attention — inputs (q (B,T,Hq,D), k (B,S,Hk,D), v (B,S,Hk,D), start (B,))
# --------------------------------------------------------------------------- #

def _chunk_attn_shape(specs, attrs):
    return [specs[0]]


def _chunk_attn_cost(specs, attrs):
    q, k = specs[0], specs[1]
    b, t, hq, d = q.shape
    s = k.shape[1]
    return Cost(flops=4.0 * b * hq * t * s * d, bytes=_bytes(specs) + q.nbytes)


defop("chunk_attention", _chunk_attn_shape, _chunk_attn_cost,
      doc="chunked-prefill attention: query t (absolute position start+t) "
          "attends cache keys at positions <= start+t; "
          "inputs (q (B,T,Hq,D), k (B,S,Hk,D), v, start (B,)); attrs: scale")


def _chunk_attn_scale(attrs, d: int) -> float:
    # NOT `attrs.get("scale") or default`: an explicit scale=0.0 is falsy
    # but meaningful (uniform attention over the allowed positions)
    scale = attrs.get("scale")
    return (1.0 / math.sqrt(d)) if scale is None else scale


def _chunk_attn_ref_cost(specs, attrs):
    q, k = specs[0], specs[1]
    b, t, hq, d = q.shape
    s = k.shape[1]
    base = _chunk_attn_cost(specs, attrs)
    # the oracle materialises the GQA-repeated K/V in fp32 plus the dense
    # (B, Hq, T, S) logits and probability tensors
    extra = 4.0 * (2.0 * b * s * hq * d + 2.0 * b * hq * t * s)
    return Cost(flops=base.flops, bytes=base.bytes + extra)


@impl("chunk_attention", "ref", cost_fn=_chunk_attn_ref_cost,
      note="dense offset-causal masked attention in fp32 (the oracle)")
def _chunk_attention_ref(inputs, attrs):
    q, k, v, start = inputs
    b, t, hq, d = q.shape
    s = k.shape[1]
    scale = _chunk_attn_scale(attrs, d)
    kf = R._repeat_kv(k, hq).astype(jnp.float32)
    vf = R._repeat_kv(v, hq).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    qpos = start[:, None] + jnp.arange(t)[None, :]            # (B, T)
    allowed = jnp.arange(s)[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    logits = jnp.where(allowed[:, None, :, :], logits, R._NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return [o.astype(q.dtype)]


@impl("chunk_attention", "xla",
      note="GQA grouped inside the einsum — the repeated-KV expansion is "
           "never materialised; XLA fuses mask+softmax")
def _chunk_attention_xla(inputs, attrs):
    q, k, v, start = inputs
    b, t, hq, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    assert hq % hk == 0, (hq, hk)
    g = hq // hk
    scale = _chunk_attn_scale(attrs, d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, t, hk, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    qpos = start[:, None] + jnp.arange(t)[None, :]              # (B, T)
    allowed = jnp.arange(s)[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    logits = jnp.where(allowed[:, None, None, :, :], logits, R._NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return [o.reshape(b, t, hq, d).astype(q.dtype)]


def _chunk_attn_pallas_supports(specs, attrs):
    q, k = specs[0], specs[1]
    bq = min(int(attrs.get("block_q", 256)), q.shape[1])
    bkv = min(int(attrs.get("block_kv", 512)), k.shape[1])
    return (q.shape[1] % bq == 0 and k.shape[1] % bkv == 0
            and q.shape[2] % k.shape[2] == 0)


@impl("chunk_attention", "pallas", supports=_chunk_attn_pallas_supports,
      note="flash-style online-softmax kernel; per-sequence offset-causal "
           "masking, fully-masked KV blocks skipped")
def _chunk_attention_pallas(inputs, attrs):
    q, k, v, start = inputs
    return [flash_chunk_attention(
        q, k, v, start, scale=attrs.get("scale"),
        block_q=int(attrs.get("block_q", 256)),
        block_kv=int(attrs.get("block_kv", 512)),
        interpret=attrs.get("interpret", pallas_interpret()))]


def chunk_attention(q, k, v, start, *, scale=None, backend: str = "ref", **kw):
    return get_impl("chunk_attention", backend)(
        [q, k, v, start], {"scale": scale, **kw})[0]
