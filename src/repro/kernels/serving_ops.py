"""Serving graph ops — the pieces that let a decoder LM's prefill and
decode steps be expressed as GraphIR and compiled into
:class:`~repro.core.program.Program` artifacts (the serving engine's step
functions in :mod:`repro.runtime.engine`).

Three ops, each with an explicit functional-state contract (caches are
graph inputs AND outputs, so a Program stays a pure function):

* ``embedding``       — token id -> row lookup.
* ``cache_update``    — length-aware scatter of new K/V rows into a
  fixed-capacity cache at per-sequence offsets.  ``n_new`` rows are
  written starting at ``start``; slots with ``n_new == 0`` are untouched,
  which is how one fixed-batch Program serves a mix of active and idle
  slots.
* ``chunk_attention`` — chunked-prefill attention: a chunk of T queries
  at absolute positions ``start .. start+T-1`` attends to cache keys at
  positions ``<= start + t`` (offset-causal).  With T=1 this degenerates
  to single-token decode; the decode graph instead uses the existing
  ``decode_attention`` op so the flash-decode Pallas backend stays
  selectable on the hot path.

All shapes are static (fixed batch = engine slots, fixed chunk size,
fixed cache capacity), so each serving step jits exactly once.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from repro.core.ir import TensorSpec
from repro.core.registry import Cost, defop, get_impl, impl
from repro.kernels import ref as R

__all__ = ["embedding", "cache_update", "chunk_attention"]

Attrs = Dict[str, Any]


def _bytes(specs: Sequence[TensorSpec]) -> float:
    return float(sum(s.nbytes for s in specs))


# --------------------------------------------------------------------------- #
# embedding — inputs (ids (B,T) int32, table (V,D))
# --------------------------------------------------------------------------- #

def _embedding_shape(specs, attrs):
    ids, table = specs
    return [TensorSpec(tuple(ids.shape) + (table.shape[1],), table.dtype)]


def _embedding_cost(specs, attrs):
    out = _embedding_shape(specs, attrs)[0]
    # gather: reads one table row per token + writes the output
    return Cost(flops=0.0, bytes=2.0 * out.nbytes + specs[0].nbytes)


defop("embedding", _embedding_shape, _embedding_cost,
      doc="token embedding lookup; inputs (ids (B,T) int32, table (V,D))")


@impl("embedding", "ref")
def _embedding_ref(inputs, attrs):
    ids, table = inputs
    return [jnp.take(table, ids, axis=0)]


def embedding(ids, table, *, backend: str = "ref", **kw):
    return get_impl("embedding", backend)([ids, table], kw)[0]


# --------------------------------------------------------------------------- #
# cache_update — inputs (cache (B,S,H,D), new (B,T,H,D), start (B,), n_new (B,))
# --------------------------------------------------------------------------- #

def _cache_update_shape(specs, attrs):
    cache, new = specs[0], specs[1]
    if cache.shape[0] != new.shape[0] or cache.shape[2:] != new.shape[2:]:
        raise ValueError(f"cache_update mismatch: {cache.shape} vs {new.shape}")
    if new.shape[1] > cache.shape[1]:
        raise ValueError(f"chunk {new.shape[1]} exceeds cache cap {cache.shape[1]}")
    return [cache]


def _cache_update_cost(specs, attrs):
    new = specs[1]
    # read-modify-write of T rows per sequence; the rest of the cache is
    # untouched (aliasing is XLA's job under jit)
    return Cost(flops=0.0, bytes=3.0 * new.nbytes + _bytes(specs[2:]))


defop("cache_update", _cache_update_shape, _cache_update_cost,
      doc="scatter n_new K/V rows into a cache at per-sequence offsets; "
          "inputs (cache (B,S,H,D), new (B,T,H,D), start (B,), n_new (B,))")


@impl("cache_update", "ref",
      note="vmap'd masked gather/scatter; n_new==0 slots are exact no-ops")
def _cache_update_ref(inputs, attrs):
    cache, new, start, n_new = inputs
    t = new.shape[1]
    cap = cache.shape[1]

    def one(c, x, s, n):
        idx = jnp.clip(s + jnp.arange(t), 0, cap - 1)
        rows = c[idx]
        mask = (jnp.arange(t) < n).reshape((t,) + (1,) * (x.ndim - 1))
        return c.at[idx].set(jnp.where(mask, x, rows))

    return [jax.vmap(one)(cache, new, start, n_new)]


def cache_update(cache, new, start, n_new, *, backend: str = "ref", **kw):
    return get_impl("cache_update", backend)([cache, new, start, n_new], kw)[0]


# --------------------------------------------------------------------------- #
# chunk_attention — inputs (q (B,T,Hq,D), k (B,S,Hk,D), v (B,S,Hk,D), start (B,))
# --------------------------------------------------------------------------- #

def _chunk_attn_shape(specs, attrs):
    return [specs[0]]


def _chunk_attn_cost(specs, attrs):
    q, k = specs[0], specs[1]
    b, t, hq, d = q.shape
    s = k.shape[1]
    return Cost(flops=4.0 * b * hq * t * s * d, bytes=_bytes(specs) + q.nbytes)


defop("chunk_attention", _chunk_attn_shape, _chunk_attn_cost,
      doc="chunked-prefill attention: query t (absolute position start+t) "
          "attends cache keys at positions <= start+t; "
          "inputs (q (B,T,Hq,D), k (B,S,Hk,D), v, start (B,)); attrs: scale")


@impl("chunk_attention", "ref",
      note="dense offset-causal masked attention in fp32 (the oracle)")
def _chunk_attention_ref(inputs, attrs):
    q, k, v, start = inputs
    b, t, hq, d = q.shape
    s = k.shape[1]
    scale = attrs.get("scale") or (1.0 / math.sqrt(d))
    kf = R._repeat_kv(k, hq).astype(jnp.float32)
    vf = R._repeat_kv(v, hq).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    qpos = start[:, None] + jnp.arange(t)[None, :]            # (B, T)
    allowed = jnp.arange(s)[None, None, :] <= qpos[:, :, None]  # (B, T, S)
    logits = jnp.where(allowed[:, None, :, :], logits, R._NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return [o.astype(q.dtype)]


def chunk_attention(q, k, v, start, *, scale=None, backend: str = "ref", **kw):
    return get_impl("chunk_attention", backend)(
        [q, k, v, start], {"scale": scale, **kw})[0]
