"""Pure-jnp oracles for every Pallas kernel in this package.

Every function here is the ground truth its kernel is tested against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes and asserts allclose).
They are also the ``ref`` backends registered in the op registry, and the
differentiable implementations used by training (`jax.grad` flows through
them; the Pallas kernels target the inference hot path — the paper is an
inference framework).

Shape conventions
-----------------
attention:        q (B, Sq, Hq, D), k/v (B, Skv, Hkv, D), Hq % Hkv == 0
decode_attention: q (B, Hq, D),     k/v (B, Skv, Hkv, D), lengths (B,)
ssd (mamba2):     x (B, S, H, P), dt (B, S, H), A (H,), B/C (B, S, G, N)
rmsnorm:          x (..., D), w (D,)
gemm:             x (M, K) @ w (K, N);  batched: (E, M, K) @ (E, K, N)
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref", "decode_attention_ref", "combine_partials_ref",
    "ssd_ref", "ssd_chunked_ref", "ssd_step_ref",
    "rmsnorm_ref", "gemm_ref", "batched_gemm_ref", "swiglu_ref",
]

_NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


def _repeat_kv(k: jax.Array, hq: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hq, D) by repeating each kv head."""
    hkv = k.shape[2]
    if hkv == hq:
        return k
    assert hq % hkv == 0, (hq, hkv)
    return jnp.repeat(k, hq // hkv, axis=2)


def attention_mask(sq: int, skv: int, *, causal: bool,
                   window: Optional[int] = None, offset: int = 0) -> jax.Array:
    """(Sq, Skv) boolean mask. ``offset`` is the absolute position of query
    row 0 minus key col 0 (for decode/chunked prefill: offset = skv - sq)."""
    row = jnp.arange(sq)[:, None] + offset
    col = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), dtype=bool)
    if causal:
        m &= col <= row
    if window is not None:
        m &= col > row - window
    return m


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Full (training/prefill) attention with GQA, causal + sliding window."""
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    mask = attention_mask(sq, skv, causal=causal, window=window,
                          offset=skv - sq)
    s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: Optional[jax.Array] = None, *,
                         scale: Optional[float] = None) -> jax.Array:
    """One-new-token attention against a KV cache.

    q (B, Hq, D); k/v (B, Skv, Hkv, D); lengths (B,) int32 = #valid cache
    entries per sequence (the new token's own K/V already written at
    position lengths-1)."""
    b, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if lengths is not None:
        valid = jnp.arange(skv)[None, None, :] < lengths[:, None, None]
        s = jnp.where(valid, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def combine_partials_ref(outs: jax.Array, ms: jax.Array,
                         ls: jax.Array) -> jax.Array:
    """Combine flash partials over a leading 'split' axis.

    outs (S, ..., D) unnormalised accumulators, ms (S, ...) running max,
    ls (S, ...) running sum-of-exp. Returns the exact softmax-weighted
    output — the tree/sequence-parallel decode combiner."""
    m = jnp.max(ms, axis=0)
    alpha = jnp.exp(ms - m[None])          # (S, ...)
    l = jnp.sum(ls * alpha, axis=0)
    o = jnp.sum(outs * alpha[..., None], axis=0)
    return o / jnp.maximum(l, 1e-30)[..., None]


# --------------------------------------------------------------------------- #
# Mamba2 SSD
# --------------------------------------------------------------------------- #

def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, D: Optional[jax.Array] = None,
            init_state: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Sequential state-space-duality recurrence (the exact oracle).

    x (B,S,H,P), dt (B,S,H), A (H,) negative, B/C (B,S,G,N) with H % G == 0.
    Returns y (B,S,H,P) and final state (B,H,P,N).

        a_t   = exp(dt_t * A)            (per head, scalar)
        S_t   = a_t S_{t-1} + (dt_t x_t) B_t^T   (P x N)
        y_t   = S_t C_t + D x_t
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hpg = h // g
    Bh = jnp.repeat(B, hpg, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(C, hpg, axis=2)
    a = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :])
    xbar = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def step(state, inp):
        a_t, xb_t, B_t, C_t = inp  # (B,H), (B,H,P), (B,H,N), (B,H,N)
        state = state * a_t[..., None, None] + xb_t[..., None] * B_t[:, :, None, :]
        y_t = jnp.einsum("bhpn,bhn->bhp", state, C_t)
        return state, y_t

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(xbar, 1, 0),
          jnp.moveaxis(Bh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Ch.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_step_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, D: Optional[jax.Array],
                 state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x (B,H,P), dt (B,H), B/C (B,G,N), state (B,H,P,N).
    Returns (y (B,H,P), new_state)."""
    y, new_state = ssd_ref(x[:, None], dt[:, None], A, B[:, None], C[:, None],
                           D, init_state=state)
    return y[:, 0], new_state


def ssd_chunked_ref(x, dt, A, B, C, D=None, init_state=None, chunk: int = 64):
    """Chunked SSD in pure jnp — the algorithm the Pallas kernel implements
    (intra-chunk quadratic + inter-chunk state carry), kept here both as
    documentation and as a second oracle for the kernel's block math."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    nc = s // chunk
    hpg = h // g
    Bh = jnp.repeat(B, hpg, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, hpg, axis=2).astype(jnp.float32)
    la = (dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :])  # log a
    xbar = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    def reshape_c(t):  # (B,S,...) -> (nc, B, chunk, ...)
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    las, xs, Bs, Cs = map(reshape_c, (la, xbar, Bh, Ch))
    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def chunk_step(state, inp):
        lac, xc, Bc, Cc = inp          # (B,chunk,H[,*])
        cs = jnp.cumsum(lac, axis=1)   # (B,chunk,H) inclusive logs
        # intra: y[i] = sum_{j<=i} exp(cs_i - cs_j) (C_i . B_j) xbar_j
        smat = jnp.einsum("bihn,bjhn->bhij", Cc, Bc)
        dec = cs[:, :, None, :] - cs[:, None, :, :]          # (B,i,j,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(dec), 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", smat * jnp.moveaxis(L, 3, 1), xc)
        # inter: y[i] += exp(cs_i) C_i . state
        y_inter = jnp.einsum("bihn,bhpn->bihp", Cc, state) * jnp.exp(cs)[..., None]
        # state update: S' = exp(cs_last) S + sum_j exp(cs_last - cs_j) xbar_j B_j^T
        w = jnp.exp(cs[:, -1:, :] - cs)                       # (B,chunk,H)
        state = (state * jnp.exp(cs[:, -1, :])[..., None, None]
                 + jnp.einsum("bjhp,bjhn->bhpn", xc * w[..., None], Bc))
        return state, y_intra + y_inter

    from repro.analysis import unrolling
    if unrolling():
        # analysis mode: scans hide their trip count from cost_analysis —
        # run the chunk loop as Python (numerics identical; tests assert)
        state, ys_l = state0, []
        for ci in range(nc):
            state, y_c = chunk_step(state, (las[ci], xs[ci], Bs[ci], Cs[ci]))
            ys_l.append(y_c)
        final, ys = state, jnp.stack(ys_l)
    else:
        final, ys = jax.lax.scan(chunk_step, state0, (las, xs, Bs, Cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    if D is not None:
        y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


# --------------------------------------------------------------------------- #
# RMSNorm / GEMM / SwiGLU
# --------------------------------------------------------------------------- #

def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                residual: Optional[jax.Array] = None) -> jax.Array:
    """RMSNorm with optional fused residual add (norm(x + residual))."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def batched_gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(E, M, K) @ (E, K, N) -> (E, M, N)."""
    return jnp.einsum("emk,ekn->emn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def swiglu_ref(gate: jax.Array, up: jax.Array) -> jax.Array:
    return (jax.nn.silu(gate.astype(jnp.float32))
            * up.astype(jnp.float32)).astype(gate.dtype)
