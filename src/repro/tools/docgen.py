"""Registry-derived documentation tables — docs that cannot drift.

Generates markdown tables straight from the live registries and splices
them into marker blocks inside checked-in markdown files:

* ``registry-tables`` (README.md) — every registered op (with its
  backends) and every registered pass
  (:func:`repro.core.registered_ops` / :func:`repro.core.registered_passes`);
* ``serving-ops`` (docs/architecture.md §6) — the serving hot-path ops:
  one row per (op, backend) with the backend's ``supports()`` constraint
  and cost-model provenance, pulled from the
  :class:`repro.core.registry.OpImpl` metadata.

Marker blocks look like::

    <!-- BEGIN GENERATED: registry-tables -->
    ...regenerated content...
    <!-- END GENERATED: registry-tables -->

Every marker pair found in a file is regenerated; unknown block names are
an error (a typo'd marker would otherwise rot silently).

Usage::

    python -m repro.tools.docgen                           # print all tables
    python -m repro.tools.docgen --update README.md --update docs/architecture.md
    python -m repro.tools.docgen --check README.md --check docs/architecture.md

CI runs ``--check`` on both files, so a new op/pass/backend (or an edited
``supports()`` constraint) that isn't re-generated into the docs fails
the build.
"""

from __future__ import annotations

import argparse
import re
import sys

__all__ = ["ops_table", "passes_table", "serving_ops_table",
           "splice", "main"]

_MARKER_RE = re.compile(
    r"<!-- BEGIN GENERATED: ([\w-]+) -->.*?<!-- END GENERATED: \1 -->",
    re.DOTALL)

# ops on the serving hot path (the engine's prefill/decode Programs plus
# the speculative draft/verify Programs), dense and paged — the §6
# reference table documents exactly these
SERVING_OPS = ("embedding", "cache_update", "chunk_attention",
               "decode_attention", "verify_attention", "greedy_token",
               "paged_cache_update",
               "paged_chunk_attention", "paged_decode_attention",
               "paged_verify_attention",
               "paged_cache_update_q", "paged_chunk_attention_q",
               "paged_decode_attention_q", "paged_verify_attention_q")


def _first_line(text: str) -> str:
    for line in (text or "").strip().splitlines():
        line = line.strip()
        if line:
            return line.replace("|", "\\|")  # keep markdown table cells intact
    return ""


def _one_line(text: str) -> str:
    """Whole docstring collapsed to one markdown-safe line (supports() and
    cost_fn docstrings wrap; truncating at the first physical line would
    ship cells cut mid-sentence)."""
    return " ".join((text or "").split()).replace("|", "\\|")


def ops_table() -> str:
    """Markdown table of every registered op: backends + one-line doc."""
    from repro.core import get_op, registered_ops
    rows = ["| op | backends | doc |", "|---|---|---|"]
    for name in registered_ops():
        op = get_op(name)
        backends = ", ".join(
            f"`{b}`" for b in sorted(op.impls, key=lambda b: (b != "ref", b)))
        rows.append(f"| `{name}` | {backends} | {_first_line(op.doc)} |")
    return "\n".join(rows)


def passes_table() -> str:
    """Markdown table of every registered pass + first docstring line."""
    from repro.core import get_pass, registered_passes
    rows = ["| pass | summary |", "|---|---|"]
    for name in registered_passes():
        if name.startswith("_"):
            continue  # test-registered scratch passes
        rows.append(f"| `{name}` | {_first_line(get_pass(name).__doc__)} |")
    return "\n".join(rows)


def serving_ops_table() -> str:
    """Markdown reference of the serving ops: one row per (op, backend)
    with the ``supports()`` constraint (the guard function's docstring —
    '(none)' for unconditional backends) and the cost model in effect
    (per-impl override docstring, or the op-level default)."""
    from repro.core import get_op
    rows = ["| op | backend | supports() constraint | cost model | note |",
            "|---|---|---|---|---|"]
    for name in SERVING_OPS:
        op = get_op(name)
        for backend in sorted(op.impls, key=lambda b: (b != "ref", b)):
            im = op.impls[backend]
            guard = _one_line(getattr(im.supports, "__doc__", "")) or "(none)"
            cost = (_one_line(getattr(im.cost_fn, "__doc__", ""))
                    if im.cost_fn is not None else "op default")
            rows.append(f"| `{name}` | `{backend}` | {guard} | {cost} | "
                        f"{_one_line(im.note) or '-'} |")
    return "\n".join(rows)


def _block(name: str) -> str:
    import repro  # noqa: F401  (registers all ops, passes and backends)
    if name == "registry-tables":
        body = (f"### Registered passes\n\n{passes_table()}\n\n"
                f"### Registered ops\n\n{ops_table()}")
    elif name == "serving-ops":
        body = (f"### Serving ops & backends (generated)\n\n"
                f"{serving_ops_table()}")
    else:
        raise SystemExit(f"unknown generated block {name!r}; "
                         f"known: registry-tables, serving-ops")
    return (f"<!-- BEGIN GENERATED: {name} -->\n{body}\n"
            f"<!-- END GENERATED: {name} -->")


def splice(text: str) -> str:
    """Regenerate every marker block found in ``text``."""
    if not _MARKER_RE.search(text):
        raise SystemExit(
            "no marker block found; add\n"
            "<!-- BEGIN GENERATED: <name> -->\n<!-- END GENERATED: <name> -->\n"
            "to the file first")
    return _MARKER_RE.sub(lambda m: _block(m.group(1)), text)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", metavar="FILE", action="append", default=[],
                    help="rewrite marker blocks in FILE (repeatable)")
    ap.add_argument("--check", metavar="FILE", action="append", default=[],
                    help="exit 1 when FILE's marker blocks are stale "
                         "(repeatable)")
    args = ap.parse_args(argv)
    stale = 0
    for path in args.update:
        with open(path) as f:
            text = f.read()
        new = splice(text)
        if new != text:
            with open(path, "w") as f:
                f.write(new)
            print(f"updated {path}")
        else:
            print(f"{path} already up to date")
    for path in args.check:
        with open(path) as f:
            text = f.read()
        if splice(text) != text:
            print(f"{path} is stale: run "
                  f"`python -m repro.tools.docgen --update {path}`",
                  file=sys.stderr)
            stale += 1
        else:
            print(f"{path} generated blocks up to date")
    if not args.update and not args.check:
        print(_block("registry-tables"))
        print()
        print(_block("serving-ops"))
    return 1 if stale else 0


if __name__ == "__main__":
    raise SystemExit(main())
