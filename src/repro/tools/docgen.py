"""Registry-derived documentation tables — docs that cannot drift.

Generates markdown tables of every registered op (with its backends) and
every registered pass straight from the live registries
(:func:`repro.core.registered_ops` / :func:`repro.core.registered_passes`),
and splices them into README.md between marker comments:

    <!-- BEGIN GENERATED: registry-tables -->
    ...regenerated content...
    <!-- END GENERATED: registry-tables -->

Usage::

    python -m repro.tools.docgen                    # print tables
    python -m repro.tools.docgen --update README.md # rewrite marker block
    python -m repro.tools.docgen --check README.md  # exit 1 when stale

CI runs ``--check`` so a new op/pass/backend that isn't re-generated into
the README fails the build.
"""

from __future__ import annotations

import argparse
import sys

BEGIN = "<!-- BEGIN GENERATED: registry-tables -->"
END = "<!-- END GENERATED: registry-tables -->"

__all__ = ["ops_table", "passes_table", "generated_block", "splice", "main"]


def _first_line(text: str) -> str:
    for line in (text or "").strip().splitlines():
        line = line.strip()
        if line:
            return line.replace("|", "\\|")  # keep markdown table cells intact
    return ""


def ops_table() -> str:
    """Markdown table of every registered op: backends + one-line doc."""
    from repro.core import get_op, registered_ops
    rows = ["| op | backends | doc |", "|---|---|---|"]
    for name in registered_ops():
        op = get_op(name)
        backends = ", ".join(
            f"`{b}`" for b in sorted(op.impls, key=lambda b: (b != "ref", b)))
        rows.append(f"| `{name}` | {backends} | {_first_line(op.doc)} |")
    return "\n".join(rows)


def passes_table() -> str:
    """Markdown table of every registered pass + first docstring line."""
    from repro.core import get_pass, registered_passes
    rows = ["| pass | summary |", "|---|---|"]
    for name in registered_passes():
        if name.startswith("_"):
            continue  # test-registered scratch passes
        rows.append(f"| `{name}` | {_first_line(get_pass(name).__doc__)} |")
    return "\n".join(rows)


def generated_block() -> str:
    import repro  # noqa: F401  (registers all ops, passes and backends)
    return (f"{BEGIN}\n"
            f"### Registered passes\n\n{passes_table()}\n\n"
            f"### Registered ops\n\n{ops_table()}\n"
            f"{END}")


def splice(text: str) -> str:
    """Replace the marker block inside ``text`` with fresh content."""
    try:
        head, rest = text.split(BEGIN, 1)
        _, tail = rest.split(END, 1)
    except ValueError:
        raise SystemExit(
            f"marker block not found; add\n{BEGIN}\n{END}\nto the file first")
    return head + generated_block() + tail


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", metavar="FILE", help="rewrite marker block in FILE")
    ap.add_argument("--check", metavar="FILE",
                    help="exit 1 when FILE's marker block is stale")
    args = ap.parse_args(argv)
    if args.update:
        with open(args.update) as f:
            text = f.read()
        new = splice(text)
        if new != text:
            with open(args.update, "w") as f:
                f.write(new)
            print(f"updated {args.update}")
        else:
            print(f"{args.update} already up to date")
        return 0
    if args.check:
        with open(args.check) as f:
            text = f.read()
        if splice(text) != text:
            print(f"{args.check} is stale: run "
                  f"`python -m repro.tools.docgen --update {args.check}`",
                  file=sys.stderr)
            return 1
        print(f"{args.check} registry tables up to date")
        return 0
    print(generated_block())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
