"""EXPERIMENTS.md §Dry-run / §Roofline table generation from
experiments/dryrun/*.json, plus Program memory-footprint reporting.

    PYTHONPATH=src python -m repro.tools.report [--dir experiments/dryrun]

Prints markdown to stdout; the checked-in EXPERIMENTS.md embeds the output.

The footprint helpers (:func:`weight_bytes`, :func:`activation_bytes`,
:func:`footprint_table`) are how quantization wins show up in reports: an
int8-quantized :class:`~repro.core.program.Program` stores 1-byte weight
params, so its weight-bytes column is ~4x smaller than the fp32 build of
the same graph.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["load_records", "roofline_table", "dryrun_table",
           "weight_bytes", "activation_bytes", "footprint_table",
           "serving_table", "backend_table", "paged_table", "load_table",
           "spec_table", "sharded_table", "overload_table"]


def load_records(dirpath: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def _fmt_s(x) -> str:
    # None = "no samples" (empty metric windows serialize as null +
    # n_samples=0, never as a perfect-looking 0.0) -> render an em dash
    if x is None:
        return "—"
    if x == 0:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def _fmt_count(x, spec: str = ".0f") -> str:
    """Format a percentile value that is ``None`` when the window had no
    samples."""
    return "—" if x is None else f"{x:{spec}}"


# --------------------------------------------------------------------------- #
# Memory footprint — the quantization-visible column
# --------------------------------------------------------------------------- #

def weight_bytes(obj) -> int:
    """Total bytes of stored parameters for a Graph or Program.  This is
    the on-device (and on-disk ``weights.npz``) weight footprint; int8
    quantization shrinks it ~4x."""
    graph = getattr(obj, "graph", obj)
    return int(sum(np.asarray(v).nbytes for v in graph.params.values()))


def activation_bytes(obj) -> int:
    """Peak-ish activation footprint: sum of all intermediate value sizes
    from ``value_info`` (an upper bound — liveness not modelled)."""
    graph = getattr(obj, "graph", obj)
    inter = set(graph.value_info) - set(graph.inputs) - set(graph.params)
    return int(sum(graph.value_info[v].nbytes for v in inter))


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GB"


def footprint_table(entries: Sequence[Tuple[str, object]]) -> str:
    """Markdown memory-footprint table for ``(label, Program)`` pairs:
    node count, weight bytes, activation bytes, and analytic cost totals.
    The weight-bytes column is where an int8 Program shows its ~4x win
    over the fp32 compile of the same graph."""
    out = ["| program | nodes | weight bytes | activation bytes | "
           "GFLOPs | GB moved |",
           "|---|---|---|---|---|---|"]
    for label, prog in entries:
        graph = getattr(prog, "graph", prog)
        total = prog.total_cost() if hasattr(prog, "total_cost") else None
        gflops = f"{total.flops/1e9:.2f}" if total else "-"
        gb = f"{total.bytes/1e9:.3f}" if total else "-"
        out.append(f"| {label} | {len(graph.nodes)} | "
                   f"{_fmt_bytes(weight_bytes(graph))} | "
                   f"{_fmt_bytes(activation_bytes(graph))} | {gflops} | {gb} |")
    return "\n".join(out)


# --------------------------------------------------------------------------- #
# Serving metrics — benchmarks/serve_bench.py JSON records
# --------------------------------------------------------------------------- #

def serving_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown serving-metrics table from ``(label, record)`` pairs, where
    each record is one ``benchmarks/serve_bench.py`` JSON output: engine
    tokens/s vs the unbatched loop, p50/p95 latency, time-to-first-token,
    busy-slot fraction, and the chunked-prefill inter-token gap against
    one full-prompt prefill."""
    out = ["| config | tok/s | vs unbatched | p50 | p95 | ttft p50 | "
           "busy | max gap (chunked) | full prefill |",
           "|---|---|---|---|---|---|---|---|---|"]
    for label, rec in records:
        eng = rec["engine"]
        gap = rec.get("prefill_gap", {})
        out.append(
            f"| {label} | {eng['tokens_per_s']:,.0f} | "
            f"{rec.get('speedup', 0):.2f}x | "
            f"{_fmt_s(eng['latency_s']['p50'])} | "
            f"{_fmt_s(eng['latency_s']['p95'])} | "
            f"{_fmt_s(eng['ttft_s']['p50'])} | "
            f"{eng['busy_slot_fraction']:.0%} | "
            f"{_fmt_s(gap.get('max_gap_chunked_s', 0))} | "
            f"{_fmt_s(gap.get('full_prefill_s', 0))} |")
    return "\n".join(out)


def spec_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown speculative-decoding table from serve_bench JSON records
    (the ``"spec"`` section): draft depth and width, accept rate, decode
    tokens/s speculative vs baseline with the measured speedup, and the
    token-exactness flag against the unbatched reference."""
    out = ["| config | draft layers | K | accept rate | decode tok/s "
           "(spec) | decode tok/s (base) | speedup | exact |",
           "|---|---|---|---|---|---|---|---|"]
    for label, rec in records:
        sp = rec.get("spec")
        if not sp:
            continue
        out.append(
            f"| {label} | {sp['draft_layers']}/{sp['n_layers']} | "
            f"{sp['spec_k']} | {sp['accept_rate']:.0%} | "
            f"{sp['decode_tok_s_spec']:,.0f} | "
            f"{sp['decode_tok_s_base']:,.0f} | "
            f"{sp['decode_speedup']:.2f}x | "
            f"{'yes' if sp.get('token_exact') else 'NO'} |")
    return "\n".join(out)


def sharded_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown tensor-parallel serving table from serve_bench JSON
    records (the ``"sharded"`` section, schema v5): decode tokens/s and
    peak concurrent requests at TP=1 vs TP=N, plus the token-identity
    flag (the tp backends promise bitwise-exact serving — ``NO`` here is
    a bug, not a tolerance).  Disabled records render their reason so a
    single-device run is visibly "not measured" rather than silently
    absent."""
    out = ["| config | TP | decode tok/s (TP=1) | decode tok/s (TP=N) | "
           "peak concurrent (TP=1 / TP=N) | exact |",
           "|---|---|---|---|---|---|"]
    for label, rec in records:
        sh = rec.get("sharded")
        if not sh:
            continue
        if not sh.get("enabled"):
            out.append(f"| {label} | — | — | — | — | "
                       f"disabled: {sh.get('reason', '?')} |")
            continue
        tpk = f"tp{sh['tp']}"
        out.append(
            f"| {label} | {sh['tp']} | "
            f"{sh['tp1']['decode_tok_s']:,.0f} | "
            f"{sh[tpk]['decode_tok_s']:,.0f} | "
            f"{sh['tp1']['peak_concurrent']} / "
            f"{sh[tpk]['peak_concurrent']} | "
            f"{'yes' if sh.get('token_exact') else 'NO'} |")
    return "\n".join(out)


def _fmt_assignment(assignment: Dict) -> str:
    """``{phase: {op: {backend: n}}}`` -> ``op=backend`` summary (majority
    backend per op across phases)."""
    merged: Dict[str, Dict[str, int]] = {}
    for per_op in assignment.values():
        for op, counts in per_op.items():
            agg = merged.setdefault(op, {})
            for b, n in counts.items():
                agg[b] = agg.get(b, 0) + n
    return ", ".join(f"{op}={max(c, key=c.get)}"
                     for op, c in sorted(merged.items()))


def backend_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown per-backend serving throughput table from serve_bench JSON
    records: for each config, one row per swept backend with prefill and
    decode step tokens/s (absolute and vs the ref row), plus what the
    autotuner chose for the serving ops on this machine."""
    out = ["| config | serving backends | prefill tok/s | vs ref | "
           "decode tok/s | vs ref |",
           "|---|---|---|---|---|---|"]
    for label, rec in records:
        for name, row in rec.get("backend_sweep", {}).items():
            out.append(
                f"| {label} | {name} | {row['prefill_tok_s']:,.0f} | "
                f"{row['prefill_vs_ref']:.2f}x | {row['decode_tok_s']:,.0f} | "
                f"{row['decode_vs_ref']:.2f}x |")
        at = rec.get("autotune")
        if at:
            out.append(f"| {label} | autotuned: {_fmt_assignment(at['assignment'])} "
                       f"| - | - | - | - |")
    return "\n".join(out)


def _bytes_per_token(pg: Dict) -> str:
    """KV bytes per cached token for one paged section (page_bytes spread
    over the page_size rows it stores — includes int8 scale sidecars)."""
    pb, ps = pg.get("page_bytes"), pg.get("page_size")
    return f"{pb / ps:.0f}" if pb and ps else "-"


def paged_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown paged-KV-cache table from serve_bench JSON records (the
    ``"paged"`` and ``"paged_kv8"`` sections): KV dtype and bytes/token,
    concurrent-request capacity at equal memory (dense vs paged for fp32
    rows; fp32-paged vs int8-paged at equal pool bytes for kv8 rows),
    prefix-hit vs cold TTFT with the deterministic prefill-tick counts,
    prefix hit rate, CoW count and internal fragmentation of the pool."""
    out = ["| config | kv dtype | page x blocks | B/token | "
           "concurrent (at equal memory) | ttft cold | ttft hit | "
           "prefill ticks (cold -> hit) | hit rate | CoW | frag | exact |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for label, rec in records:
        for key in ("paged", "paged_kv8"):
            pg = rec.get(key)
            if not pg:
                continue
            cap, pre = pg["capacity"], pg["prefix"]
            pool = pg.get("pool", {})
            if key == "paged":
                conc = (f"dense {cap['dense_concurrent']} -> "
                        f"paged {cap['paged_concurrent']} "
                        f"({cap['ratio']:.1f}x)")
                ticks = (f"{pre['prefill_ticks_cold']} -> "
                         f"{pre['prefill_ticks_hit']}")
                cold_s = _fmt_s(pre.get("ttft_cold_s") or 0)
                hit_s = _fmt_s(pre.get("ttft_hit_s") or 0)
                exact = bool(pg.get("token_exact"))
            else:
                r = cap.get("equal_memory_vs_fp32_paged", 0.0)
                conc = (f"fp32 {cap['fp32_paged_concurrent']} -> "
                        f"int8 {cap['paged_concurrent']} ({r:.1f}x)")
                ticks = cold_s = hit_s = "-"
                exact = bool(pg.get("token_exact", {}).get("all"))
            out.append(
                f"| {label} | {pg.get('kv_dtype', 'float32')} | "
                f"{pg['page_size']} x {pg['n_blocks']} | "
                f"{_bytes_per_token(pg)} | {conc} | {cold_s} | {hit_s} | "
                f"{ticks} | {pool.get('hit_rate', 0):.0%} | "
                f"{pool.get('cow_count', 0)} | "
                f"{pool.get('fragmentation', 0):.0%} | "
                f"{'yes' if exact else 'NO'} |")
    return "\n".join(out)


def load_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown SLO-goodput table from serve_bench JSON records (the
    ``"load"`` section): one row per (config, tier) plus an overall row —
    offered/finished/shed/dropped counts, SLO attainment, goodput in
    requests/s, and the deterministic p99 TTFT and inter-token gap in
    engine ticks against the SLO bounds.

    A tier with zero finished requests (everything shed or expired under
    overload) reports ``slo_attainment: null`` — there is nothing to
    attain over — and renders as an em dash, mirroring the empty-window
    percentile contract."""
    out = ["| config | tier | offered | finished | shed | dropped | "
           "SLO met | attainment | goodput req/s | ttft p99 (ticks) | "
           "gap p99 (ticks) |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for label, rec in records:
        ld = rec.get("load")
        if not ld:
            continue
        slo = ld.get("slo", {})
        rows = [("overall", ld["overall"])]
        rows += sorted(ld.get("tiers", {}).items())
        for tier, tr in rows:
            out.append(
                f"| {label} | {tier} | {tr['n_offered']} | "
                f"{tr['n_finished']} | {tr['n_shed']} | {tr['n_dropped']} | "
                f"{tr['n_slo_met']} | {_fmt_count(tr['slo_attainment'], '.0%')} | "
                f"{tr['goodput_requests_per_s']:.1f} | "
                f"{_fmt_count(tr['ttft_ticks']['p99'])} / "
                f"{slo.get('ttft_ticks', '-')} | "
                f"{_fmt_count(tr['gap_ticks']['p99'])} / "
                f"{slo.get('gap_ticks', '-')} |")
    return "\n".join(out)


def overload_table(records: Sequence[Tuple[str, Dict]]) -> str:
    """Markdown overload-scheduling table from serve_bench JSON records
    (the ``"overload"`` section, schema v6): the same 2x-offered-load
    trace replayed under the tier-blind FIFO baseline and under
    tier-aware shedding/preemption, one row per (config, policy, tier).
    The attainment column is **SLO-met over OFFERED** (the section's
    headline metric — a request shed at admission did not meet its SLO;
    met-over-finished would hide exactly the baseline's failure mode).
    The headline claim is the high-tier rows: tier-aware must strictly
    beat tier-blind on attainment (``validate_record`` enforces this
    before artifacts upload).  Zero-offered tiers render an em dash,
    never a fake 0% or 100%."""
    out = ["| config | policy | tier | offered | finished | shed | "
           "dropped | attainment (met/offered) | preempted | tier-shed |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for label, rec in records:
        ov = rec.get("overload")
        if not ov:
            continue
        for policy in ("tier_blind", "tier_aware"):
            pol = ov["policies"][policy]
            rep = pol["report"]
            for tier, tr in sorted(rep.get("tiers", {}).items()):
                mark = " *" if tier == ov.get("high_tier") else ""
                att = (tr["n_slo_met"] / tr["n_offered"]
                       if tr["n_offered"] else None)
                out.append(
                    f"| {label} | {policy} | {tier}{mark} | "
                    f"{tr['n_offered']} | {tr['n_finished']} | "
                    f"{tr['n_shed']} | {tr['n_dropped']} | "
                    f"{_fmt_count(att, '.0%')} | "
                    f"{pol['n_preempted']} | {pol['n_tier_shed']} |")
    return "\n".join(out)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful ratio | GB/dev | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - "
                       f"| skipped: {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - "
                       f"| ERROR {r.get('error','')[:40]} |")
            continue
        note = ""
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
            f"{r['bytes_per_device']/1e9:.1f} | {note} |")
    return "\n".join(out)


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | HLO FLOPs/dev | bytes/dev | "
           "wire B/dev | collectives | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | - | - | - | - | - |")
            continue
        cols = ", ".join(f"{k}x{v}" for k, v in sorted(
            r.get("counts", {}).items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['hlo_flops']:.2e} | {r['bytes_per_device']/1e9:.1f}G | "
            f"{r['wire_bytes_per_chip']:.2e} | {cols} | "
            f"{r.get('compile_s','-')} |")
    return "\n".join(out)


def summary_stats(recs: List[Dict]) -> str:
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    lines = [f"- cells: {len(recs)} ({len(ok)} compiled ok, "
             f"{len(skipped)} documented skips, {len(err)} errors)"]
    for mesh in ("single", "multipod"):
        ms = [r for r in ok if r["mesh"] == mesh]
        if ms:
            bn: Dict[str, int] = {}
            for r in ms:
                bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
            lines.append(f"- {mesh}: bottleneck distribution {bn}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--serve-dir", default="experiments/serve",
                    help="directory of serve_bench JSON records")
    args = ap.parse_args()
    serve = [(os.path.splitext(os.path.basename(f))[0], json.load(open(f)))
             for f in sorted(glob.glob(os.path.join(args.serve_dir, "*.json")))]
    if serve:
        print("## Serving (benchmarks/serve_bench.py)\n")
        print(serving_table(serve))
        print()
        if any("backend_sweep" in rec or "autotune" in rec
               for _, rec in serve):
            print("## Serving-op backends (serve_bench backend sweep)\n")
            print(backend_table(serve))
            print()
        if any("paged" in rec or "paged_kv8" in rec for _, rec in serve):
            print("## Paged KV cache (serve_bench paged section)\n")
            print(paged_table(serve))
            print()
        if any("spec" in rec for _, rec in serve):
            print("## Speculative decoding (serve_bench spec section)\n")
            print(spec_table(serve))
            print()
        if any("load" in rec for _, rec in serve):
            print("## SLO goodput (serve_bench load section)\n")
            print(load_table(serve))
            print()
        if any("overload" in rec for _, rec in serve):
            print("## Tier-aware overload (serve_bench overload section)\n")
            print(overload_table(serve))
            print()
        if any("sharded" in rec for _, rec in serve):
            print("## Tensor-parallel serving (serve_bench sharded "
                  "section)\n")
            print(sharded_table(serve))
            print()
    recs = load_records(args.dir)
    print("## Summary\n")
    print(summary_stats(recs))
    print("\n## Roofline (single-pod 16x16, per-chip seconds)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "multipod"))
    print("\n## Dry-run raw\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
