"""EXPERIMENTS.md §Dry-run / §Roofline table generation from
experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.tools.report [--dir experiments/dryrun]

Prints markdown to stdout; the checked-in EXPERIMENTS.md embeds the output.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

__all__ = ["load_records", "roofline_table", "dryrun_table"]


def load_records(dirpath: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful ratio | GB/dev | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - "
                       f"| skipped: {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - "
                       f"| ERROR {r.get('error','')[:40]} |")
            continue
        note = ""
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
            f"{r['bytes_per_device']/1e9:.1f} | {note} |")
    return "\n".join(out)


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | HLO FLOPs/dev | bytes/dev | "
           "wire B/dev | collectives | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | - | - | - | - | - |")
            continue
        cols = ", ".join(f"{k}x{v}" for k, v in sorted(
            r.get("counts", {}).items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['hlo_flops']:.2e} | {r['bytes_per_device']/1e9:.1f}G | "
            f"{r['wire_bytes_per_chip']:.2e} | {cols} | "
            f"{r.get('compile_s','-')} |")
    return "\n".join(out)


def summary_stats(recs: List[Dict]) -> str:
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    lines = [f"- cells: {len(recs)} ({len(ok)} compiled ok, "
             f"{len(skipped)} documented skips, {len(err)} errors)"]
    for mesh in ("single", "multipod"):
        ms = [r for r in ok if r["mesh"] == mesh]
        if ms:
            bn: Dict[str, int] = {}
            for r in ms:
                bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
            lines.append(f"- {mesh}: bottleneck distribution {bn}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print("## Summary\n")
    print(summary_stats(recs))
    print("\n## Roofline (single-pod 16x16, per-chip seconds)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "multipod"))
    print("\n## Dry-run raw\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
