"""Analysis tooling: roofline derivation from compiled HLO."""

from repro.tools import roofline  # noqa: F401
