"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs            / (chips x peak_FLOP/s)
    memory     = HLO_bytes_accessed   / (chips x HBM_bw)
    collective = wire_bytes           / (chips x link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program,
all chips together — we divide by chip count assuming SPMD balance, which
holds for our pjit programs).  wire_bytes comes from parsing the
post-SPMD HLO text: for every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute we take the RESULT buffer size and convert
to per-chip wire traffic with the standard ring costs over the collective's
participant count:

    all-reduce      2 (n-1)/n x size     all-gather      (n-1)/n x size
    reduce-scatter  (n-1)/n x size(in)   all-to-all      (n-1)/n x size
    collective-permute   1 x size

Pallas caveat: XLA cost analysis cannot see inside custom calls, so when a
program embeds Pallas kernels the tool adds back analytic FLOPs/bytes from
the registry cost models (``extra_cost``); with the default ref/xla
backends the numbers are pure-HLO.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["RooflineReport", "analyze", "collective_bytes", "V5E"]


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float      # per chip, bf16
    hbm_bw: float          # per chip, B/s
    link_bw: float         # per link, B/s


V5E = Hardware("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  %all-reduce.5 = f32[256,14336]{1,0} all-reduce(...)
#       ROOT %r = (bf16[8,128], bf16[8,128]) all-to-all(...)
_COLL_RE = re.compile(
    r"=\s*(?P<sig>\([^)]*\)|\S+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _sig_bytes(sig: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(sig):
        bytes_per = _DTYPE_BYTES.get(m.group("dt"))
        if bytes_per is None:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * bytes_per
    return total


def _participants(line: str, total_devices: int) -> int:
    m = _GROUPS_ARR_RE.search(line)       # replica_groups=[16,16] form
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return max(1, len(first.split(",")))
    return total_devices


def collective_bytes(hlo_text: str, total_devices: int
                     ) -> Tuple[float, Dict[str, float], Dict[str, int]]:
    """Per-chip wire bytes (ring model), per-op-type breakdown, op counts.

    Result-buffer sizes in the post-SPMD module are PER-SHARD, so the sum
    over ops of ring-model wire traffic is already per-chip."""
    per_type: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        size = _sig_bytes(m.group("sig"))
        n = max(_participants(line, total_devices), 1)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op in ("all-gather", "all-to-all"):
            wire = (n - 1) / n * size
        elif op == "reduce-scatter":
            wire = (n - 1) / n * size * n     # input = result x n
        else:  # collective-permute
            wire = size
        per_type[op] = per_type.get(op, 0.0) + wire
        counts[op] = counts.get(op, 0) + 1
    return sum(per_type.values()), per_type, counts


@dataclass
class RooflineReport:
    cell: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float            # MODEL_FLOPS / HLO_FLOPs
    roofline_s: float              # max of the three terms
    per_type: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_per_device: float = 0.0
    extra: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1, sort_keys=True)


def analyze(cell: str, mesh_name: str, chips: int, cost: Dict[str, float],
            hlo_text: str, model_flops: float, hw: Hardware = V5E,
            bytes_per_device: float = 0.0,
            extra_cost: Optional[Tuple[float, float]] = None,
            extra: Optional[Dict[str, Any]] = None) -> RooflineReport:
    # cost_analysis runs on the post-SPMD module == ONE device's program,
    # so flops/bytes are already per-device (verified: multipod flops are
    # ~half of single-pod for DP-scaled batches).  The three terms below are
    # therefore all per-chip seconds, directly comparable.
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if extra_cost:
        flops += extra_cost[0]
        byts += extra_cost[1]
    wire, per_type, counts = collective_bytes(hlo_text, chips)
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = wire / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    model_flops_per_chip = model_flops / chips
    return RooflineReport(
        cell=cell, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, wire_bytes_per_chip=wire,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=(model_flops_per_chip / flops if flops else 0.0),
        roofline_s=max(terms.values()), per_type=per_type, counts=counts,
        bytes_per_device=bytes_per_device, extra=extra or {})


def model_flops_for(cfg, kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N·B decode (per step);
    MoE uses active params."""
    counts = cfg.param_count()
    n_active = counts["active"]
    if kind == "train":
        return 6.0 * n_active * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * n_active * seq_len * global_batch
    return 2.0 * n_active * global_batch
