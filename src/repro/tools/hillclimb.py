import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Perf hillclimbing harness (EXPERIMENTS.md §Perf).

Lowers VARIANTS of one (arch x shape) cell on the single-pod mesh —
config tweaks (MoE dispatch mode, SSD chunk, backend choice) or sharding
tweaks (cache seq-shard fallback) — and reports the roofline-term deltas
vs the named baseline.  Results land in experiments/perf/<cell>/<variant>.json.

    PYTHONPATH=src python -m repro.tools.hillclimb --cell stablelm-12b/decode_32k
    PYTHONPATH=src python -m repro.tools.hillclimb --list
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.stack import unroll_scans  # noqa: E402
from repro.tools.roofline import analyze, model_flops_for  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf")


def _ssd_chunk(cfg, q):
    return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=q))


def _moe_dispatch(cfg, mode):
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                            dispatch=mode))


def _remat_off(cfg):
    # no-remat variant is threaded via backends dict hack? kept explicit:
    return cfg


# variant -> (cfg_transform, build_cell kwargs)
VARIANTS = {
    "stablelm-12b/decode_32k": {
        "baseline-replicated-kv": (None, {"seq_shard_fallback": False}),
        "seq-shard-kv": (None, {"seq_shard_fallback": True}),
    },
    "pixtral-12b/decode_32k": {
        "baseline-replicated-kv": (None, {"seq_shard_fallback": False}),
        "seq-shard-kv": (None, {"seq_shard_fallback": True}),
    },
    "minitron-4b/decode_32k": {
        "baseline-replicated-kv": (None, {"seq_shard_fallback": False}),
        "seq-shard-kv": (None, {"seq_shard_fallback": True}),
    },
    "gemma3-1b/decode_32k": {
        "baseline-replicated-kv": (None, {"seq_shard_fallback": False}),
        "seq-shard-kv": (None, {"seq_shard_fallback": True}),
    },
    "deepseek-v2-lite-16b/decode_32k": {
        "baseline-replicated-latent": (None, {"seq_shard_fallback": False}),
        "seq-shard-latent": (None, {"seq_shard_fallback": True}),
    },
    "qwen2-moe-a2.7b/train_4k": {
        "baseline-global-dispatch": (lambda c: _moe_dispatch(c, "global"), {}),
        "local-dispatch": (lambda c: _moe_dispatch(c, "local"), {}),
    },
    "deepseek-v2-lite-16b/train_4k": {
        "baseline-global-dispatch": (lambda c: _moe_dispatch(c, "global"), {}),
        "local-dispatch": (lambda c: _moe_dispatch(c, "local"), {}),
    },
    "mamba2-370m/train_4k": {
        "baseline-chunk128": (lambda c: _ssd_chunk(c, 128), {}),
        "chunk-64": (lambda c: _ssd_chunk(c, 64), {}),
        "chunk-32": (lambda c: _ssd_chunk(c, 32), {}),
        "chunk-256": (lambda c: _ssd_chunk(c, 256), {}),
        "no-remat": (lambda c: dataclasses.replace(c, remat=False), {}),
    },
    "zamba2-7b/train_4k": {
        "baseline-chunk128": (lambda c: _ssd_chunk(c, 128), {}),
        "chunk-64": (lambda c: _ssd_chunk(c, 64), {}),
        "chunk-256": (lambda c: _ssd_chunk(c, 256), {}),
    },
}


def run_variant(arch: str, shape: str, label: str, cfg_fn, kwargs,
                out_dir: str) -> dict:
    cfg = get_config(arch)
    if cfg_fn is not None:
        cfg = cfg_fn(cfg)
    sc = cfg.shape(shape)
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with mesh, unroll_scans():
        cell = build_cell(arch, shape, mesh, cfg=cfg, **kwargs)
        compiled = cell.step.lower(*cell.args).compile()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        mem = compiled.memory_analysis()
    rep = analyze(cell.name, "single", mesh.size, cost, hlo,
                  model_flops=model_flops_for(cfg, sc.kind, sc.seq_len,
                                              sc.global_batch),
                  bytes_per_device=(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes))
    rec = json.loads(rep.to_json())
    rec.update(arch=arch, shape=shape, variant=label,
               compile_s=round(time.time() - t0, 1))
    d = os.path.join(out_dir, f"{arch}__{shape}")
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{label}.json"), "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
    print(f"[{label:28s}] compute={rec['compute_s']:.3e} "
          f"memory={rec['memory_s']:.3e} collective={rec['collective_s']:.3e} "
          f"bneck={rec['bottleneck']} GB/dev={rec['bytes_per_device']/1e9:.1f} "
          f"({rec['compile_s']}s)")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, help="arch/shape")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for cell, vs in VARIANTS.items():
            print(cell, "->", ", ".join(vs))
        return 0
    cells = [args.cell] if args.cell else list(VARIANTS)
    for cell in cells:
        arch, shape = cell.split("/")
        print(f"=== {cell} ===")
        for label, (cfg_fn, kwargs) in VARIANTS[cell].items():
            if args.variant and label != args.variant:
                continue
            try:
                run_variant(arch, shape, label, cfg_fn, kwargs, args.out)
            except Exception as e:  # noqa: BLE001
                print(f"[{label:28s}] FAILED {type(e).__name__}: {e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
