"""Docs symbol checker — fail CI when docs reference dead code.

Scans markdown files for backtick-quoted dotted references into the
``repro`` package (```repro.core.quant.calibrate`` and friends) and
verifies each one resolves against the live package: the longest importable
module prefix is imported, the remaining parts are attribute-chained.  A
reference to a module, class, function or attribute that no longer exists
makes the check fail with the offending file/line.

Usage::

    python -m repro.tools.doccheck                 # docs/*.md + README.md
    python -m repro.tools.doccheck docs/foo.md ... # explicit files

This is the drift guard for hand-written prose (``docs/architecture.md``,
``docs/oxf-format.md``); the generated registry tables in README.md are
covered separately by :mod:`repro.tools.docgen` ``--check``.
"""

from __future__ import annotations

import argparse
import glob
import importlib
import os
import re
import sys
from typing import List, Tuple

__all__ = ["find_refs", "resolves", "check_files", "main"]

# `repro.x.y` inside backticks, optionally with a trailing call-ish suffix
# like `repro.core.compile(...)` which we strip before resolving.
_REF_RE = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)(?:\(\S*?\))?`")


def find_refs(text: str) -> List[Tuple[int, str]]:
    """All (line_number, dotted_ref) pairs in ``text``."""
    out = []
    for i, line in enumerate(text.splitlines(), 1):
        for m in _REF_RE.finditer(line):
            out.append((i, m.group(1)))
    return out


def resolves(ref: str) -> bool:
    """True when ``ref`` names an importable module, or an attribute chain
    hanging off one (longest importable prefix wins)."""
    parts = ref.split(".")
    obj = None
    rest: List[str] = []
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            rest = parts[i:]
            break
        except ImportError:
            continue
    if obj is None:
        return False
    for p in rest:
        if not hasattr(obj, p):
            return False
        obj = getattr(obj, p)
    return True


def check_files(paths: List[str]) -> List[str]:
    """Returns a list of 'file:line: bad ref' error strings."""
    errors = []
    for path in paths:
        with open(path) as f:
            text = f.read()
        for line_no, ref in find_refs(text):
            if not resolves(ref):
                errors.append(f"{path}:{line_no}: unresolvable reference `{ref}`")
    return errors


def _default_paths() -> List[str]:
    root = os.getcwd()
    paths = sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        paths.append(readme)
    return paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", help="markdown files (default: docs/*.md README.md)")
    args = ap.parse_args(argv)
    paths = args.files or _default_paths()
    if not paths:
        print("no markdown files to check", file=sys.stderr)
        return 1
    errors = check_files(paths)
    for e in errors:
        print(e, file=sys.stderr)
    n_refs = sum(len(find_refs(open(p).read())) for p in paths)
    print(f"doccheck: {len(paths)} files, {n_refs} repro.* references, "
          f"{len(errors)} unresolvable")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
