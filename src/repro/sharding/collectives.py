"""Collective strategies built on shard_map — the distribution-level
"backends" of Orpheus-JAX (selectable like any op backend).

* ``tree_decode_attention`` — sequence-parallel decode: the KV cache is
  sharded along its length dim over the "data" axis (long_500k, batch=1);
  every shard runs flash-decode over its slice and emits unnormalised
  partials (acc, m, l); shards combine with pmax/psum — mathematically
  exact (see ``ref.combine_partials_ref``), turning a full-cache gather
  into two scalar-ish collectives + one (B, Hq, D) psum.

* ``ring_allgather_matmul`` — overlap demonstration: all-gather of the
  row-sharded activation interleaved with per-chunk matmul via
  ``collective_permute`` (the classic ring schedule that hides comm behind
  MXU work on TPU).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.ops import decode_attention_partial

__all__ = ["tree_decode_attention", "ring_allgather_matmul"]


def tree_decode_attention(mesh: Mesh, q: jax.Array, k: jax.Array,
                          v: jax.Array, lengths: jax.Array, *,
                          scale: Optional[float] = None, axis: str = "data",
                          backend: str = "ref") -> jax.Array:
    """q (B,Hq,D) replicated; k/v (B,Skv,Hkv,D) sharded on dim 1 over
    ``axis``; lengths (B,) global valid counts. Returns (B,Hq,Dv)."""
    n = mesh.shape[axis]
    skv = k.shape[1]
    assert skv % n == 0, (skv, n)
    s_loc = skv // n

    def local(q_, k_, v_, lengths_):
        idx = jax.lax.axis_index(axis)
        offset = (idx * s_loc).astype(jnp.int32)
        local_len = jnp.clip(lengths_ - offset, 0, s_loc)
        acc, m, l = decode_attention_partial(q_, k_, v_, local_len,
                                             scale=scale, backend=backend)
        m_glob = jax.lax.pmax(m, axis)                     # (B,Hq)
        alpha = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * alpha, axis)
        acc_glob = jax.lax.psum(acc.astype(jnp.float32) * alpha[..., None], axis)
        return (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q_.dtype)

    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None), P())
    return jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                         out_specs=P())(q, k, v, lengths)


def ring_allgather_matmul(mesh: Mesh, x: jax.Array, w: jax.Array, *,
                          axis: str = "model") -> jax.Array:
    """y = allgather(x, axis) @ w, with the gather pipelined against the
    matmul: at step t each device multiplies the chunk it currently holds
    while collective-permuting it to the next neighbour.

    x (M, K) sharded on dim 0 over ``axis`` -> every device needs all of x;
    w (K, N) replicated inside shard_map (caller shards as needed).
    """
    n = mesh.shape[axis]

    def local(x_loc, w_):
        m_loc = x_loc.shape[0]
        perm = [(i, (i + 1) % n) for i in range(n)]
        idx0 = jax.lax.axis_index(axis)

        def step(carry, t):
            chunk, acc = carry
            # chunk currently holds shard (idx0 - t) mod n
            part = jnp.dot(chunk, w_, preferred_element_type=jnp.float32)
            src = (idx0 - t) % n
            acc = jax.lax.dynamic_update_slice(
                acc, part[None], (src % n, 0, 0))
            chunk = jax.lax.ppermute(chunk, axis, perm)
            return (chunk, acc), None

        acc0 = jnp.zeros((n, m_loc, w_.shape[1]), jnp.float32)
        # the carry becomes device-varying after the first axis_index use;
        # mark the initial value varying so scan's carry types match
        acc0 = jax.lax.pcast(acc0, ("model",), to="varying")
        (chunk, acc), _ = jax.lax.scan(step, (x_loc, acc0), jnp.arange(n))
        return acc.reshape(n * m_loc, w_.shape[1]).astype(x_loc.dtype)

    # every device finishes holding the full (M, N) product, but the vma
    # type system sees an axis_index-dependent value and can't infer the
    # replication — disable the static check (numerics verified in tests).
    return jax.shard_map(local, mesh=mesh, in_specs=(P(axis, None), P()),
                         out_specs=P(), check_vma=False)(x, w)
