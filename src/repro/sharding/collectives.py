"""Collective strategies built on shard_map — the distribution-level
"backends" of Orpheus-JAX (selectable like any op backend).

* ``tree_decode_attention`` — sequence-parallel decode: the KV cache is
  sharded along its length dim over the "data" axis (long_500k, batch=1);
  every shard runs flash-decode over its slice and emits unnormalised
  partials (acc, m, l); shards combine with pmax/psum — mathematically
  exact (see ``ref.combine_partials_ref``), turning a full-cache gather
  into two scalar-ish collectives + one (B, Hq, D) psum.

* ``ring_allgather_matmul`` — overlap demonstration: all-gather of the
  row-sharded activation interleaved with per-chunk matmul via
  ``collective_permute`` (the classic ring schedule that hides comm behind
  MXU work on TPU).

* ``shard_map_compat`` / ``replicate`` / ``allgather_bytes`` — the pieces
  the serving engine's tensor-parallel attention backends are built on
  (``kernels/serving_ops.py``'s ``tp`` impls): a version-portable
  shard_map, a with_sharding_constraint that forces an (exact) all-gather
  of the head-sharded attention output, and the cost-model accounting for
  that gather's traffic.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.ops import decode_attention_partial

__all__ = ["tree_decode_attention", "ring_allgather_matmul",
           "shard_map_compat", "replicate", "allgather_bytes"]


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    Modern jax exposes ``jax.shard_map`` (vma-checked); older releases
    only have ``jax.experimental.shard_map.shard_map`` (rep-checked).  The
    serving bodies are per-head-local closures over host scalars, which
    neither checker can see through, so the static replication check is
    disabled in both forms (the engine's token-identity tests verify the
    numerics end to end)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:  # pre-vma signature spells it check_rep
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def replicate(x: jax.Array, mesh: Mesh) -> jax.Array:
    """Constrain ``x`` to be fully replicated on ``mesh`` — an explicit
    all-gather point.  Pure data movement, so bitwise exact; this is how
    the ``tp`` attention backends hand their head-sharded output back to
    the replicated half of the Program (o_proj onward)."""
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P()))


def allgather_bytes(nbytes: float, degree: int) -> float:
    """Traffic one device moves all-gathering an ``nbytes`` global array
    sharded ``degree`` ways: each device receives the (degree-1) shards it
    doesn't hold."""
    return float(nbytes) * (degree - 1) / max(degree, 1)


def tree_decode_attention(mesh: Mesh, q: jax.Array, k: jax.Array,
                          v: jax.Array, lengths: jax.Array, *,
                          scale: Optional[float] = None, axis: str = "data",
                          backend: str = "ref") -> jax.Array:
    """q (B,Hq,D) replicated; k/v (B,Skv,Hkv,D) sharded on dim 1 over
    ``axis``; lengths (B,) global valid counts. Returns (B,Hq,Dv)."""
    n = mesh.shape[axis]
    skv = k.shape[1]
    assert skv % n == 0, (skv, n)
    s_loc = skv // n

    def local(q_, k_, v_, lengths_):
        idx = jax.lax.axis_index(axis)
        offset = (idx * s_loc).astype(jnp.int32)
        local_len = jnp.clip(lengths_ - offset, 0, s_loc)
        acc, m, l = decode_attention_partial(q_, k_, v_, local_len,
                                             scale=scale, backend=backend)
        m_glob = jax.lax.pmax(m, axis)                     # (B,Hq)
        alpha = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * alpha, axis)
        acc_glob = jax.lax.psum(acc.astype(jnp.float32) * alpha[..., None], axis)
        return (acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]).astype(q_.dtype)

    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None), P())
    return jax.shard_map(local, mesh=mesh, in_specs=in_specs,
                         out_specs=P())(q, k, v, lengths)


def ring_allgather_matmul(mesh: Mesh, x: jax.Array, w: jax.Array, *,
                          axis: str = "model") -> jax.Array:
    """y = allgather(x, axis) @ w, with the gather pipelined against the
    matmul: at step t each device multiplies the chunk it currently holds
    while collective-permuting it to the next neighbour.

    x (M, K) sharded on dim 0 over ``axis`` -> every device needs all of x;
    w (K, N) replicated inside shard_map (caller shards as needed).
    """
    n = mesh.shape[axis]

    def local(x_loc, w_):
        m_loc = x_loc.shape[0]
        perm = [(i, (i + 1) % n) for i in range(n)]
        idx0 = jax.lax.axis_index(axis)

        def step(carry, t):
            chunk, acc = carry
            # chunk currently holds shard (idx0 - t) mod n
            part = jnp.dot(chunk, w_, preferred_element_type=jnp.float32)
            src = (idx0 - t) % n
            acc = jax.lax.dynamic_update_slice(
                acc, part[None], (src % n, 0, 0))
            chunk = jax.lax.ppermute(chunk, axis, perm)
            return (chunk, acc), None

        acc0 = jnp.zeros((n, m_loc, w_.shape[1]), jnp.float32)
        # the carry becomes device-varying after the first axis_index use;
        # mark the initial value varying so scan's carry types match
        acc0 = jax.lax.pcast(acc0, ("model",), to="varying")
        (chunk, acc), _ = jax.lax.scan(step, (x_loc, acc0), jnp.arange(n))
        return acc.reshape(n * m_loc, w_.shape[1]).astype(x_loc.dtype)

    # every device finishes holding the full (M, N) product, but the vma
    # type system sees an axis_index-dependent value and can't infer the
    # replication — disable the static check (numerics verified in tests).
    return jax.shard_map(local, mesh=mesh, in_specs=(P(axis, None), P()),
                         out_specs=P(), check_vma=False)(x, w)
