"""Per-architecture PartitionSpec rules (DP / TP / EP / SP).

``param_specs`` walks a params pytree by key-path and assigns a
PartitionSpec per leaf from name-based rules, guarded by divisibility
checks against the mesh (a dim that doesn't divide falls back to
replication — this is how gemma3's 4-head attention ends up replicated on
a 16-way model axis while its FFN and vocab still carry TP).

Megatron pattern for transformer blocks:
  wq/wk/wv, w_gate/w_up  column-parallel  P(None, "model")
  wo, w_down             row-parallel     P("model", None)
  embed                  P("model", None)  (vocab-sharded)
  lm_head                P(None, "model")
  MoE experts            P("model", None, None)  (expert-parallel)
  Mamba streams          wz/wx column over d_inner; wdt over H;
                         out_proj row; B/C streams replicated (G*N small)
  norms / biases / A_log / D  replicated

Batch/activation rules: batch dim over ("pod","data"); for batch==1
long-context decode the KV-cache sequence dim is sharded over "data"
instead (sequence parallelism — the tree-decode path).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "data_axes",
           "named_shardings", "opt_state_specs",
           "serving_value_role", "graph_partition_specs", "mesh_axes",
           "check_mesh_compat"]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ("pod","data") on multi-pod, ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def ambient_mesh() -> Optional[Mesh]:
    """The context-manager mesh active at trace time (None outside one).
    Lets layer code apply sharding constraints only when actually lowering
    for a mesh — CPU tests and 1-device paths stay constraint-free."""
    try:
        from jax._src import mesh as mesh_lib  # noqa: PLC0415
        pm = mesh_lib.thread_resources.env.physical_mesh
        return None if pm.empty else pm
    except Exception:  # pragma: no cover - private API drift
        return None


def constrain(x, spec: P):
    """with_sharding_constraint iff an ambient mesh exists (else no-op)."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0 and n > 0


def _key_str(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _param_rule(names: Tuple[str, ...], shape: Tuple[int, ...],
                cfg: ArchConfig, mesh: Mesh) -> P:
    name = names[-1] if names else ""
    leaf = name
    nd = len(shape)

    def col() -> P:  # column-parallel (shard last dim)
        if _div(shape[-1], mesh, "model"):
            return P(*([None] * (nd - 1) + ["model"]))
        return P()

    def row() -> P:  # row-parallel (shard first dim)
        if _div(shape[0], mesh, "model"):
            return P(*(["model"] + [None] * (nd - 1)))
        return P()

    # --- embeddings ---
    if leaf == "embed":
        return row()          # vocab-sharded
    if leaf == "lm_head":
        return col()

    # --- attention (megatron) ---
    if leaf in ("wq", "w_gate", "w_up", "w_in", "wz", "wx", "wuk", "wuv"):
        return col()
    if leaf in ("wk", "wv"):
        # shard kv heads only if they divide; else replicate (GQA small-kv)
        if _div(cfg.n_kv_heads, mesh, "model"):
            return col()
        return P()
    if leaf in ("wo", "w_down", "w_out", "out_proj"):
        return row()
    if leaf == "wdt":
        return col()
    if leaf in ("wdkv", "wkpe", "wB", "wC", "fuse"):
        return col() if leaf == "fuse" else P()

    # --- MoE experts: expert-parallel on the expert dim ---
    if nd == 3 and leaf in ("w_gate", "w_up", "w_down"):  # (E, d, f)
        pass  # unreachable (handled above by name), kept for clarity
    if leaf == "router":
        return P()

    # --- mamba conv / scalars / norms ---
    if leaf.startswith("conv_x") or leaf == "conv_bx":
        return col() if _div(shape[-1], mesh, "model") else P()
    if leaf.startswith("conv_") or leaf.startswith("norm") or leaf in (
            "A_log", "D", "dt_bias", "final_norm", "enc_norm", "b", "bias"):
        return P()
    return P()


def _moe_aware_rule(names: Tuple[str, ...], shape: Tuple[int, ...],
                    cfg: ArchConfig, mesh: Mesh) -> P:
    """Expert tensors are 3-D (E, ·, ·): shard the expert dim (EP)."""
    leaf = names[-1] if names else ""
    if len(shape) == 3 and leaf in ("w_gate", "w_up", "w_down"):
        if _div(shape[0], mesh, "model"):
            return P("model", None, None)
        return P()
    return _param_rule(names, shape, cfg, mesh)


def param_specs(params_shape: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (from jax.eval_shape).
    Stacked period params have a leading n_periods axis -> spec gets an
    extra None."""
    def assign(path, leaf):
        names = _key_str(path)
        shape = tuple(leaf.shape)
        stacked = "period" in names or names[-1] in ("0", "1")
        # stacked period params: (n_periods, ...) and shared: (2, ...)
        lead = 0
        if "period" in names:
            lead = 1
        elif "shared" in names and "stack" in names:
            lead = 1
        core = shape[lead:]
        spec = _moe_aware_rule(names, core, cfg, mesh)
        return P(*([None] * lead + list(spec)))

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard the leading batch dim over ("pod","data") when divisible."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        if shape and shape[0] % dp_size == 0 and dp_size > 1:
            return P(dp, *([None] * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_specs(cache_shape: Any, cfg: ArchConfig, mesh: Mesh, batch: int,
                seq_shard_fallback: bool = True) -> Any:
    """Decode-cache sharding.  Batch dim over DP axes when divisible; for
    batch==1 (long-context) the sequence/capacity dim is sharded over
    "data" instead (sequence parallelism).  KV head dims shard on "model"
    when divisible.

    ``seq_shard_fallback`` (perf iteration 1, EXPERIMENTS.md §Perf): when a
    cache's kv-head dim does NOT divide the model axis (stablelm/pixtral
    kv=8 vs model=16, gemma3 kv=1, MLA's single latent "head"), the
    baseline replicated the cache across "model" — 16x the HBM footprint
    and an all-gather per decode step.  The fallback shards the cache
    LENGTH dim over "model" instead (sequence-parallel attention inside the
    TP group; XLA partitions the masked softmax with small psums)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def assign(path, leaf):
        names = _key_str(path)
        shape = tuple(leaf.shape)
        lead = 1 if "period" in names else 0   # stacked (n_periods, ...)
        core = list(shape[lead:])
        spec: list = [None] * len(core)
        leaf_name = names[-1]
        paged_kv = (leaf_name in ("pages_k", "pages_v")
                    or (leaf_name in ("k", "v") and len(core) == 4
                        and core[0] != batch))
        if paged_kv and len(core) == 4:
            # paged pool (N_pages, page, Hk, D): rows are block-addressed
            # through tables, so neither the pool dim nor the page dim can
            # shard usefully — the kv-head dim carries TP, with full
            # replication as the GQA-small fallback (never a crash).
            if _div(core[2], mesh, "model"):
                spec[2] = "model"
            return P(*([None] * lead + spec))
        if leaf_name.endswith("_scale") and len(core) == 2:
            # (N_pages, Hk) dequant sidecar: mirrors its pool's head shard
            if _div(core[1], mesh, "model"):
                spec[1] = "model"
            return P(*([None] * lead + spec))
        # core[0] = batch
        if core and core[0] == batch and batch % dp_size == 0 and dp_size > 1:
            spec[0] = dp
        elif core and batch == 1 and len(core) >= 2:
            # sequence-parallel: shard the cache length dim over "data"
            if leaf_name in ("k", "v", "ckv", "kpe") and _div(core[1], mesh, "data"):
                spec[1] = "data"
        if leaf_name in ("k", "v") and len(core) == 4:
            if _div(core[2], mesh, "model"):
                spec[2] = "model"
            elif seq_shard_fallback and _div(core[1], mesh, "model") \
                    and spec[1] is None:
                spec[1] = "model"
        if leaf_name in ("ckv", "kpe") and len(core) == 3 \
                and seq_shard_fallback and spec[1] is None \
                and _div(core[1], mesh, "model"):
            spec[1] = "model"      # MLA latent cache: shard length over TP
        if leaf_name == "ssm" and len(core) == 4:
            if _div(core[1], mesh, "model"):
                spec[1] = "model"
        if leaf_name == "conv_x" and len(core) == 3:
            if _div(core[2], mesh, "model"):
                spec[2] = "model"
        return P(*([None] * lead + spec))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def opt_state_specs(params_shape: Any, param_spec: Any, mesh: Mesh,
                    zero1: bool = True) -> Any:
    """Adam moment sharding.  With ZeRO-1 each moment additionally shards
    its largest not-yet-sharded dim over the "data" axis (when divisible):
    grads arrive DP-replicated, each DP shard updates its slice, and XLA
    all-gathers the fresh params — the ZeRO-1 pattern expressed purely as
    sharding annotations."""
    if not zero1 or "data" not in mesh.axis_names:
        return param_spec
    dsize = mesh.shape["data"]

    def widen(leaf, spec):
        shape = tuple(leaf.shape)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        # pick the largest replicated dim divisible by the data axis
        best, best_dim = -1, -1
        for i, (n, s) in enumerate(zip(shape, entries)):
            if s is None and n % dsize == 0 and n > best:
                best, best_dim = n, i
        if best_dim >= 0 and best >= dsize:
            entries[best_dim] = "data"
        return P(*entries)

    return jax.tree_util.tree_map(widen, params_shape, param_spec,
                                  is_leaf=lambda x: isinstance(x, P))


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------- #
# Serving-graph partitioning — rules behind compile(mesh=...)'s
# `partition` pass.  Every Program input / param / output gets a
# PartitionSpec derived from its *name* and *shape*; divisibility guards
# fall back to replication (the GQA-small fallback), never crash.
# --------------------------------------------------------------------- #

# scalar/bookkeeping serving inputs that must stay replicated: token ids,
# write cursors, and block tables (host-computed int32 indices)
SERVING_REPLICATED = ("tokens", "start", "n_new", "kvlen", "block_tables")


def serving_value_role(name: str, shape: Tuple[int, ...], *,
                       paged: bool = False) -> str:
    """Classify one serving-graph value into a partition role.

    Roles: ``replicated`` (tokens, cursors, tables, norms, logits, and —
    deliberately — the row-parallel candidates wo/wd/embed/head_w, see
    below), ``col`` (column-parallel projection weight), ``kv_col``
    (column-parallel iff whole kv heads divide the model axis),
    ``dense_cache`` ((B, S, Hk, D) cache), ``paged_pool``
    ((N_pages, page, Hk, D) pool), ``kv_scale`` ((N_pages, Hk) sidecar).

    wo/wd (and embed/head_w) are kept replicated rather than row-parallel:
    a row-parallel matmul splits the contraction dim and combines partial
    products with a psum, whose float-addition order differs from the
    single-device reduction — that breaks the engine's token-identity
    guarantee.  The TP win on those layers is given up in exchange for
    bitwise-exact serving; the attention shard_map backends charge the
    resulting all-gather in their cost models instead.
    """
    base = name[4:] if name.startswith("new_") else name
    leaf = base.rsplit(".", 1)[-1]
    if base in SERVING_REPLICATED or base.startswith("tokens."):
        return "replicated"
    if base.startswith("cache_k") or base.startswith("cache_v"):
        if base.endswith("_scale"):
            return "kv_scale" if len(shape) == 2 else "replicated"
        if len(shape) == 4:
            return "paged_pool" if paged else "dense_cache"
        return "replicated"
    if leaf in ("wq", "wg", "wu"):
        return "col"
    if leaf in ("wk", "wv"):
        return "kv_col"
    return "replicated"


def graph_partition_specs(graph: Any, mesh: Mesh) -> Dict[str, P]:
    """PartitionSpec for every input, param and output of a serving graph.

    Name/shape-driven (the convention of :mod:`repro.models.graph_lm`'s
    builders): caches and paged pools shard the kv-head dim on "model"
    when it divides, scale sidecars mirror their pool, q/gate/up
    projections go column-parallel, wk/wv go column-parallel only when
    whole kv heads land on each device (GQA-small fallback: replicate),
    everything else — tokens, cursors, block tables, norms, wo/wd, embed,
    head_w, logits — is replicated.  Outputs mirror the input they update
    (``new_<name>`` strips to ``<name>``); unknown names replicate.
    """
    paged = "block_tables" in graph.inputs
    # kv-head count from any 4-D cache input (dim 2, dense and paged alike)
    kv_heads = 0
    for n, ts in graph.inputs.items():
        if (n.startswith("cache_k") or n.startswith("cache_v")) \
                and not n.endswith("_scale") and len(ts.shape) == 4:
            kv_heads = int(ts.shape[2])
            break

    def spec_for(name: str, shape: Tuple[int, ...]) -> P:
        role = serving_value_role(name, shape, paged=paged)
        nd = len(shape)
        if role == "col" and nd >= 1 and _div(shape[-1], mesh, "model"):
            return P(*([None] * (nd - 1) + ["model"]))
        if role == "kv_col":
            # packed (d_model, Hk*dh): shard only on whole kv heads
            if kv_heads and _div(kv_heads, mesh, "model") \
                    and nd >= 1 and _div(shape[-1], mesh, "model"):
                return P(*([None] * (nd - 1) + ["model"]))
            return P()
        if role in ("dense_cache", "paged_pool") and nd == 4 \
                and _div(shape[2], mesh, "model"):
            return P(None, None, "model", None)
        if role == "kv_scale" and nd == 2 and _div(shape[1], mesh, "model"):
            return P(None, "model")
        return P()

    specs: Dict[str, P] = {}
    for name, ts in graph.inputs.items():
        specs[name] = spec_for(name, tuple(ts.shape))
    for name, arr in graph.params.items():
        specs[name] = spec_for(name, tuple(np.shape(arr)))
    for name in graph.outputs:
        base = name[4:] if name.startswith("new_") else None
        if base is not None and base in specs:
            specs[name] = specs[base]    # cache outputs mirror their input
        else:
            try:
                shape = tuple(graph.spec_of(name).shape)
            except Exception:
                specs[name] = P()        # shape unknown -> replicate
                continue
            specs[name] = spec_for(name, shape)
    return specs


def mesh_axes(mesh: Mesh) -> Dict[str, int]:
    """``{axis_name: size}`` — the serialisable identity of a mesh."""
    return {a: int(mesh.shape[a]) for a in mesh.axis_names}


def check_mesh_compat(recorded: Dict[str, int], mesh: Mesh) -> None:
    """Raise ValueError unless ``mesh`` matches a recorded axis layout.

    Compatible means: same axis names with the same sizes (order-free).
    Specs name mesh axes, so a renamed or resized axis would silently
    re-plan the layout — exactly what a partitioned bundle promises not
    to do."""
    actual = mesh_axes(mesh)
    if actual != dict(recorded):
        raise ValueError(
            f"partitioned Program was saved for mesh axes {dict(recorded)} "
            f"but is being loaded onto {actual}; reload on a mesh with the "
            f"same axis names and sizes, or load with mesh=None and "
            f"re-partition via compile(mesh=...)")
