"""Per-architecture PartitionSpec rules (DP / TP / EP / SP).

``param_specs`` walks a params pytree by key-path and assigns a
PartitionSpec per leaf from name-based rules, guarded by divisibility
checks against the mesh (a dim that doesn't divide falls back to
replication — this is how gemma3's 4-head attention ends up replicated on
a 16-way model axis while its FFN and vocab still carry TP).

Megatron pattern for transformer blocks:
  wq/wk/wv, w_gate/w_up  column-parallel  P(None, "model")
  wo, w_down             row-parallel     P("model", None)
  embed                  P("model", None)  (vocab-sharded)
  lm_head                P(None, "model")
  MoE experts            P("model", None, None)  (expert-parallel)
  Mamba streams          wz/wx column over d_inner; wdt over H;
                         out_proj row; B/C streams replicated (G*N small)
  norms / biases / A_log / D  replicated

Batch/activation rules: batch dim over ("pod","data"); for batch==1
long-context decode the KV-cache sequence dim is sharded over "data"
instead (sequence parallelism — the tree-decode path).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "data_axes",
           "named_shardings", "opt_state_specs"]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ("pod","data") on multi-pod, ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def ambient_mesh() -> Optional[Mesh]:
    """The context-manager mesh active at trace time (None outside one).
    Lets layer code apply sharding constraints only when actually lowering
    for a mesh — CPU tests and 1-device paths stay constraint-free."""
    try:
        from jax._src import mesh as mesh_lib  # noqa: PLC0415
        pm = mesh_lib.thread_resources.env.physical_mesh
        return None if pm.empty else pm
    except Exception:  # pragma: no cover - private API drift
        return None


def constrain(x, spec: P):
    """with_sharding_constraint iff an ambient mesh exists (else no-op)."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0 and n > 0


def _key_str(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _param_rule(names: Tuple[str, ...], shape: Tuple[int, ...],
                cfg: ArchConfig, mesh: Mesh) -> P:
    name = names[-1] if names else ""
    leaf = name
    nd = len(shape)

    def col() -> P:  # column-parallel (shard last dim)
        if _div(shape[-1], mesh, "model"):
            return P(*([None] * (nd - 1) + ["model"]))
        return P()

    def row() -> P:  # row-parallel (shard first dim)
        if _div(shape[0], mesh, "model"):
            return P(*(["model"] + [None] * (nd - 1)))
        return P()

    # --- embeddings ---
    if leaf == "embed":
        return row()          # vocab-sharded
    if leaf == "lm_head":
        return col()

    # --- attention (megatron) ---
    if leaf in ("wq", "w_gate", "w_up", "w_in", "wz", "wx", "wuk", "wuv"):
        return col()
    if leaf in ("wk", "wv"):
        # shard kv heads only if they divide; else replicate (GQA small-kv)
        if _div(cfg.n_kv_heads, mesh, "model"):
            return col()
        return P()
    if leaf in ("wo", "w_down", "w_out", "out_proj"):
        return row()
    if leaf == "wdt":
        return col()
    if leaf in ("wdkv", "wkpe", "wB", "wC", "fuse"):
        return col() if leaf == "fuse" else P()

    # --- MoE experts: expert-parallel on the expert dim ---
    if nd == 3 and leaf in ("w_gate", "w_up", "w_down"):  # (E, d, f)
        pass  # unreachable (handled above by name), kept for clarity
    if leaf == "router":
        return P()

    # --- mamba conv / scalars / norms ---
    if leaf.startswith("conv_x") or leaf == "conv_bx":
        return col() if _div(shape[-1], mesh, "model") else P()
    if leaf.startswith("conv_") or leaf.startswith("norm") or leaf in (
            "A_log", "D", "dt_bias", "final_norm", "enc_norm", "b", "bias"):
        return P()
    return P()


def _moe_aware_rule(names: Tuple[str, ...], shape: Tuple[int, ...],
                    cfg: ArchConfig, mesh: Mesh) -> P:
    """Expert tensors are 3-D (E, ·, ·): shard the expert dim (EP)."""
    leaf = names[-1] if names else ""
    if len(shape) == 3 and leaf in ("w_gate", "w_up", "w_down"):
        if _div(shape[0], mesh, "model"):
            return P("model", None, None)
        return P()
    return _param_rule(names, shape, cfg, mesh)


def param_specs(params_shape: Any, cfg: ArchConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (from jax.eval_shape).
    Stacked period params have a leading n_periods axis -> spec gets an
    extra None."""
    def assign(path, leaf):
        names = _key_str(path)
        shape = tuple(leaf.shape)
        stacked = "period" in names or names[-1] in ("0", "1")
        # stacked period params: (n_periods, ...) and shared: (2, ...)
        lead = 0
        if "period" in names:
            lead = 1
        elif "shared" in names and "stack" in names:
            lead = 1
        core = shape[lead:]
        spec = _moe_aware_rule(names, core, cfg, mesh)
        return P(*([None] * lead + list(spec)))

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Shard the leading batch dim over ("pod","data") when divisible."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def assign(path, leaf):
        shape = tuple(leaf.shape)
        if shape and shape[0] % dp_size == 0 and dp_size > 1:
            return P(dp, *([None] * (len(shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_specs(cache_shape: Any, cfg: ArchConfig, mesh: Mesh, batch: int,
                seq_shard_fallback: bool = True) -> Any:
    """Decode-cache sharding.  Batch dim over DP axes when divisible; for
    batch==1 (long-context) the sequence/capacity dim is sharded over
    "data" instead (sequence parallelism).  KV head dims shard on "model"
    when divisible.

    ``seq_shard_fallback`` (perf iteration 1, EXPERIMENTS.md §Perf): when a
    cache's kv-head dim does NOT divide the model axis (stablelm/pixtral
    kv=8 vs model=16, gemma3 kv=1, MLA's single latent "head"), the
    baseline replicated the cache across "model" — 16x the HBM footprint
    and an all-gather per decode step.  The fallback shards the cache
    LENGTH dim over "model" instead (sequence-parallel attention inside the
    TP group; XLA partitions the masked softmax with small psums)."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def assign(path, leaf):
        names = _key_str(path)
        shape = tuple(leaf.shape)
        lead = 1 if "period" in names else 0   # stacked (n_periods, ...)
        core = list(shape[lead:])
        spec: list = [None] * len(core)
        leaf_name = names[-1]
        # core[0] = batch
        if core and core[0] == batch and batch % dp_size == 0 and dp_size > 1:
            spec[0] = dp
        elif core and batch == 1 and len(core) >= 2:
            # sequence-parallel: shard the cache length dim over "data"
            if leaf_name in ("k", "v", "ckv", "kpe") and _div(core[1], mesh, "data"):
                spec[1] = "data"
        if leaf_name in ("k", "v") and len(core) == 4:
            if _div(core[2], mesh, "model"):
                spec[2] = "model"
            elif seq_shard_fallback and _div(core[1], mesh, "model") \
                    and spec[1] is None:
                spec[1] = "model"
        if leaf_name in ("ckv", "kpe") and len(core) == 3 \
                and seq_shard_fallback and spec[1] is None \
                and _div(core[1], mesh, "model"):
            spec[1] = "model"      # MLA latent cache: shard length over TP
        if leaf_name == "ssm" and len(core) == 4:
            if _div(core[1], mesh, "model"):
                spec[1] = "model"
        if leaf_name == "conv_x" and len(core) == 3:
            if _div(core[2], mesh, "model"):
                spec[2] = "model"
        return P(*([None] * lead + spec))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def opt_state_specs(params_shape: Any, param_spec: Any, mesh: Mesh,
                    zero1: bool = True) -> Any:
    """Adam moment sharding.  With ZeRO-1 each moment additionally shards
    its largest not-yet-sharded dim over the "data" axis (when divisible):
    grads arrive DP-replicated, each DP shard updates its slice, and XLA
    all-gathers the fresh params — the ZeRO-1 pattern expressed purely as
    sharding annotations."""
    if not zero1 or "data" not in mesh.axis_names:
        return param_spec
    dsize = mesh.shape["data"]

    def widen(leaf, spec):
        shape = tuple(leaf.shape)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        # pick the largest replicated dim divisible by the data axis
        best, best_dim = -1, -1
        for i, (n, s) in enumerate(zip(shape, entries)):
            if s is None and n % dsize == 0 and n > best:
                best, best_dim = n, i
        if best_dim >= 0 and best >= dsize:
            entries[best_dim] = "data"
        return P(*entries)

    return jax.tree_util.tree_map(widen, params_shape, param_spec,
                                  is_leaf=lambda x: isinstance(x, P))


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
