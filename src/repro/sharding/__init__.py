"""Distribution: mesh axes, PartitionSpec rules, collective strategies."""

from repro.sharding import specs  # noqa: F401
from repro.sharding.specs import (batch_specs, cache_specs, data_axes,
                                  named_shardings, opt_state_specs,
                                  param_specs)

__all__ = ["specs", "batch_specs", "cache_specs", "data_axes",
           "named_shardings", "opt_state_specs", "param_specs"]
