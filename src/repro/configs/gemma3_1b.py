"""gemma3-1b [dense] — 5 sliding-window : 1 global attention pattern,
MQA (kv=1), head_dim 256, window 512, tied embeddings, 262k vocab.

26 layers = 4 periods of (5 local + 1 global) + 2 trailing local.
long_500k RUNS: local layers cache only `window` entries (rolling buffer);
the 5 global layers' KV is sequence-sharded. [hf:google/gemma-3-1b-pt]
"""

from repro.configs.base import ArchConfig, Block, LayerPlan

L = Block("attn_local", "swiglu")
G = Block("attn", "swiglu")

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    plan=LayerPlan(period=(L, L, L, L, L, G), n_periods=4, suffix=(L, L)),
    window=512,
    tie_embeddings=True,
    rope_theta=1e6,          # global-layer theta; local layers share it (simpl.)
    skip_shapes=(),
    notes="TP note: 4 q heads / 1 kv head -> attention replicated on model axis; TP carried by FFN (6912=16x432) and vocab.",
)
