"""phi3-mini-3.8b [dense] — RoPE + SwiGLU + GQA(kv=32 i.e. MHA). [arXiv:2404.14219]"""

from repro.configs.base import ArchConfig, Block, LayerPlan

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    plan=LayerPlan(period=(Block("attn", "swiglu"),), n_periods=32),
    skip_shapes=("long_500k",),
)
