"""pixtral-12b [vlm] — Mistral-Nemo-style text backbone; the Pixtral ViT
frontend is a stub (input_specs supplies precomputed patch+token embeddings,
per the assignment). 32H x 128 head_dim (q dim 4096 != d_model 5120).
[hf:mistralai/Pixtral-12B-2409]
"""

from repro.configs.base import ArchConfig, Block, LayerPlan

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    plan=LayerPlan(period=(Block("attn", "swiglu"),), n_periods=40),
    frontend="embeds",
    skip_shapes=("long_500k",),
)
