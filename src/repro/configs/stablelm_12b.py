"""stablelm-12b [dense] — 40L, GQA kv=8. [hf:stabilityai/stablelm-2-12b]"""

from repro.configs.base import ArchConfig, Block, LayerPlan

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    plan=LayerPlan(period=(Block("attn", "swiglu"),), n_periods=40),
    skip_shapes=("long_500k",),
)
