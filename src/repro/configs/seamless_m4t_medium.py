"""seamless-m4t-medium [audio] — encoder-decoder backbone.

12 encoder + 12 decoder layers (the "12L" assignment read as symmetric
enc-dec, matching SeamlessM4T-medium's text model).  Audio frontend is a
stub: input_specs supplies precomputed frame embeddings (B, S_src, d).

Shape conventions (see DESIGN.md §4): train_4k splits seq_len into
src = tgt = 2048; prefill_32k encodes 32k frames + 1k decoder prefill;
decode_32k decodes against 32k cross-attention KV; long_500k skipped
(full attention). [arXiv:2308.11596]
"""

from repro.configs.base import ArchConfig, Block, LayerPlan

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    plan=LayerPlan(period=(Block("attn", "mlp", cross=True),), n_periods=12),
    n_encoder_layers=12,
    act="relu",
    frontend="embeds",
    skip_shapes=("long_500k",),
    notes="enc-dec; audio frontend stubbed to precomputed embeddings.",
)
