"""deepseek-v2-lite-16b [moe] — MLA attention + 64 routed experts top-6
+ 2 shared experts.

The assignment header says "MoE 64e top-6"; its trailing note says "160
routed" — we follow the header (which matches the real DeepSeek-V2-Lite:
64 routed + 2 shared, top-6).  MLA: kv_lora_rank 512, per-head qk =
128 nope + 64 rope, v 128; decode uses the absorbed-matmul latent cache
(576 floats/token vs 8192 for full K+V — the MLA memory win).
Deviation noted in DESIGN.md: real model's layer-0 dense FFN is replaced
by MoE like all other layers. [arXiv:2405.04434]
"""

from repro.configs.base import ArchConfig, Block, LayerPlan, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,           # nominal; MLA replaces K/V heads with the latent
    head_dim=128,
    d_ff=1408,               # per-expert width (assignment value)
    vocab=102400,
    plan=LayerPlan(period=(Block("mla", "moe"),), n_periods=27),
    moe=MoECfg(n_routed=64, top_k=6, d_expert=1408, n_shared=2, d_shared=2816,
               dispatch="local"),  # EXPERIMENTS.md §Perf-2 (baseline: global)
    mla=MLACfg(kv_lora_rank=512, rope_dim=64, nope_dim=128, v_dim=128),
    skip_shapes=("long_500k",),
    notes="MLA latent cache + absorbed decode; all 27 layers MoE.",
)
