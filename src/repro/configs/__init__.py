"""Config registry: the 10 assigned architectures + reduced smoke variants.

``get_config(name)`` returns the exact assigned config;
``get_reduced(name)`` returns a structurally identical but tiny variant
(same LayerPlan block kinds, fewer periods, small dims) for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (ArchConfig, Block, LayerPlan, MLACfg, MoECfg,
                                ShapeCfg, SSMCfg)

from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.gemma3_1b import CONFIG as _gemma3
from repro.configs.pixtral_12b import CONFIG as _pixtral
from repro.configs.mamba2_370m import CONFIG as _mamba2

_CONFIGS: Dict[str, ArchConfig] = {c.name: c for c in [
    _zamba2, _seamless, _qwen2moe, _deepseek, _phi3, _stablelm, _minitron,
    _gemma3, _pixtral, _mamba2,
]}


def list_configs() -> List[str]:
    return sorted(_CONFIGS)


def get_config(name: str) -> ArchConfig:
    try:
        return _CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {list_configs()}") from None


SMOKE_SHAPES = (
    ShapeCfg("smoke_train", "train", 32, 2),
    ShapeCfg("smoke_prefill", "prefill", 32, 2),
    ShapeCfg("smoke_decode", "decode", 32, 2),
)


def get_reduced(name: str) -> ArchConfig:
    """Tiny structurally-faithful variant: same block kinds & plan shape,
    n_periods <= 2, small dims, f32 (CPU numerics)."""
    cfg = get_config(name)
    kv = max(1, (4 * cfg.n_kv_heads) // max(cfg.n_heads, 1)) if cfg.n_heads > 1 else 1
    plan = LayerPlan(period=cfg.plan.period,
                     n_periods=min(2, cfg.plan.n_periods),
                     prefix=cfg.plan.prefix,
                     suffix=cfg.plan.suffix[:2])
    red = dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        d_model=64,
        n_heads=4 if cfg.n_heads > 1 else 1,
        n_kv_heads=kv,
        head_dim=16 if cfg.n_heads > 1 else 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        plan=plan,
        window=16 if cfg.window else None,
        n_encoder_layers=min(2, cfg.n_encoder_layers),
        # capacity_factor 8: no token drops at smoke scale, so decode-vs-
        # teacher-forcing consistency tests are exact (drops are the one
        # legitimate source of prefill/decode divergence in capacity MoE)
        moe=(MoECfg(n_routed=6, n_routed_padded=8, top_k=2, d_expert=32,
                    n_shared=(1 if cfg.moe.n_shared else 0), d_shared=64,
                    capacity_factor=8.0)
             if cfg.moe else None),
        ssm=(SSMCfg(d_inner=128, head_dim=16, state=16, n_groups=1,
                    conv_kernel=4, chunk=16) if cfg.ssm else None),
        mla=(MLACfg(kv_lora_rank=32, rope_dim=8, nope_dim=16, v_dim=16)
             if cfg.mla else None),
        dtype="float32",
        param_dtype="float32",
        shapes=SMOKE_SHAPES,
        skip_shapes=(),
    )
    return red
