"""zamba2-7b [hybrid] — Mamba2 blocks + shared-weight attention blocks.

81 blocks: 11 periods of (6 Mamba2 + 1 shared-attn application) + 4 trailing
Mamba2 = 70 Mamba2 + 11 shared-attn applications; the shared applications
alternate between TWO weight-shared attention blocks (Zamba2 pattern), each
taking concat(hidden, initial_embedding) through a fused projection.
[arXiv:2411.15242]
"""

from repro.configs.base import ArchConfig, Block, LayerPlan, SSMCfg

M = Block("mamba", "none")
S = Block("shared_attn", "none")

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,            # 3584 / 32
    d_ff=14336,              # shared block's MLP width
    vocab=32000,
    plan=LayerPlan(period=(M, M, M, M, M, M, S), n_periods=11,
                   suffix=(M, M, M, M)),
    ssm=SSMCfg(d_inner=7168, head_dim=64, state=64, n_groups=1,
               conv_kernel=4, chunk=128),
    rope_theta=1e4,
    backends={"ssd": "chunked"},
    skip_shapes=(),          # hybrid: long_500k runs (SSM majority; 11 full-KV
                             # shared-attn applications, seq-sharded cache)
    notes="Zamba2 realised as 6:1 mamba:shared-attn periods; G=1 B/C groups.",
)
