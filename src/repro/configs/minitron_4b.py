"""minitron-4b [dense] — pruned Nemotron: 24H x 128, GQA kv=8, 2-matrix
ReLU MLP (squared-relu in the original; plain relu here — noted).
[arXiv:2407.14679]"""

from repro.configs.base import ArchConfig, Block, LayerPlan

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    plan=LayerPlan(period=(Block("attn", "mlp"),), n_periods=32),
    act="relu",
    skip_shapes=("long_500k",),
)
