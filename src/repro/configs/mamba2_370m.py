"""mamba2-370m [ssm] — 48 pure SSD blocks, attention-free; the flagship
long-context arch (long_500k decodes with O(1) state). [arXiv:2405.21060]"""

from repro.configs.base import ArchConfig, Block, LayerPlan, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    d_model=1024,
    n_heads=1,               # no attention heads
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    plan=LayerPlan(period=(Block("mamba", "none"),), n_periods=48),
    ssm=SSMCfg(d_inner=2048, head_dim=64, state=128, n_groups=1,
               conv_kernel=4, chunk=128),
    tie_embeddings=True,
    backends={"ssd": "chunked"},
    skip_shapes=(),
)
