"""Architecture/config schema for all assigned architectures.

An :class:`ArchConfig` fully describes one architecture: dims, the per-layer
block plan (prefix + scanned periods + suffix — heterogeneous stacks like
gemma3's 5 local : 1 global or zamba2's 6 mamba : 1 shared-attn compile as a
single scanned period, keeping HLO size O(period) instead of O(layers)), the
MoE / SSM / MLA sub-configs, and the assigned benchmark shapes.

Backend selection (the paper's technique) is carried per-arch in
``backends`` — op name -> registry backend — so a config IS a backend
assignment, swappable at launch (``--backend attention=pallas``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

__all__ = [
    "Block", "LayerPlan", "MoECfg", "SSMCfg", "MLACfg", "ShapeCfg",
    "ArchConfig", "round_up", "STANDARD_SHAPES",
]


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class Block:
    """One layer's composition: a sequence mixer + a channel mixer.
    ``cross=True`` inserts a cross-attention sublayer (enc-dec decoders)."""

    mixer: str   # attn | attn_local | mla | mamba | shared_attn
    ffn: str     # swiglu | mlp | moe | none
    cross: bool = False


@dataclass(frozen=True)
class LayerPlan:
    """prefix (unrolled) + period x n_periods (lax.scan) + suffix (unrolled)."""

    period: Tuple[Block, ...]
    n_periods: int
    prefix: Tuple[Block, ...] = ()
    suffix: Tuple[Block, ...] = ()

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + self.n_periods * len(self.period) + len(self.suffix)

    def all_blocks(self) -> Tuple[Block, ...]:
        return self.prefix + self.period * self.n_periods + self.suffix


@dataclass(frozen=True)
class MoECfg:
    n_routed: int            # logical routed experts
    top_k: int
    d_expert: int            # per-expert FFN width
    n_shared: int = 0        # shared experts (always active)
    d_shared: int = 0        # total shared-expert FFN width
    n_routed_padded: int = 0 # padded for even EP sharding (0 = same as n_routed)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True  # normalise top-k weights to sum 1
    # "global": one capacity pool over all tokens (baseline; the dispatch
    #   cumsum/sort/scatter spans the whole DP-sharded token axis).
    # "local": per-batch-row capacity pools — every routing/dispatch index
    #   op stays inside one DP shard, so the only cross-device traffic left
    #   is the unavoidable token->expert movement (EXPERIMENTS.md §Perf).
    dispatch: str = "global"

    @property
    def n_experts(self) -> int:
        return self.n_routed_padded or self.n_routed


@dataclass(frozen=True)
class SSMCfg:
    d_inner: int
    head_dim: int            # P
    state: int               # N
    n_groups: int = 1        # G (B/C shared per group)
    conv_kernel: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.state


@dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128

    @property
    def qk_dim(self) -> int:
        return self.nope_dim + self.rope_dim


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


STANDARD_SHAPES: Tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", "train", 4096, 256),
    ShapeCfg("prefill_32k", "prefill", 32768, 32),
    ShapeCfg("decode_32k", "decode", 32768, 128),
    ShapeCfg("long_500k", "decode", 524288, 1),
)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    plan: LayerPlan
    # attention details
    window: Optional[int] = None      # sliding window for attn_local
    rope_theta: float = 1e4
    attn_logit_softcap: Optional[float] = None
    # sub-configs
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    mla: Optional[MLACfg] = None
    # enc-dec (seamless): encoder stack prepended; plan describes the decoder
    n_encoder_layers: int = 0
    # frontends: tokens (LM) | embeds (vlm/audio stub provides embeddings)
    frontend: str = "tokens"
    act: str = "silu"                 # for ffn="mlp": relu/gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "bfloat16"     # serving param dtype (training: f32)
    remat: bool = True                # activation checkpointing over periods
    backends: Mapping[str, str] = field(default_factory=dict)
    skip_shapes: Tuple[str, ...] = ()
    shapes: Tuple[ShapeCfg, ...] = STANDARD_SHAPES
    notes: str = ""

    # ------------------------------------------------------------------ #
    @property
    def n_layers(self) -> int:
        return self.plan.n_layers + self.n_encoder_layers

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so embedding/lm_head shard evenly on 16-way TP."""
        return round_up(self.vocab, 128)

    def backend(self, op: str, default: str = "ref") -> str:
        return self.backends.get(op, default)

    def shape(self, name: str) -> ShapeCfg:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name}: unknown shape {name!r}")

    def runnable_shapes(self) -> Tuple[ShapeCfg, ...]:
        return tuple(s for s in self.shapes if s.name not in self.skip_shapes)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # parameter count (embedding + blocks), used for MODEL_FLOPS and docs
    def param_count(self) -> Dict[str, float]:
        d, dff = self.d_model, self.d_ff
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        counts = {"embed": self.vocab_padded * d}
        if not self.tie_embeddings:
            counts["lm_head"] = self.vocab_padded * d
        total_blk = 0.0
        active_blk = 0.0
        shared_attn_counted = False
        for blk in self.plan.all_blocks():
            m = 0.0
            if blk.mixer in ("attn", "attn_local", "cross_attn"):
                m += d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d
            elif blk.mixer == "mla":
                mla = self.mla
                m += d * (hq * mla.qk_dim)                       # q proj
                m += d * (mla.kv_lora_rank + mla.rope_dim)       # latent + k_pe
                m += mla.kv_lora_rank * hq * (mla.nope_dim + mla.v_dim)
                m += hq * mla.v_dim * d
            elif blk.mixer == "mamba":
                s = self.ssm
                m += d * (2 * s.d_inner + 2 * s.n_groups * s.state + s.n_heads)
                m += s.conv_kernel * s.conv_dim + 3 * s.n_heads + s.d_inner
                m += s.d_inner * d
            elif blk.mixer == "shared_attn":
                if not shared_attn_counted:   # params shared across periods
                    m += 2 * d * d            # concat fuse (2d -> d)
                    m += d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d
                    m += 3 * d * dff          # shared block's own MLP
                    shared_attn_counted = True
            f = 0.0
            f_active = 0.0
            if blk.ffn == "swiglu":
                f = 3 * d * dff
                f_active = f
            elif blk.ffn == "mlp":
                f = 2 * d * dff
                f_active = f
            elif blk.ffn == "moe":
                mo = self.moe
                f = mo.n_experts * 3 * d * mo.d_expert + d * mo.n_experts
                if mo.n_shared:
                    f += 3 * d * mo.d_shared
                f_active = (mo.top_k * 3 * d * mo.d_expert + d * mo.n_experts
                            + (3 * d * mo.d_shared if mo.n_shared else 0))
            total_blk += m + f
            active_blk += m + f_active
        # encoder stack (attn + mlp per layer)
        enc = self.n_encoder_layers * (
            d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d + 2 * d * dff)
        counts["blocks"] = total_blk + enc
        counts["total"] = sum(v for k, v in counts.items() if k != "total")
        # "active" = params that do matmul work per token (6·N·D convention):
        # block params (top-k experts only for MoE) + the LM head projection
        # (tied or not, the head matmul happens); the input-embedding GATHER
        # does no FLOPs and is excluded.
        counts["active"] = active_blk + enc + self.vocab_padded * d
        return counts
