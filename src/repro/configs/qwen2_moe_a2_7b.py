"""qwen2-moe-a2.7b [moe] — 24L, 60 routed experts top-4 + 4 shared experts.

Routed experts padded 60 -> 64 for even 16-way expert parallelism (padding
experts masked to -inf in the router; 6.7% extra expert storage, zero extra
active FLOPs).  Shared experts modelled as one SwiGLU of width 4x1408=5632.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""

from repro.configs.base import ArchConfig, Block, LayerPlan, MoECfg

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,               # per-expert width (assignment value)
    vocab=151936,
    plan=LayerPlan(period=(Block("attn", "moe"),), n_periods=24),
    moe=MoECfg(n_routed=60, n_routed_padded=64, top_k=4, d_expert=1408,
               n_shared=4, d_shared=5632,
               dispatch="local"),  # EXPERIMENTS.md §Perf-2 (baseline: global)
    skip_shapes=("long_500k",),
    notes="60->64 expert padding for even EP; shared experts fused to one 5632-wide SwiGLU.",
)
