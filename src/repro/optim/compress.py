"""Gradient compression for DP all-reduce: int8 quantisation with error
feedback (EF-SGD style).

Under pjit the gradient all-reduce is implicit, so compression is expressed
as a shard_map stage: each DP shard adds its carried quantisation residual,
quantises to int8 (symmetric per-tensor scale; 4x fewer wire bytes than
f32, 2x vs bf16), all-reduces, and keeps the new residual locally — added
back next step.  Error feedback keeps the induced bias bounded
(tests/test_compress.py checks the convergence property).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["quantize", "dequantize", "compress_decompress",
           "compressed_psum_mean"]


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8 payload, f32 scale). Symmetric per-tensor."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array, err: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback round on one shard: (decompressed, new_error)."""
    g32 = g.astype(jnp.float32) + err
    q, s = quantize(g32)
    deq = dequantize(q, s)
    return deq, g32 - deq


def compressed_psum_mean(mesh: Mesh, axis: str = "data"):
    """Returns ``f(local_grads, err_state) -> (mean_grads, new_errs)``.

    The wire payload is the int8 tensor + one f32 scale per tensor per
    shard; the psum of per-shard dequantisations equals the sum of
    quantised shard gradients exactly."""
    n = mesh.shape[axis]

    def one(g, err):
        deq, new_err = compress_decompress(g, err)
        return jax.lax.psum(deq, axis) / n, new_err

    def wrapped(grads, errs):
        flat_g, tree = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errs)
        out = jax.shard_map(
            lambda gs, es: tuple(one(g, e) for g, e in zip(gs, es)),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(),
        )(tuple(flat_g), tuple(flat_e))
        means = tree.unflatten([o[0] for o in out])
        new_errs = tree.unflatten([o[1] for o in out])
        return means, new_errs

    return wrapped
