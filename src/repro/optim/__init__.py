"""Optimisation: AdamW (from scratch), schedules, gradient compression."""

from repro.optim import adamw, compress, schedule  # noqa: F401
from repro.optim.adamw import AdamWConfig

__all__ = ["adamw", "compress", "schedule", "AdamWConfig"]
