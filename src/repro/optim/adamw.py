"""AdamW from scratch (no optax), with mixed-precision master params and
ZeRO-1-style sharded moments.

State layout: {"step", "m", "mu", "nu"} where "m" holds f32 master params
(when params are bf16) and mu/nu are the f32 moments. Moment sharding comes
from :func:`repro.sharding.specs.opt_state_specs` — each moment shards its
largest replicated dim over the data axis, giving the ZeRO-1 memory win
(8 bytes/param -> 8/DP bytes/param) with XLA inserting the param
all-gather after the update.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
State = Dict[str, Any]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None  # step -> lr


def init(params: Params, cfg: AdamWConfig) -> State:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    state: State = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads: Params, state: State, params: Params, cfg: AdamWConfig
           ) -> Tuple[Params, State, Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        m32 = master.astype(jnp.float32)
        step_v = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * m32
        new_master = m32 - lr * step_v
        return mu, nu, new_master, new_master.astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["mu"], state["nu"], masters, params)
    mu = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda t: t[3], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_state: State = {"step": step, "mu": mu, "nu": nu}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, new_state, metrics
