"""Encoder-decoder model (seamless-m4t backbone).

Encoder: non-causal attn + MLP blocks over precomputed frame embeddings
(the audio frontend is a stub per the assignment — ``input_specs`` provides
(B, S_src, d) embeddings).  Decoder: causal self-attn + cross-attn + MLP
over text tokens.  Decode-time cross-attention K/V are computed once at
prefill and cached read-only.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Block, LayerPlan
from repro.layers.common import dense_init, embed_init, norm
from repro.models.lm import cross_entropy, mask_vocab
from repro.models.stack import init_stack_caches, stack_apply, stack_init

Params = Dict[str, Any]


class EncDec:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.enc_plan = LayerPlan(period=(Block("attn", "mlp"),),
                                  n_periods=cfg.n_encoder_layers)
        self.dec_plan = cfg.plan  # blocks carry cross=True

    def init_params(self, key: jax.Array, dtype=None) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype) if dtype is None else dtype
        ks = jax.random.split(key, 5)
        return {
            "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype=dtype),
            "encoder": stack_init(ks[1], cfg, self.enc_plan, dtype=dtype),
            "enc_norm": jnp.ones((cfg.d_model,), dtype),
            "decoder": stack_init(ks[2], cfg, self.dec_plan, dtype=dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
            "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab_padded, dtype=dtype),
        }

    # ------------------------------------------------------------------ #
    def encode(self, params: Params, src_embeds: jax.Array,
               remat: bool = True) -> jax.Array:
        cfg = self.cfg
        h = src_embeds.astype(jnp.dtype(cfg.dtype))
        h, _, _ = stack_apply(params["encoder"], h, self.enc_plan, cfg=cfg,
                              mode="train", causal=False, remat=remat)
        return norm(h, params["enc_norm"], eps=cfg.norm_eps,
                    backend=cfg.backend("rmsnorm"))

    def _decode_trunk(self, params, h, *, mode, caches, lengths, enc_out,
                      enc_lengths, cache_cap, remat=True):
        cfg = self.cfg
        h, new_caches, aux = stack_apply(
            params["decoder"], h, self.dec_plan, cfg=cfg, mode=mode,
            caches=caches, lengths=lengths, enc_out=enc_out,
            enc_lengths=enc_lengths, cache_cap=cache_cap, remat=remat)
        h = norm(h, params["final_norm"], eps=cfg.norm_eps,
                 backend=cfg.backend("rmsnorm"))
        return h, new_caches, aux

    # ------------------------------------------------------------------ #
    def train_loss(self, params: Params, batch: Dict[str, jax.Array],
                   *, remat: bool = True):
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"], remat=remat)
        h = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
        h, _, aux = self._decode_trunk(params, h, mode="train", caches=None,
                                       lengths=None, enc_out=enc_out,
                                       enc_lengths=None, cache_cap=None,
                                       remat=remat)
        logits = jnp.einsum("...d,dv->...v", h,
                            params["lm_head"].astype(h.dtype))
        ce = cross_entropy(logits, batch["labels"], cfg)
        return ce, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ #
    def prefill(self, params: Params, batch: Dict[str, jax.Array], *,
                cache_cap: int):
        """Encode src, prefill decoder over ``tokens``; returns
        (last logits, caches, lengths)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"], remat=False)
        b, s_src = enc_out.shape[0], enc_out.shape[1]
        h = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
        h, caches, _ = self._decode_trunk(
            params, h, mode="prefill", caches=None, lengths=None,
            enc_out=enc_out, enc_lengths=jnp.full((b,), s_src, jnp.int32),
            cache_cap=cache_cap, remat=False)
        logits = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"].astype(h.dtype))
        lengths = jnp.full((b,), batch["tokens"].shape[1], jnp.int32)
        return mask_vocab(logits, cfg), caches, lengths

    def decode_step(self, params: Params, tokens: jax.Array, caches,
                    lengths: jax.Array, enc_lengths: jax.Array):
        cfg = self.cfg
        h = params["embed"][tokens[:, None]].astype(jnp.dtype(cfg.dtype))
        h, new_caches, _ = self._decode_trunk(
            params, h, mode="decode", caches=caches, lengths=lengths,
            enc_out=None, enc_lengths=enc_lengths, cache_cap=None, remat=False)
        logits = jnp.einsum("bd,dv->bv", h[:, 0],
                            params["lm_head"].astype(h.dtype))
        return mask_vocab(logits, cfg), new_caches

    # ------------------------------------------------------------------ #
    def init_caches(self, batch: int, cache_cap: int, enc_len: int,
                    dtype=None):
        dtype = jnp.dtype(self.cfg.dtype) if dtype is None else dtype
        return init_stack_caches(self.cfg, self.dec_plan, batch, cache_cap,
                                 enc_len=enc_len, dtype=dtype)
