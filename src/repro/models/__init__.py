"""Model assemblies: decoder LMs, encoder-decoder, and the paper's CNN zoo."""

from repro.models.cnn import CNN_MODELS, build_cnn
from repro.models.encdec import EncDec
from repro.models.lm import LM

__all__ = ["CNN_MODELS", "build_cnn", "EncDec", "LM"]
