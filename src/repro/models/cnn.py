"""The paper's five evaluation CNNs (Fig. 2) as GraphIR builders:
WRN-40-2, MobileNetV1, ResNet-18, Inception-v3, ResNet-50.

Built exactly the way an ONNX import would land: conv / batchnorm / relu /
pool / dense nodes with weights as graph params — so the simplification
pipeline (BN folding, bias+act fusion) and the backend comparison
(GEMM vs direct vs winograd vs pallas conv) run on the real structures the
paper measured.  Weights are seeded-random (inference timing doesn't care).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import Graph, Node, TensorSpec

__all__ = ["build_cnn", "CNN_MODELS"]


class _GB:
    """Tiny graph builder."""

    def __init__(self, name: str, input_shape: Tuple[int, ...], seed: int = 0):
        self.g = Graph(name=name, inputs={"x": TensorSpec(input_shape)},
                       outputs=[], nodes=[], params={})
        self.rng = np.random.default_rng(seed)
        self.n = 0

    def _name(self, op: str) -> str:
        self.n += 1
        return f"{op}_{self.n}"

    def _param(self, name: str, shape, scale=None) -> str:
        fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        scale = scale if scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
        self.g.params[name] = (self.rng.standard_normal(shape) * scale
                               ).astype(np.float32)
        return name

    def _node(self, op: str, inputs: List[str], attrs=None) -> str:
        name = self._name(op)
        out = f"{name}.out"
        self.g.nodes.append(Node(name, op, inputs, [out], attrs or {}))
        return out

    # ------------------------------------------------------------------ #
    def conv(self, x: str, ci: int, co: int, k: int, stride: int = 1,
             padding: str = "SAME", groups: int = 1) -> str:
        w = self._param(self._name("w"), (k, k, ci // groups, co))
        return self._node("conv2d", [x, w],
                          {"stride": stride, "padding": padding, "groups": groups})

    def bn(self, x: str, c: int) -> str:
        pre = self._name("bn")
        names = [self._param(f"{pre}.{s}", (c,), scale=1.0) for s in
                 ("scale", "bias", "mean")]
        var = f"{pre}.var"
        self.g.params[var] = np.abs(self.rng.standard_normal((c,))
                                    ).astype(np.float32) + 0.5
        return self._node("batchnorm", [x] + names + [var], {"eps": 1e-5})

    def relu(self, x: str) -> str:
        return self._node("relu", [x])

    def add(self, a: str, b: str) -> str:
        return self._node("add", [a, b])

    def maxpool(self, x: str, k: int, s: int, padding="SAME") -> str:
        return self._node("maxpool2d", [x], {"window": k, "stride": s,
                                             "padding": padding})

    def avgpool(self, x: str, k: int, s: int, padding="SAME") -> str:
        return self._node("avgpool2d", [x], {"window": k, "stride": s,
                                             "padding": padding})

    def gap(self, x: str) -> str:
        return self._node("global_avgpool", [x])

    def concat(self, xs: List[str]) -> str:
        return self._node("concat", xs, {"axis": -1})

    def head(self, x: str, ci: int, classes: int = 1000) -> str:
        w = self._param(self._name("w"), (ci, classes))
        b = self._param(self._name("b"), (classes,), scale=0.0)
        h = self._node("dense", [x, w])
        return self._node("bias_add", [h, b])

    def cbr(self, x: str, ci: int, co: int, k: int, stride: int = 1,
            padding="SAME", groups: int = 1, act: bool = True) -> str:
        h = self.bn(self.conv(x, ci, co, k, stride, padding, groups), co)
        return self.relu(h) if act else h

    def done(self, out: str) -> Graph:
        self.g.outputs = [out]
        self.g.validate()
        return self.g


# --------------------------------------------------------------------------- #

def resnet18(batch: int = 1) -> Graph:
    b = _GB("resnet18", (batch, 224, 224, 3), seed=18)
    h = b.cbr("x", 3, 64, 7, 2)
    h = b.maxpool(h, 3, 2)
    c = 64
    for stage, (co, blocks) in enumerate([(64, 2), (128, 2), (256, 2), (512, 2)]):
        for i in range(blocks):
            stride = 2 if (i == 0 and stage > 0) else 1
            sc = h if (stride == 1 and c == co) else b.cbr(h, c, co, 1, stride, act=False)
            y = b.cbr(h, c, co, 3, stride)
            y = b.cbr(y, co, co, 3, 1, act=False)
            h = b.relu(b.add(y, sc))
            c = co
    return b.done(b.head(b.gap(h), 512))


def resnet50(batch: int = 1) -> Graph:
    b = _GB("resnet50", (batch, 224, 224, 3), seed=50)
    h = b.cbr("x", 3, 64, 7, 2)
    h = b.maxpool(h, 3, 2)
    c = 64
    for stage, (w, blocks) in enumerate([(64, 3), (128, 4), (256, 6), (512, 3)]):
        co = w * 4
        for i in range(blocks):
            stride = 2 if (i == 0 and stage > 0) else 1
            sc = h if (stride == 1 and c == co) else b.cbr(h, c, co, 1, stride, act=False)
            y = b.cbr(h, c, w, 1, 1)
            y = b.cbr(y, w, w, 3, stride)
            y = b.cbr(y, w, co, 1, 1, act=False)
            h = b.relu(b.add(y, sc))
            c = co
    return b.done(b.head(b.gap(h), 2048))


def wrn_40_2(batch: int = 1) -> Graph:
    """Wide ResNet 40-2 (CIFAR): n=(40-4)/6=6 blocks/group, widen 2."""
    b = _GB("wrn40_2", (batch, 32, 32, 3), seed=40)
    h = b.cbr("x", 3, 16, 3, 1)
    c = 16
    for stage, co in enumerate([32, 64, 128]):
        for i in range(6):
            stride = 2 if (i == 0 and stage > 0) else 1
            sc = h if (stride == 1 and c == co) else b.cbr(h, c, co, 1, stride, act=False)
            y = b.cbr(h, c, co, 3, stride)
            y = b.cbr(y, co, co, 3, 1, act=False)
            h = b.relu(b.add(y, sc))
            c = co
    return b.done(b.head(b.gap(h), 128, classes=10))


def mobilenet_v1(batch: int = 1) -> Graph:
    b = _GB("mobilenet_v1", (batch, 224, 224, 3), seed=1)
    h = b.cbr("x", 3, 32, 3, 2)
    c = 32
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
            (1024, 1)]
    for co, stride in plan:
        h = b.cbr(h, c, c, 3, stride, groups=c)    # depthwise
        h = b.cbr(h, c, co, 1, 1)                  # pointwise
        c = co
    return b.done(b.head(b.gap(h), 1024))


def _inception_a(b: _GB, x: str, ci: int, pool_ch: int) -> Tuple[str, int]:
    b1 = b.cbr(x, ci, 64, 1)
    b2 = b.cbr(b.cbr(x, ci, 48, 1), 48, 64, 5)
    b3 = b.cbr(b.cbr(b.cbr(x, ci, 64, 1), 64, 96, 3), 96, 96, 3)
    b4 = b.cbr(b.avgpool(x, 3, 1), ci, pool_ch, 1)
    return b.concat([b1, b2, b3, b4]), 64 + 64 + 96 + pool_ch


def _inception_b(b: _GB, x: str, ci: int, c7: int) -> Tuple[str, int]:
    b1 = b.cbr(x, ci, 192, 1)
    h = b.cbr(x, ci, c7, 1)
    h = b.cbr(h, c7, c7, 1)   # 1x7 simplified to 1x1+3x3 pair cost-equivalent
    b2 = b.cbr(h, c7, 192, 3)
    h = b.cbr(x, ci, c7, 1)
    h = b.cbr(h, c7, c7, 3)
    b3 = b.cbr(h, c7, 192, 3)
    b4 = b.cbr(b.avgpool(x, 3, 1), ci, 192, 1)
    return b.concat([b1, b2, b3, b4]), 192 * 4


def _inception_c(b: _GB, x: str, ci: int) -> Tuple[str, int]:
    b1 = b.cbr(x, ci, 320, 1)
    h = b.cbr(x, ci, 384, 1)
    b2 = b.concat([b.cbr(h, 384, 384, 3), b.cbr(h, 384, 384, 3)])
    h = b.cbr(x, ci, 448, 1)
    h = b.cbr(h, 448, 384, 3)
    b3 = b.concat([b.cbr(h, 384, 384, 3), b.cbr(h, 384, 384, 3)])
    b4 = b.cbr(b.avgpool(x, 3, 1), ci, 192, 1)
    return b.concat([b1, b2, b3, b4]), 320 + 768 + 768 + 192


def inception_v3(batch: int = 1) -> Graph:
    """Inception-v3 (299x299); 1x7/7x1 factorised convs approximated by
    cost-equivalent 3x3s (documented simplification — the backend comparison
    is about conv algorithm choice, not exact Inception kernels)."""
    b = _GB("inception_v3", (batch, 299, 299, 3), seed=3)
    h = b.cbr("x", 3, 32, 3, 2, padding="VALID")
    h = b.cbr(h, 32, 32, 3, 1, padding="VALID")
    h = b.cbr(h, 32, 64, 3, 1)
    h = b.maxpool(h, 3, 2, padding="VALID")
    h = b.cbr(h, 64, 80, 1)
    h = b.cbr(h, 80, 192, 3, 1, padding="VALID")
    h = b.maxpool(h, 3, 2, padding="VALID")
    ci = 192
    for pool_ch in (32, 64, 64):
        h, ci = _inception_a(b, h, ci, pool_ch)
    # reduction A
    r1 = b.cbr(h, ci, 384, 3, 2, padding="VALID")
    r2 = b.cbr(b.cbr(b.cbr(h, ci, 64, 1), 64, 96, 3), 96, 96, 3, 2, padding="VALID")
    r3 = b.maxpool(h, 3, 2, padding="VALID")
    h = b.concat([r1, r2, r3])
    ci = 384 + 96 + ci
    for c7 in (128, 160, 160, 192):
        h, ci = _inception_b(b, h, ci, c7)
    # reduction B
    r1 = b.cbr(b.cbr(h, ci, 192, 1), 192, 320, 3, 2, padding="VALID")
    r2 = b.cbr(b.cbr(b.cbr(h, ci, 192, 1), 192, 192, 3), 192, 192, 3, 2,
               padding="VALID")
    r3 = b.maxpool(h, 3, 2, padding="VALID")
    h = b.concat([r1, r2, r3])
    ci = 320 + 192 + ci
    for _ in range(2):
        h, ci = _inception_c(b, h, ci)
    return b.done(b.head(b.gap(h), ci))


CNN_MODELS = {
    "wrn-40-2": wrn_40_2,
    "mobilenet-v1": mobilenet_v1,
    "resnet-18": resnet18,
    "inception-v3": inception_v3,
    "resnet-50": resnet50,
}


def build_cnn(name: str, batch: int = 1) -> Graph:
    return CNN_MODELS[name](batch)
