"""Block-stack machinery: init/apply for a LayerPlan (prefix + scanned
periods + suffix).

The scanned period keeps compiled HLO size O(|period|) instead of
O(n_layers) — essential for the 81-layer zamba2 / 48-layer mamba2 dry-runs —
while heterogeneous patterns (gemma3 5 local:1 global, zamba2 6 mamba:1
shared-attn) fit naturally as the period.

Parameters for position i of the period are stacked along axis 0
(n_periods, ...); caches follow the same layout, so prefill produces them
as scan outputs and decode consumes/updates them as scan xs/ys.

Zamba2's *shared* attention blocks live OUTSIDE the stacking (weights are
shared across periods — two alternating blocks selected by period index);
their caches are per-application and therefore stacked like everything else.

Train mode wraps the period body in ``jax.checkpoint`` (dots-saveable
policy) — activation recompute keeps the backward pass' live set
O(period) too.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Block, LayerPlan
from repro.layers.attention import (attn_apply, attn_init, mla_apply,
                                    mla_init, shared_attn_apply,
                                    shared_attn_init)
from repro.layers.common import norm
from repro.layers.mlp import mlp_apply, mlp_init, swiglu_apply, swiglu_init
from repro.layers.moe import moe_apply, moe_init
from repro.layers.ssm import mamba_apply, mamba_init

Params = Dict[str, Any]

# Analysis mode: see repro.analysis (re-exported here for launch/dryrun).
from repro.analysis import unroll_scans, unrolling  # noqa: E402,F401


# --------------------------------------------------------------------------- #
# single block
# --------------------------------------------------------------------------- #

def block_init(key: jax.Array, cfg: ArchConfig, blk: Block, *,
               dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Params = {}
    if blk.mixer in ("attn", "attn_local"):
        p["norm1"] = jnp.ones((d,), dtype)
        p["mixer"] = attn_init(ks[0], cfg, dtype=dtype)
    elif blk.mixer == "mla":
        p["norm1"] = jnp.ones((d,), dtype)
        p["mixer"] = mla_init(ks[0], cfg, dtype=dtype)
    elif blk.mixer == "mamba":
        p["norm1"] = jnp.ones((d,), dtype)
        p["mixer"] = mamba_init(ks[0], cfg, dtype=dtype)
    elif blk.mixer == "shared_attn":
        pass  # params live in the stack-level "shared" slot
    else:
        raise ValueError(f"unknown mixer {blk.mixer!r}")
    if blk.cross:
        p["norm_x"] = jnp.ones((d,), dtype)
        p["cross"] = attn_init(ks[1], cfg, cross=True, dtype=dtype)
    if blk.ffn != "none":
        p["norm2"] = jnp.ones((d,), dtype)
        if blk.ffn == "swiglu":
            p["ffn"] = swiglu_init(ks[2], d, cfg.d_ff, dtype=dtype)
        elif blk.ffn == "mlp":
            p["ffn"] = mlp_init(ks[2], d, cfg.d_ff, dtype=dtype)
        elif blk.ffn == "moe":
            p["ffn"] = moe_init(ks[2], cfg, dtype=dtype)
        else:
            raise ValueError(f"unknown ffn {blk.ffn!r}")
    return p


def _empty_cache_like(blk: Block) -> bool:
    return blk.mixer in ("attn", "attn_local", "mla", "mamba", "shared_attn") \
        or blk.cross


def block_apply(p: Params, h: jax.Array, blk: Block, *, cfg: ArchConfig,
                mode: str, cache: Any = None, lengths=None, emb0=None,
                enc_out=None, enc_lengths=None, shared_params: Params = None,
                cache_cap: Optional[int] = None, causal: bool = True
                ) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (h, new_cache, aux_loss). ``cache`` is a dict with optional
    keys 'mix' and 'cross' (block-level cache container)."""
    aux = jnp.zeros((), jnp.float32)
    cache = cache or {}
    new_cache: Dict[str, Any] = {}
    nb = cfg.backend("rmsnorm")
    eps = cfg.norm_eps

    if blk.mixer == "shared_attn":
        h, c = shared_attn_apply(shared_params, h, emb0, cfg=cfg, mode=mode,
                                 cache=cache.get("mix"), lengths=lengths,
                                 cache_cap=cache_cap)
        if c is not None:
            new_cache["mix"] = c
    else:
        x = norm(h, p["norm1"], eps=eps, backend=nb)
        if blk.mixer in ("attn", "attn_local"):
            window = cfg.window if blk.mixer == "attn_local" else None
            y, c = attn_apply(p["mixer"], x, cfg=cfg, mode=mode, window=window,
                              cache=cache.get("mix"), lengths=lengths,
                              cache_cap=cache_cap, causal=causal)
        elif blk.mixer == "mla":
            y, c = mla_apply(p["mixer"], x, cfg=cfg, mode=mode,
                             cache=cache.get("mix"), lengths=lengths,
                             cache_cap=cache_cap)
        elif blk.mixer == "mamba":
            y, c = mamba_apply(p["mixer"], x, cfg=cfg, mode=mode,
                               cache=cache.get("mix"), lengths=lengths)
        else:
            raise ValueError(blk.mixer)
        h = h + y
        if c is not None:
            new_cache["mix"] = c

    if blk.cross:
        x = norm(h, p["norm_x"], eps=eps, backend=nb)
        y, c = attn_apply(p["cross"], x, cfg=cfg, mode=mode, cross=True,
                          cache=cache.get("cross"), enc_out=enc_out,
                          enc_lengths=enc_lengths)
        h = h + y
        if c is not None:
            new_cache["cross"] = c

    if blk.ffn != "none":
        x = norm(h, p["norm2"], eps=eps, backend=nb)
        if blk.ffn == "swiglu":
            y = swiglu_apply(p["ffn"], x, cfg=cfg)
        elif blk.ffn == "mlp":
            y = mlp_apply(p["ffn"], x, cfg=cfg)
        else:  # moe
            y, aux = moe_apply(p["ffn"], x, cfg=cfg)
        h = h + y

    return h, (new_cache if new_cache else None), aux


# --------------------------------------------------------------------------- #
# stack = prefix + scanned periods + suffix
# --------------------------------------------------------------------------- #

def stack_init(key: jax.Array, cfg: ArchConfig, plan: LayerPlan, *,
               dtype=jnp.float32) -> Params:
    p: Params = {"prefix": [], "period": [], "suffix": []}
    for i, blk in enumerate(plan.prefix):
        p["prefix"].append(block_init(jax.random.fold_in(key, 1000 + i),
                                      cfg, blk, dtype=dtype))
    for pos, blk in enumerate(plan.period):
        per = [block_init(jax.random.fold_in(key, 10_000 + pos * 100 + j),
                          cfg, blk, dtype=dtype) for j in range(plan.n_periods)]
        p["period"].append(jax.tree.map(lambda *xs: jnp.stack(xs), *per)
                           if per and per[0] else {})
    for i, blk in enumerate(plan.suffix):
        p["suffix"].append(block_init(jax.random.fold_in(key, 2000 + i),
                                      cfg, blk, dtype=dtype))
    if any(b.mixer == "shared_attn" for b in plan.all_blocks()):
        sh = [shared_attn_init(jax.random.fold_in(key, 77 + i), cfg, dtype=dtype)
              for i in range(2)]  # two alternating shared blocks (Zamba2)
        p["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sh)
    return p


def stack_apply(params: Params, h: jax.Array, plan: LayerPlan, *,
                cfg: ArchConfig, mode: str, caches: Any = None,
                lengths=None, emb0=None, enc_out=None, enc_lengths=None,
                cache_cap: Optional[int] = None, causal: bool = True,
                remat: bool = True):
    """Returns (h, new_caches, aux_total)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = caches or {"prefix": [None] * len(plan.prefix),
                        "period": [None] * len(plan.period),
                        "suffix": [None] * len(plan.suffix)}
    new_caches = {"prefix": [], "period": None, "suffix": []}
    shared = params.get("shared")

    def pick_shared(period_idx):
        if shared is None:
            return None
        return jax.tree.map(lambda a: a[period_idx % 2], shared)

    common = dict(cfg=cfg, mode=mode, lengths=lengths, emb0=emb0,
                  enc_out=enc_out, enc_lengths=enc_lengths,
                  cache_cap=cache_cap, causal=causal)

    for blk, bp, bc in zip(plan.prefix, params["prefix"], caches["prefix"]):
        h, c, aux = block_apply(bp, h, blk, cache=bc,
                                shared_params=pick_shared(0), **common)
        new_caches["prefix"].append(c)
        aux_total = aux_total + aux

    if plan.n_periods > 0:
        def period_step(carry, xs):
            h, aux_acc = carry
            stacked_p, stacked_c, pidx = xs
            new_cs = []
            for j, blk in enumerate(plan.period):
                bc = stacked_c[j] if stacked_c is not None else None
                h, c, aux = block_apply(stacked_p[j], h, blk, cache=bc,
                                        shared_params=pick_shared(pidx),
                                        **common)
                new_cs.append(c)
            return (h, aux_acc + aux), new_cs

        body = period_step
        if remat and mode == "train":
            body = jax.checkpoint(
                period_step,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        if unrolling():
            collected = []
            for pidx in range(plan.n_periods):
                xs_i = jax.tree.map(lambda a: a[pidx],
                                    (params["period"], caches["period"]))
                (h, aux_total), cs = body((h, aux_total),
                                          (xs_i[0], xs_i[1], pidx))
                collected.append(cs)
            if mode != "train":
                new_caches["period"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *collected)
        else:
            xs = (params["period"], caches["period"],
                  jnp.arange(plan.n_periods))
            (h, aux_total), period_caches = jax.lax.scan(body, (h, aux_total),
                                                         xs)
            # drop all-None cache pytrees (train mode)
            if mode != "train":
                new_caches["period"] = period_caches
    for blk, bp, bc in zip(plan.suffix, params["suffix"], caches["suffix"]):
        h, c, aux = block_apply(bp, h, blk, cache=bc,
                                shared_params=pick_shared(plan.n_periods),
                                **common)
        new_caches["suffix"].append(c)
        aux_total = aux_total + aux

    return h, (new_caches if mode != "train" else None), aux_total


def init_stack_caches(cfg: ArchConfig, plan: LayerPlan, batch: int,
                      cache_cap: int, *, enc_len: int = 0,
                      dtype=jnp.bfloat16) -> Any:
    """Zero caches for decode-from-scratch / dry-run input specs."""
    def one(blk: Block):
        c: Dict[str, Any] = {}
        if blk.mixer in ("attn", "attn_local", "shared_attn"):
            cap = min(cfg.window, cache_cap) if blk.mixer == "attn_local" else cache_cap
            c["mix"] = {
                "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.head_dim), dtype),
            }
        elif blk.mixer == "mla":
            m = cfg.mla
            c["mix"] = {
                "ckv": jnp.zeros((batch, cache_cap, m.kv_lora_rank), dtype),
                "kpe": jnp.zeros((batch, cache_cap, m.rope_dim), dtype),
            }
        elif blk.mixer == "mamba":
            s = cfg.ssm
            gn = s.n_groups * s.state
            c["mix"] = {
                "conv_x": jnp.zeros((batch, s.conv_kernel - 1, s.d_inner), dtype),
                "conv_B": jnp.zeros((batch, s.conv_kernel - 1, gn), dtype),
                "conv_C": jnp.zeros((batch, s.conv_kernel - 1, gn), dtype),
                "ssm": jnp.zeros((batch, s.n_heads, s.head_dim, s.state),
                                 jnp.float32),
            }
        if blk.cross:
            c["cross"] = {
                "k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            }
        return c if c else None

    stack = lambda c: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (plan.n_periods,) + a.shape), c)
    return {
        "prefix": [one(b) for b in plan.prefix],
        "period": [stack(one(b)) for b in plan.period],
        "suffix": [one(b) for b in plan.suffix],
    }
