"""A decoder-only transformer LM expressed as GraphIR — the serving
engine's model.

Unlike :class:`repro.models.lm.LM` (a Python class over layer functions),
this builder emits flat :class:`~repro.core.ir.Graph` objects, so the
prefill and decode steps go through the full staged compilation pipeline:
``compile(graph, policy=..., quantize=...)`` → :class:`Program`.  That is
the point of the serving engine — backend selection, quantization and the
autotune cache all apply to the serving hot path.

No node pins a backend: every op in these graphs — including the serving
ops ``embedding`` / ``cache_update`` / ``chunk_attention`` /
``decode_attention``, each of which carries ref/xla/pallas alternatives —
resolves through whatever :class:`~repro.core.selector.BackendPolicy` the
caller compiles with, and an :class:`~repro.core.selector.AutotunePolicy`
measures the candidates at the exact batch/chunk/cache-capacity shapes
these builders emit (persisted in the on-disk autotune cache).

State is functional: KV caches are graph *inputs* and *outputs*
(``cache_k{i}`` → ``new_cache_k{i}``), so a Program stays a pure function
and the engine threads cache arrays between calls.

Two graph shapes per model:

* decode:  tokens (B, 1)  — one token per slot, ``decode_attention`` hot op.
* prefill: tokens (B, T)  — one chunk per slot, ``chunk_attention``;
  ``n_new[b] <= T`` marks the valid prefix (0 = slot idle this step), so a
  fixed-shape Program serves ragged chunks and idle slots exactly.

Value names are identical across batch/chunk variants of the same config,
which lets one calibration (``repro.core.quant.calibrate``) drive the
int8 quantization of every variant — the engine's batched Programs and
the unbatched reference then share activation scales and stay token-exact
against each other.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import Graph, Node, TensorSpec

__all__ = ["GraphLMConfig", "init_lm_params", "build_decode_graph",
           "build_prefill_graph", "init_cache_inputs",
           "build_paged_decode_graph", "build_paged_prefill_graph",
           "init_paged_cache_inputs"]


@dataclass(frozen=True)
class GraphLMConfig:
    """Shape of the graph LM.  ``d_head = d_model // n_heads``; GQA when
    ``n_kv_heads < n_heads``."""

    vocab: int = 128
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 128
    eps: float = 1e-6

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def init_lm_params(cfg: GraphLMConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic random weights (numpy, float32), keyed by the value
    names the graph builders reference."""
    rng = np.random.default_rng(seed)

    def dense(din: int, dout: int) -> np.ndarray:
        return (rng.standard_normal((din, dout)) / np.sqrt(din)).astype(np.float32)

    dm, dh = cfg.d_model, cfg.d_head
    p: Dict[str, np.ndarray] = {
        "embed": (rng.standard_normal((cfg.vocab, dm)) * 0.5).astype(np.float32),
        "final_norm": np.ones((dm,), np.float32),
        "head_w": dense(dm, cfg.vocab),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.norm1"] = np.ones((dm,), np.float32)
        p[f"l{i}.wq"] = dense(dm, cfg.n_heads * dh)
        p[f"l{i}.wk"] = dense(dm, cfg.n_kv_heads * dh)
        p[f"l{i}.wv"] = dense(dm, cfg.n_kv_heads * dh)
        p[f"l{i}.wo"] = dense(cfg.n_heads * dh, dm)
        p[f"l{i}.norm2"] = np.ones((dm,), np.float32)
        p[f"l{i}.wg"] = dense(dm, cfg.d_ff)
        p[f"l{i}.wu"] = dense(dm, cfg.d_ff)
        p[f"l{i}.wd"] = dense(cfg.d_ff, dm)
    return p


def init_cache_inputs(cfg: GraphLMConfig, batch: int,
                      cache_cap: int) -> Dict[str, np.ndarray]:
    """Zeroed cache arrays matching the graph's cache input names."""
    shape = (batch, cache_cap, cfg.n_kv_heads, cfg.d_head)
    out: Dict[str, np.ndarray] = {}
    for i in range(cfg.n_layers):
        out[f"cache_k{i}"] = np.zeros(shape, np.float32)
        out[f"cache_v{i}"] = np.zeros(shape, np.float32)
    return out


def init_paged_cache_inputs(cfg: GraphLMConfig, n_blocks: int,
                            page_size: int, *,
                            kv_dtype: str = "float32") -> Dict[str, np.ndarray]:
    """Zeroed page-pool arrays matching the paged graphs' cache input
    names.  Unlike the dense layout there is no batch dimension — one
    shared pool of ``n_blocks`` fixed-size pages per layer, indexed
    through per-sequence block tables.  With ``kv_dtype="int8"`` the
    pools are int8 and each gains a ``cache_{k,v}{i}_scale`` sidecar
    ((n_blocks, Hk) float32, all zeros = every page empty)."""
    if kv_dtype not in ("float32", "int8"):
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
    shape = (n_blocks, page_size, cfg.n_kv_heads, cfg.d_head)
    dt = np.int8 if kv_dtype == "int8" else np.float32
    out: Dict[str, np.ndarray] = {}
    for i in range(cfg.n_layers):
        out[f"cache_k{i}"] = np.zeros(shape, dt)
        out[f"cache_v{i}"] = np.zeros(shape, dt)
        if kv_dtype == "int8":
            sshape = (n_blocks, cfg.n_kv_heads)
            out[f"cache_k{i}_scale"] = np.zeros(sshape, np.float32)
            out[f"cache_v{i}_scale"] = np.zeros(sshape, np.float32)
    return out


def _lm_graph(cfg: GraphLMConfig, params: Dict[str, Any], *, batch: int,
              t: int, cache_cap: int, decode: bool,
              paged: Optional[Tuple[int, int, int]] = None,
              kv_dtype: str = "float32") -> Graph:
    if t > cache_cap:
        raise ValueError(f"chunk {t} exceeds cache capacity {cache_cap}")
    if kv_dtype not in ("float32", "int8"):
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
    kv8 = kv_dtype == "int8"
    if kv8 and paged is None:
        raise ValueError("kv_dtype='int8' requires the paged cache layout")
    dm, dh, hq, hk = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    inputs: Dict[str, TensorSpec] = {
        "tokens": TensorSpec((batch, t), "int32"),
        "start": TensorSpec((batch,), "int32"),
        "n_new": TensorSpec((batch,), "int32"),
    }
    if paged is None:
        for i in range(cfg.n_layers):
            spec = TensorSpec((batch, cache_cap, hk, dh), "float32")
            inputs[f"cache_k{i}"] = spec
            inputs[f"cache_v{i}"] = spec
    else:
        n_blocks, page_size, max_pages = paged
        inputs["block_tables"] = TensorSpec((batch, max_pages), "int32")
        for i in range(cfg.n_layers):
            spec = TensorSpec((n_blocks, page_size, hk, dh), kv_dtype)
            inputs[f"cache_k{i}"] = spec
            inputs[f"cache_v{i}"] = spec
            if kv8:
                sspec = TensorSpec((n_blocks, hk), "float32")
                inputs[f"cache_k{i}_scale"] = sspec
                inputs[f"cache_v{i}_scale"] = sspec

    nodes: List[Node] = [Node("embed_lookup", "embedding",
                              ["tokens", "embed"], ["x0"])]
    if decode:
        nodes.append(Node("kv_len", "add", ["start", "n_new"], ["kvlen"]))
    x = "x0"
    eps = {"eps": cfg.eps}
    for i in range(cfg.n_layers):
        L = f"l{i}"
        nodes += [
            Node(f"{L}.attn_norm", "rmsnorm", [x, f"{L}.norm1"], [f"{L}.h1"], dict(eps)),
            Node(f"{L}.q_proj", "dense", [f"{L}.h1", f"{L}.wq"], [f"{L}.q"]),
            Node(f"{L}.k_proj", "dense", [f"{L}.h1", f"{L}.wk"], [f"{L}.k"]),
            Node(f"{L}.v_proj", "dense", [f"{L}.h1", f"{L}.wv"], [f"{L}.v"]),
            Node(f"{L}.k_heads", "reshape", [f"{L}.k"], [f"{L}.k4"],
                 {"shape": (batch, t, hk, dh)}),
            Node(f"{L}.v_heads", "reshape", [f"{L}.v"], [f"{L}.v4"],
                 {"shape": (batch, t, hk, dh)}),
        ]
        if paged is None:
            nodes += [
                Node(f"{L}.k_write", "cache_update",
                     [f"cache_k{i}", f"{L}.k4", "start", "n_new"],
                     [f"new_cache_k{i}"]),
                Node(f"{L}.v_write", "cache_update",
                     [f"cache_v{i}", f"{L}.v4", "start", "n_new"],
                     [f"new_cache_v{i}"]),
            ]
        elif kv8:
            nodes += [
                Node(f"{L}.k_write", "paged_cache_update_q",
                     [f"cache_k{i}", f"cache_k{i}_scale", f"{L}.k4",
                      "block_tables", "start", "n_new"],
                     [f"new_cache_k{i}", f"new_cache_k{i}_scale"]),
                Node(f"{L}.v_write", "paged_cache_update_q",
                     [f"cache_v{i}", f"cache_v{i}_scale", f"{L}.v4",
                      "block_tables", "start", "n_new"],
                     [f"new_cache_v{i}", f"new_cache_v{i}_scale"]),
            ]
        else:
            nodes += [
                Node(f"{L}.k_write", "paged_cache_update",
                     [f"cache_k{i}", f"{L}.k4", "block_tables", "start", "n_new"],
                     [f"new_cache_k{i}"]),
                Node(f"{L}.v_write", "paged_cache_update",
                     [f"cache_v{i}", f"{L}.v4", "block_tables", "start", "n_new"],
                     [f"new_cache_v{i}"]),
            ]
        if decode:
            nodes.append(Node(f"{L}.q_heads", "reshape", [f"{L}.q"],
                              [f"{L}.qd"], {"shape": (batch, hq, dh)}))
            if paged is None:
                nodes.append(Node(
                    f"{L}.attn", "decode_attention",
                    [f"{L}.qd", f"new_cache_k{i}", f"new_cache_v{i}", "kvlen"],
                    [f"{L}.att"]))
            elif kv8:
                nodes.append(Node(
                    f"{L}.attn", "paged_decode_attention_q",
                    [f"{L}.qd", f"new_cache_k{i}", f"new_cache_k{i}_scale",
                     f"new_cache_v{i}", f"new_cache_v{i}_scale",
                     "block_tables", "kvlen"], [f"{L}.att"]))
            else:
                nodes.append(Node(
                    f"{L}.attn", "paged_decode_attention",
                    [f"{L}.qd", f"new_cache_k{i}", f"new_cache_v{i}",
                     "block_tables", "kvlen"], [f"{L}.att"]))
        else:
            nodes.append(Node(f"{L}.q_heads", "reshape", [f"{L}.q"],
                              [f"{L}.q4"], {"shape": (batch, t, hq, dh)}))
            if paged is None:
                nodes.append(Node(
                    f"{L}.attn", "chunk_attention",
                    [f"{L}.q4", f"new_cache_k{i}", f"new_cache_v{i}", "start"],
                    [f"{L}.att"]))
            elif kv8:
                nodes.append(Node(
                    f"{L}.attn", "paged_chunk_attention_q",
                    [f"{L}.q4", f"new_cache_k{i}", f"new_cache_k{i}_scale",
                     f"new_cache_v{i}", f"new_cache_v{i}_scale",
                     "block_tables", "start"], [f"{L}.att"]))
            else:
                nodes.append(Node(
                    f"{L}.attn", "paged_chunk_attention",
                    [f"{L}.q4", f"new_cache_k{i}", f"new_cache_v{i}",
                     "block_tables", "start"], [f"{L}.att"]))
        nodes += [
            Node(f"{L}.attn_flat", "reshape", [f"{L}.att"], [f"{L}.attn2"],
                 {"shape": (batch, t, hq * dh)}),
            Node(f"{L}.o_proj", "dense", [f"{L}.attn2", f"{L}.wo"], [f"{L}.proj"]),
            Node(f"{L}.attn_res", "add", [x, f"{L}.proj"], [f"{L}.xa"]),
            Node(f"{L}.mlp_norm", "rmsnorm", [f"{L}.xa", f"{L}.norm2"],
                 [f"{L}.h2"], dict(eps)),
            Node(f"{L}.gate_proj", "dense", [f"{L}.h2", f"{L}.wg"], [f"{L}.gate"]),
            Node(f"{L}.up_proj", "dense", [f"{L}.h2", f"{L}.wu"], [f"{L}.up"]),
            Node(f"{L}.swiglu", "swiglu", [f"{L}.gate", f"{L}.up"], [f"{L}.act"]),
            Node(f"{L}.down_proj", "dense", [f"{L}.act", f"{L}.wd"], [f"{L}.down"]),
            Node(f"{L}.mlp_res", "add", [f"{L}.xa", f"{L}.down"], [f"{L}.out"]),
        ]
        x = f"{L}.out"
    nodes.append(Node("final_norm_n", "rmsnorm", [x, "final_norm"],
                      ["final_h"], dict(eps)))
    if decode:
        nodes += [
            Node("lm_head", "dense", ["final_h", "head_w"], ["logits3"]),
            Node("logits_flat", "reshape", ["logits3"], ["logits"],
                 {"shape": (batch, cfg.vocab)}),
        ]
    else:
        nodes.append(Node("lm_head", "dense", ["final_h", "head_w"], ["logits"]))
    outputs = ["logits"]
    for i in range(cfg.n_layers):
        outputs += [f"new_cache_k{i}", f"new_cache_v{i}"]
        if kv8:
            outputs += [f"new_cache_k{i}_scale", f"new_cache_v{i}_scale"]
    mode = "decode" if decode else "prefill"
    tag = ("paged_kv8_" if kv8 else "paged_") if paged is not None else ""
    g = Graph(name=f"graph_lm_{tag}{mode}_b{batch}_t{t}", inputs=inputs,
              outputs=outputs, nodes=nodes, params=dict(params))
    g.validate()
    return g


def build_decode_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                       batch: int, cache_cap: int) -> Graph:
    """One decode step for a fixed batch of slots: tokens (B, 1) + caches
    -> next-token logits (B, V) + updated caches.  ``n_new[b]`` in {0, 1}
    gates the cache write, so idle slots are untouched."""
    return _lm_graph(cfg, params, batch=batch, t=1, cache_cap=cache_cap,
                     decode=True)


def build_prefill_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                        batch: int, chunk: int, cache_cap: int) -> Graph:
    """One prefill chunk: tokens (B, T) at absolute positions
    ``start .. start+n_new-1`` -> per-position logits (B, T, V) + updated
    caches.  Positions >= ``n_new[b]`` are padding (outputs ignored; their
    cache rows are overwritten by the next chunk or the first decode)."""
    return _lm_graph(cfg, params, batch=batch, t=chunk, cache_cap=cache_cap,
                     decode=False)


def build_paged_decode_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                             batch: int, n_blocks: int, page_size: int,
                             max_pages: int,
                             kv_dtype: str = "float32") -> Graph:
    """Paged decode step: the dense caches are replaced by one shared
    page pool per layer (``(n_blocks, page_size, Hk, D)``) plus an int32
    ``block_tables`` input ``(B, max_pages)`` mapping each slot's logical
    page to a physical block.  Every activation value name matches the
    dense variant, so one calibration drives int8 quantization of both
    (the paged ops themselves are not quantized — they move cache rows).

    ``kv_dtype="int8"`` swaps the pools to int8 with per-(page, kv-head)
    float32 scale sidecars (``cache_{k,v}{i}_scale`` inputs ->
    ``new_...`` outputs) and routes writes/attention through the
    ``*_q`` serving ops; activation value names are unchanged, so the
    same calibration still drives these variants."""
    return _lm_graph(cfg, params, batch=batch, t=1,
                     cache_cap=max_pages * page_size, decode=True,
                     paged=(n_blocks, page_size, max_pages),
                     kv_dtype=kv_dtype)


def build_paged_prefill_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                              batch: int, chunk: int, n_blocks: int,
                              page_size: int, max_pages: int,
                              kv_dtype: str = "float32") -> Graph:
    """Paged prefill chunk — see :func:`build_paged_decode_graph` for the
    cache layout (and the ``kv_dtype`` knob); chunk semantics match
    :func:`build_prefill_graph`."""
    return _lm_graph(cfg, params, batch=batch, t=chunk,
                     cache_cap=max_pages * page_size, decode=False,
                     paged=(n_blocks, page_size, max_pages),
                     kv_dtype=kv_dtype)
