"""A decoder-only transformer LM expressed as GraphIR — the serving
engine's model.

Unlike :class:`repro.models.lm.LM` (a Python class over layer functions),
this builder emits flat :class:`~repro.core.ir.Graph` objects, so the
prefill and decode steps go through the full staged compilation pipeline:
``compile(graph, policy=..., quantize=...)`` → :class:`Program`.  That is
the point of the serving engine — backend selection, quantization and the
autotune cache all apply to the serving hot path.

No node pins a backend: every op in these graphs — including the serving
ops ``embedding`` / ``cache_update`` / ``chunk_attention`` /
``decode_attention``, each of which carries ref/xla/pallas alternatives —
resolves through whatever :class:`~repro.core.selector.BackendPolicy` the
caller compiles with, and an :class:`~repro.core.selector.AutotunePolicy`
measures the candidates at the exact batch/chunk/cache-capacity shapes
these builders emit (persisted in the on-disk autotune cache).

State is functional: KV caches are graph *inputs* and *outputs*
(``cache_k{i}`` → ``new_cache_k{i}``), so a Program stays a pure function
and the engine threads cache arrays between calls.

Two graph shapes per model:

* decode:  tokens (B, 1)  — one token per slot, ``decode_attention`` hot op.
* prefill: tokens (B, T)  — one chunk per slot, ``chunk_attention``;
  ``n_new[b] <= T`` marks the valid prefix (0 = slot idle this step), so a
  fixed-shape Program serves ragged chunks and idle slots exactly.

Value names are identical across batch/chunk variants of the same config,
which lets one calibration (``repro.core.quant.calibrate``) drive the
int8 quantization of every variant — the engine's batched Programs and
the unbatched reference then share activation scales and stay token-exact
against each other.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import Graph, Node, TensorSpec

__all__ = ["GraphLMConfig", "init_lm_params", "build_decode_graph",
           "build_prefill_graph", "init_cache_inputs",
           "build_paged_decode_graph", "build_paged_prefill_graph",
           "init_paged_cache_inputs", "build_verify_graph",
           "build_paged_verify_graph", "build_paged_verify_seq_graph",
           "build_spec_commit_graph",
           "build_draft_graph", "expand_spec_ranges", "partition_roles"]


@dataclass(frozen=True)
class GraphLMConfig:
    """Shape of the graph LM.  ``d_head = d_model // n_heads``; GQA when
    ``n_kv_heads < n_heads``."""

    vocab: int = 128
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 128
    eps: float = 1e-6

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def init_lm_params(cfg: GraphLMConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic random weights (numpy, float32), keyed by the value
    names the graph builders reference."""
    rng = np.random.default_rng(seed)

    def dense(din: int, dout: int) -> np.ndarray:
        return (rng.standard_normal((din, dout)) / np.sqrt(din)).astype(np.float32)

    dm, dh = cfg.d_model, cfg.d_head
    p: Dict[str, np.ndarray] = {
        "embed": (rng.standard_normal((cfg.vocab, dm)) * 0.5).astype(np.float32),
        "final_norm": np.ones((dm,), np.float32),
        "head_w": dense(dm, cfg.vocab),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.norm1"] = np.ones((dm,), np.float32)
        p[f"l{i}.wq"] = dense(dm, cfg.n_heads * dh)
        p[f"l{i}.wk"] = dense(dm, cfg.n_kv_heads * dh)
        p[f"l{i}.wv"] = dense(dm, cfg.n_kv_heads * dh)
        p[f"l{i}.wo"] = dense(cfg.n_heads * dh, dm)
        p[f"l{i}.norm2"] = np.ones((dm,), np.float32)
        p[f"l{i}.wg"] = dense(dm, cfg.d_ff)
        p[f"l{i}.wu"] = dense(dm, cfg.d_ff)
        p[f"l{i}.wd"] = dense(cfg.d_ff, dm)
    return p


def init_cache_inputs(cfg: GraphLMConfig, batch: int,
                      cache_cap: int) -> Dict[str, np.ndarray]:
    """Zeroed cache arrays matching the graph's cache input names."""
    shape = (batch, cache_cap, cfg.n_kv_heads, cfg.d_head)
    out: Dict[str, np.ndarray] = {}
    for i in range(cfg.n_layers):
        out[f"cache_k{i}"] = np.zeros(shape, np.float32)
        out[f"cache_v{i}"] = np.zeros(shape, np.float32)
    return out


def init_paged_cache_inputs(cfg: GraphLMConfig, n_blocks: int,
                            page_size: int, *,
                            kv_dtype: str = "float32") -> Dict[str, np.ndarray]:
    """Zeroed page-pool arrays matching the paged graphs' cache input
    names.  Unlike the dense layout there is no batch dimension — one
    shared pool of ``n_blocks`` fixed-size pages per layer, indexed
    through per-sequence block tables.  With ``kv_dtype="int8"`` the
    pools are int8 and each gains a ``cache_{k,v}{i}_scale`` sidecar
    ((n_blocks, Hk) float32, all zeros = every page empty)."""
    if kv_dtype not in ("float32", "int8"):
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
    shape = (n_blocks, page_size, cfg.n_kv_heads, cfg.d_head)
    dt = np.int8 if kv_dtype == "int8" else np.float32
    out: Dict[str, np.ndarray] = {}
    for i in range(cfg.n_layers):
        out[f"cache_k{i}"] = np.zeros(shape, dt)
        out[f"cache_v{i}"] = np.zeros(shape, dt)
        if kv_dtype == "int8":
            sshape = (n_blocks, cfg.n_kv_heads)
            out[f"cache_k{i}_scale"] = np.zeros(sshape, np.float32)
            out[f"cache_v{i}_scale"] = np.zeros(sshape, np.float32)
    return out


def _lm_graph(cfg: GraphLMConfig, params: Dict[str, Any], *, batch: int,
              t: int, cache_cap: int, decode: bool, verify: bool = False,
              paged: Optional[Tuple[int, int, int]] = None,
              kv_dtype: str = "float32") -> Graph:
    if t > cache_cap:
        raise ValueError(f"chunk {t} exceeds cache capacity {cache_cap}")
    if kv_dtype not in ("float32", "int8"):
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
    kv8 = kv_dtype == "int8"
    if kv8 and paged is None:
        raise ValueError("kv_dtype='int8' requires the paged cache layout")
    dm, dh, hq, hk = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    inputs: Dict[str, TensorSpec] = {
        "tokens": TensorSpec((batch, t), "int32"),
        "start": TensorSpec((batch,), "int32"),
        "n_new": TensorSpec((batch,), "int32"),
    }
    if paged is None:
        for i in range(cfg.n_layers):
            spec = TensorSpec((batch, cache_cap, hk, dh), "float32")
            inputs[f"cache_k{i}"] = spec
            inputs[f"cache_v{i}"] = spec
    else:
        n_blocks, page_size, max_pages = paged
        inputs["block_tables"] = TensorSpec((batch, max_pages), "int32")
        for i in range(cfg.n_layers):
            spec = TensorSpec((n_blocks, page_size, hk, dh), kv_dtype)
            inputs[f"cache_k{i}"] = spec
            inputs[f"cache_v{i}"] = spec
            if kv8:
                sspec = TensorSpec((n_blocks, hk), "float32")
                inputs[f"cache_k{i}_scale"] = sspec
                inputs[f"cache_v{i}_scale"] = sspec

    nodes: List[Node] = [Node("embed_lookup", "embedding",
                              ["tokens", "embed"], ["x0"])]
    if decode:
        nodes.append(Node("kv_len", "add", ["start", "n_new"], ["kvlen"]))
    x = "x0"
    eps = {"eps": cfg.eps}
    for i in range(cfg.n_layers):
        L = f"l{i}"
        nodes += [
            Node(f"{L}.attn_norm", "rmsnorm", [x, f"{L}.norm1"], [f"{L}.h1"], dict(eps)),
            Node(f"{L}.q_proj", "dense", [f"{L}.h1", f"{L}.wq"], [f"{L}.q"]),
            Node(f"{L}.k_proj", "dense", [f"{L}.h1", f"{L}.wk"], [f"{L}.k"]),
            Node(f"{L}.v_proj", "dense", [f"{L}.h1", f"{L}.wv"], [f"{L}.v"]),
            Node(f"{L}.k_heads", "reshape", [f"{L}.k"], [f"{L}.k4"],
                 {"shape": (batch, t, hk, dh)}),
            Node(f"{L}.v_heads", "reshape", [f"{L}.v"], [f"{L}.v4"],
                 {"shape": (batch, t, hk, dh)}),
        ]
        if paged is None:
            nodes += [
                Node(f"{L}.k_write", "cache_update",
                     [f"cache_k{i}", f"{L}.k4", "start", "n_new"],
                     [f"new_cache_k{i}"]),
                Node(f"{L}.v_write", "cache_update",
                     [f"cache_v{i}", f"{L}.v4", "start", "n_new"],
                     [f"new_cache_v{i}"]),
            ]
        elif kv8:
            # kv8 VERIFY never writes pages: quantize-on-write scales only
            # grow, and a raise lossily requantizes the whole page, so a
            # rejected draft row would permanently perturb committed rows
            # sharing its page.  Attention reads the new rows from the
            # fp32 k4/v4 instead (two-source) and accepted rows commit via
            # the separate spec-commit Program.
            if not verify:
                nodes += [
                    Node(f"{L}.k_write", "paged_cache_update_q",
                         [f"cache_k{i}", f"cache_k{i}_scale", f"{L}.k4",
                          "block_tables", "start", "n_new"],
                         [f"new_cache_k{i}", f"new_cache_k{i}_scale"]),
                    Node(f"{L}.v_write", "paged_cache_update_q",
                         [f"cache_v{i}", f"cache_v{i}_scale", f"{L}.v4",
                          "block_tables", "start", "n_new"],
                         [f"new_cache_v{i}", f"new_cache_v{i}_scale"]),
                ]
        else:
            nodes += [
                Node(f"{L}.k_write", "paged_cache_update",
                     [f"cache_k{i}", f"{L}.k4", "block_tables", "start", "n_new"],
                     [f"new_cache_k{i}"]),
                Node(f"{L}.v_write", "paged_cache_update",
                     [f"cache_v{i}", f"{L}.v4", "block_tables", "start", "n_new"],
                     [f"new_cache_v{i}"]),
            ]
        if decode:
            nodes.append(Node(f"{L}.q_heads", "reshape", [f"{L}.q"],
                              [f"{L}.qd"], {"shape": (batch, hq, dh)}))
            if paged is None:
                nodes.append(Node(
                    f"{L}.attn", "decode_attention",
                    [f"{L}.qd", f"new_cache_k{i}", f"new_cache_v{i}", "kvlen"],
                    [f"{L}.att"]))
            elif kv8:
                nodes.append(Node(
                    f"{L}.attn", "paged_decode_attention_q",
                    [f"{L}.qd", f"new_cache_k{i}", f"new_cache_k{i}_scale",
                     f"new_cache_v{i}", f"new_cache_v{i}_scale",
                     "block_tables", "kvlen"], [f"{L}.att"]))
            else:
                nodes.append(Node(
                    f"{L}.attn", "paged_decode_attention",
                    [f"{L}.qd", f"new_cache_k{i}", f"new_cache_v{i}",
                     "block_tables", "kvlen"], [f"{L}.att"]))
        else:
            nodes.append(Node(f"{L}.q_heads", "reshape", [f"{L}.q"],
                              [f"{L}.q4"], {"shape": (batch, t, hq, dh)}))
            # a verify step IS a prefill chunk of T = K+1 rows, but it runs
            # through the verify_attention op family so the selector can
            # pick a backend for the verify shape independently; value
            # names stay identical to the prefill variant, so one
            # calibration drives both
            if paged is None:
                op = "verify_attention" if verify else "chunk_attention"
                nodes.append(Node(
                    f"{L}.attn", op,
                    [f"{L}.q4", f"new_cache_k{i}", f"new_cache_v{i}", "start"],
                    [f"{L}.att"]))
            elif kv8:
                if verify:
                    nodes.append(Node(
                        f"{L}.attn", "paged_verify_attention_q",
                        [f"{L}.q4", f"cache_k{i}", f"cache_k{i}_scale",
                         f"cache_v{i}", f"cache_v{i}_scale",
                         "block_tables", "start", f"{L}.k4", f"{L}.v4"],
                        [f"{L}.att"]))
                else:
                    nodes.append(Node(
                        f"{L}.attn", "paged_chunk_attention_q",
                        [f"{L}.q4", f"new_cache_k{i}",
                         f"new_cache_k{i}_scale", f"new_cache_v{i}",
                         f"new_cache_v{i}_scale", "block_tables", "start"],
                        [f"{L}.att"]))
            else:
                op = ("paged_verify_attention" if verify
                      else "paged_chunk_attention")
                nodes.append(Node(
                    f"{L}.attn", op,
                    [f"{L}.q4", f"new_cache_k{i}", f"new_cache_v{i}",
                     "block_tables", "start"], [f"{L}.att"]))
        nodes += [
            Node(f"{L}.attn_flat", "reshape", [f"{L}.att"], [f"{L}.attn2"],
                 {"shape": (batch, t, hq * dh)}),
            Node(f"{L}.o_proj", "dense", [f"{L}.attn2", f"{L}.wo"], [f"{L}.proj"]),
            Node(f"{L}.attn_res", "add", [x, f"{L}.proj"], [f"{L}.xa"]),
            Node(f"{L}.mlp_norm", "rmsnorm", [f"{L}.xa", f"{L}.norm2"],
                 [f"{L}.h2"], dict(eps)),
            Node(f"{L}.gate_proj", "dense", [f"{L}.h2", f"{L}.wg"], [f"{L}.gate"]),
            Node(f"{L}.up_proj", "dense", [f"{L}.h2", f"{L}.wu"], [f"{L}.up"]),
            Node(f"{L}.swiglu", "swiglu", [f"{L}.gate", f"{L}.up"], [f"{L}.act"]),
            Node(f"{L}.down_proj", "dense", [f"{L}.act", f"{L}.wd"], [f"{L}.down"]),
            Node(f"{L}.mlp_res", "add", [f"{L}.xa", f"{L}.down"], [f"{L}.out"]),
        ]
        x = f"{L}.out"
    nodes.append(Node("final_norm_n", "rmsnorm", [x, "final_norm"],
                      ["final_h"], dict(eps)))
    if decode:
        nodes += [
            Node("lm_head", "dense", ["final_h", "head_w"], ["logits3"]),
            Node("logits_flat", "reshape", ["logits3"], ["logits"],
                 {"shape": (batch, cfg.vocab)}),
        ]
    else:
        nodes.append(Node("lm_head", "dense", ["final_h", "head_w"], ["logits"]))
    outputs = ["logits"]
    if kv8 and verify:
        # no page writes happened; hand the fp32 K/V rows of this call's
        # speculative chunk back to the engine for the post-acceptance
        # spec-commit write
        for i in range(cfg.n_layers):
            outputs += [f"l{i}.k4", f"l{i}.v4"]
    else:
        for i in range(cfg.n_layers):
            outputs += [f"new_cache_k{i}", f"new_cache_v{i}"]
            if kv8:
                outputs += [f"new_cache_k{i}_scale",
                            f"new_cache_v{i}_scale"]
    mode = "decode" if decode else ("verify" if verify else "prefill")
    tag = ("paged_kv8_" if kv8 else "paged_") if paged is not None else ""
    g = Graph(name=f"graph_lm_{tag}{mode}_b{batch}_t{t}", inputs=inputs,
              outputs=outputs, nodes=nodes, params=dict(params))
    g.validate()
    return g


def build_decode_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                       batch: int, cache_cap: int) -> Graph:
    """One decode step for a fixed batch of slots: tokens (B, 1) + caches
    -> next-token logits (B, V) + updated caches.  ``n_new[b]`` in {0, 1}
    gates the cache write, so idle slots are untouched."""
    return _lm_graph(cfg, params, batch=batch, t=1, cache_cap=cache_cap,
                     decode=True)


def build_prefill_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                        batch: int, chunk: int, cache_cap: int) -> Graph:
    """One prefill chunk: tokens (B, T) at absolute positions
    ``start .. start+n_new-1`` -> per-position logits (B, T, V) + updated
    caches.  Positions >= ``n_new[b]`` are padding (outputs ignored; their
    cache rows are overwritten by the next chunk or the first decode)."""
    return _lm_graph(cfg, params, batch=batch, t=chunk, cache_cap=cache_cap,
                     decode=False)


def build_paged_decode_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                             batch: int, n_blocks: int, page_size: int,
                             max_pages: int,
                             kv_dtype: str = "float32") -> Graph:
    """Paged decode step: the dense caches are replaced by one shared
    page pool per layer (``(n_blocks, page_size, Hk, D)``) plus an int32
    ``block_tables`` input ``(B, max_pages)`` mapping each slot's logical
    page to a physical block.  Every activation value name matches the
    dense variant, so one calibration drives int8 quantization of both
    (the paged ops themselves are not quantized — they move cache rows).

    ``kv_dtype="int8"`` swaps the pools to int8 with per-(page, kv-head)
    float32 scale sidecars (``cache_{k,v}{i}_scale`` inputs ->
    ``new_...`` outputs) and routes writes/attention through the
    ``*_q`` serving ops; activation value names are unchanged, so the
    same calibration still drives these variants."""
    return _lm_graph(cfg, params, batch=batch, t=1,
                     cache_cap=max_pages * page_size, decode=True,
                     paged=(n_blocks, page_size, max_pages),
                     kv_dtype=kv_dtype)


def build_paged_prefill_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                              batch: int, chunk: int, n_blocks: int,
                              page_size: int, max_pages: int,
                              kv_dtype: str = "float32") -> Graph:
    """Paged prefill chunk — see :func:`build_paged_decode_graph` for the
    cache layout (and the ``kv_dtype`` knob); chunk semantics match
    :func:`build_prefill_graph`."""
    return _lm_graph(cfg, params, batch=batch, t=chunk,
                     cache_cap=max_pages * page_size, decode=False,
                     paged=(n_blocks, page_size, max_pages),
                     kv_dtype=kv_dtype)


def build_verify_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                       batch: int, width: int, cache_cap: int) -> Graph:
    """Speculative-verify step: tokens (B, width) — the committed next
    token plus up to ``width - 1`` draft proposals per slot — scored
    against the dense cache in one call, returning per-position logits
    (B, width, V).  Structurally a prefill chunk of T = ``width`` rows
    (``n_new[b] <= width`` marks the valid prefix, 0 = idle), but the
    attention runs through ``verify_attention`` so backend selection for
    the verify shape is independent of the prefill chunk.  Value names
    match the prefill variant exactly — one calibration drives both, which
    is what keeps int8 speculative decode token-exact."""
    return _lm_graph(cfg, params, batch=batch, t=width, cache_cap=cache_cap,
                     decode=False, verify=True)


def build_paged_verify_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                             batch: int, width: int, n_blocks: int,
                             page_size: int, max_pages: int,
                             kv_dtype: str = "float32") -> Graph:
    """Paged speculative-verify step — see :func:`build_verify_graph`;
    cache layout and ``kv_dtype`` as in :func:`build_paged_decode_graph`
    (``paged_verify_attention`` / ``paged_verify_attention_q``)."""
    return _lm_graph(cfg, params, batch=batch, t=width,
                     cache_cap=max_pages * page_size, decode=False,
                     verify=True, paged=(n_blocks, page_size, max_pages),
                     kv_dtype=kv_dtype)


def build_paged_verify_seq_graph(cfg: GraphLMConfig, params: Dict[str, Any],
                                 *, batch: int, width: int, n_blocks: int,
                                 page_size: int, max_pages: int) -> Graph:
    """The kv8 engine's verify Program: ``width`` single-row decode stages
    unrolled into ONE graph, threading the int8 page state stage to stage.

    Why not the chunk-shaped :func:`build_paged_verify_graph` here?
    Quantize-on-write makes int8 page bytes HISTORY-dependent (scales
    ratchet up; a raise requantizes the page), so a batched verify cannot
    reproduce plain decode's numerics bit-for-bit — and near-tied argmax
    rows would then flip tokens vs a non-speculative run.  This variant
    IS plain decode, stage by stage: stage ``j`` embeds its own token
    input (``tokens.s{j}``), quantize-writes that row in-graph, and runs
    ``paged_decode_attention_q`` at exactly the decode shapes — so every
    stage's logits are bit-identical to the decode Program at the same
    position, dispatched once instead of ``width`` times.  The threaded
    page state is DISCARDED (it includes later-rejected rows); instead
    each stage's fp32 ``k4``/``v4`` rows are returned so the spec-commit
    replay (:func:`build_spec_commit_graph`) can rebuild the accepted
    prefix of the very same write sequence against the live pages.

    Stage masks ``n_new.s{j}`` are 1 while ``j`` is inside the slot's fed
    width, else 0 (idle stage: no write, garbage logits, ignored); the
    ``spec.one`` ones-vector param advances ``start`` in-graph.

    Outputs: ``logits.s0 .. logits.s{width-1}`` then per stage, per
    layer, the fp32 ``l{i}.k4.s{j}`` / ``l{i}.v4.s{j}`` rows."""
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    dm, dh, hq, hk = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    inputs: Dict[str, TensorSpec] = {
        "start": TensorSpec((batch,), "int32"),
        "block_tables": TensorSpec((batch, max_pages), "int32"),
    }
    for j in range(width):
        inputs[f"tokens.s{j}"] = TensorSpec((batch, 1), "int32")
        inputs[f"n_new.s{j}"] = TensorSpec((batch,), "int32")
    for i in range(cfg.n_layers):
        spec = TensorSpec((n_blocks, page_size, hk, dh), "int8")
        sspec = TensorSpec((n_blocks, hk), "float32")
        inputs[f"cache_k{i}"] = spec
        inputs[f"cache_v{i}"] = spec
        inputs[f"cache_k{i}_scale"] = sspec
        inputs[f"cache_v{i}_scale"] = sspec
    p = dict(params)
    p["spec.one"] = np.ones((batch,), np.int32)
    nodes: List[Node] = []
    eps = {"eps": cfg.eps}
    for j in range(width):
        sfx = f".s{j}"
        last = j == width - 1
        if j == 0:
            start_name = "start"
        else:
            start_name = f"start{sfx}"
            prev = "start" if j == 1 else f"start.s{j - 1}"
            nodes.append(Node(f"step_pos{sfx}", "add", [prev, "spec.one"],
                              [start_name]))
        nodes += [
            Node(f"embed_lookup{sfx}", "embedding",
                 [f"tokens{sfx}", "embed"], [f"x0{sfx}"]),
            Node(f"kv_len{sfx}", "add", [start_name, f"n_new{sfx}"],
                 [f"kvlen{sfx}"]),
        ]
        x = f"x0{sfx}"
        for i in range(cfg.n_layers):
            L = f"l{i}"
            ck_in = f"cache_k{i}" if j == 0 else f"cache_k{i}{sfx}"
            cv_in = f"cache_v{i}" if j == 0 else f"cache_v{i}{sfx}"
            cks_in = (f"cache_k{i}_scale" if j == 0
                      else f"cache_k{i}_scale{sfx}")
            cvs_in = (f"cache_v{i}_scale" if j == 0
                      else f"cache_v{i}_scale{sfx}")
            ck_out = f"cache_k{i}.sout{j}" if last else f"cache_k{i}.s{j + 1}"
            cv_out = f"cache_v{i}.sout{j}" if last else f"cache_v{i}.s{j + 1}"
            cks_out = (f"cache_k{i}_scale.sout{j}" if last
                       else f"cache_k{i}_scale.s{j + 1}")
            cvs_out = (f"cache_v{i}_scale.sout{j}" if last
                       else f"cache_v{i}_scale.s{j + 1}")
            nodes += [
                Node(f"{L}.attn_norm{sfx}", "rmsnorm", [x, f"{L}.norm1"],
                     [f"{L}.h1{sfx}"], dict(eps)),
                Node(f"{L}.q_proj{sfx}", "dense", [f"{L}.h1{sfx}", f"{L}.wq"],
                     [f"{L}.q{sfx}"]),
                Node(f"{L}.k_proj{sfx}", "dense", [f"{L}.h1{sfx}", f"{L}.wk"],
                     [f"{L}.k{sfx}"]),
                Node(f"{L}.v_proj{sfx}", "dense", [f"{L}.h1{sfx}", f"{L}.wv"],
                     [f"{L}.v{sfx}"]),
                Node(f"{L}.k_heads{sfx}", "reshape", [f"{L}.k{sfx}"],
                     [f"{L}.k4{sfx}"], {"shape": (batch, 1, hk, dh)}),
                Node(f"{L}.v_heads{sfx}", "reshape", [f"{L}.v{sfx}"],
                     [f"{L}.v4{sfx}"], {"shape": (batch, 1, hk, dh)}),
                Node(f"{L}.k_write{sfx}", "paged_cache_update_q",
                     [ck_in, cks_in, f"{L}.k4{sfx}", "block_tables",
                      start_name, f"n_new{sfx}"], [ck_out, cks_out]),
                Node(f"{L}.v_write{sfx}", "paged_cache_update_q",
                     [cv_in, cvs_in, f"{L}.v4{sfx}", "block_tables",
                      start_name, f"n_new{sfx}"], [cv_out, cvs_out]),
                Node(f"{L}.q_heads{sfx}", "reshape", [f"{L}.q{sfx}"],
                     [f"{L}.qd{sfx}"], {"shape": (batch, hq, dh)}),
                Node(f"{L}.attn{sfx}", "paged_decode_attention_q",
                     [f"{L}.qd{sfx}", ck_out, cks_out, cv_out, cvs_out,
                      "block_tables", f"kvlen{sfx}"], [f"{L}.att{sfx}"]),
                Node(f"{L}.attn_flat{sfx}", "reshape", [f"{L}.att{sfx}"],
                     [f"{L}.attn2{sfx}"], {"shape": (batch, 1, hq * dh)}),
                Node(f"{L}.o_proj{sfx}", "dense",
                     [f"{L}.attn2{sfx}", f"{L}.wo"], [f"{L}.proj{sfx}"]),
                Node(f"{L}.attn_res{sfx}", "add", [x, f"{L}.proj{sfx}"],
                     [f"{L}.xa{sfx}"]),
                Node(f"{L}.mlp_norm{sfx}", "rmsnorm",
                     [f"{L}.xa{sfx}", f"{L}.norm2"], [f"{L}.h2{sfx}"],
                     dict(eps)),
                Node(f"{L}.gate_proj{sfx}", "dense",
                     [f"{L}.h2{sfx}", f"{L}.wg"], [f"{L}.gate{sfx}"]),
                Node(f"{L}.up_proj{sfx}", "dense",
                     [f"{L}.h2{sfx}", f"{L}.wu"], [f"{L}.up{sfx}"]),
                Node(f"{L}.swiglu{sfx}", "swiglu",
                     [f"{L}.gate{sfx}", f"{L}.up{sfx}"], [f"{L}.act{sfx}"]),
                Node(f"{L}.down_proj{sfx}", "dense",
                     [f"{L}.act{sfx}", f"{L}.wd"], [f"{L}.down{sfx}"]),
                Node(f"{L}.mlp_res{sfx}", "add",
                     [f"{L}.xa{sfx}", f"{L}.down{sfx}"], [f"{L}.out{sfx}"]),
            ]
            x = f"{L}.out{sfx}"
        nodes += [
            Node(f"final_norm_n{sfx}", "rmsnorm", [x, "final_norm"],
                 [f"final_h{sfx}"], dict(eps)),
            Node(f"lm_head{sfx}", "dense", [f"final_h{sfx}", "head_w"],
                 [f"logits3{sfx}"]),
            Node(f"logits_flat{sfx}", "reshape", [f"logits3{sfx}"],
                 [f"logits{sfx}"], {"shape": (batch, cfg.vocab)}),
        ]
    outputs = [f"logits.s{j}" for j in range(width)]
    for j in range(width):
        for i in range(cfg.n_layers):
            outputs += [f"l{i}.k4.s{j}", f"l{i}.v4.s{j}"]
    g = Graph(name=f"graph_lm_paged_kv8_verify_seq_b{batch}_t{width}",
              inputs=inputs, outputs=outputs, nodes=nodes, params=p)
    g.validate()
    return g


def build_spec_commit_graph(cfg: GraphLMConfig, *, batch: int, width: int,
                            n_blocks: int, page_size: int,
                            max_pages: int) -> Graph:
    """The kv8 spec-commit step: REPLAY the accepted prefix of the verify
    call's write sequence against the live int8 pages.

    The kv8 verify (:func:`build_paged_verify_seq_graph`) threads its
    quantize-on-write page state internally but that state includes
    later-rejected rows (whose scale raises would lossily requantize
    committed neighbours), so the engine discards it.  This graph takes
    the verify call's per-stage fp32 rows back (``k_new{i}.s{j}``,
    (B, 1, Hk, D)) and re-applies the SAME single-row
    ``paged_cache_update_q`` writes in the SAME order, with stage masks
    ``n_new.s{j}`` zeroed from the first rejected stage on — determinism
    makes the replayed page states bit-identical to the ones the verify
    attention actually read, which in turn are bit-identical to plain
    decode's write history.  No model weights; just the write chain."""
    hk, dh = cfg.n_kv_heads, cfg.d_head
    inputs: Dict[str, TensorSpec] = {
        "start": TensorSpec((batch,), "int32"),
        "block_tables": TensorSpec((batch, max_pages), "int32"),
    }
    for j in range(width):
        inputs[f"n_new.s{j}"] = TensorSpec((batch,), "int32")
        for i in range(cfg.n_layers):
            inputs[f"k_new{i}.s{j}"] = TensorSpec((batch, 1, hk, dh),
                                                  "float32")
            inputs[f"v_new{i}.s{j}"] = TensorSpec((batch, 1, hk, dh),
                                                  "float32")
    for i in range(cfg.n_layers):
        inputs[f"cache_k{i}"] = TensorSpec((n_blocks, page_size, hk, dh),
                                           "int8")
        inputs[f"cache_v{i}"] = TensorSpec((n_blocks, page_size, hk, dh),
                                           "int8")
        inputs[f"cache_k{i}_scale"] = TensorSpec((n_blocks, hk), "float32")
        inputs[f"cache_v{i}_scale"] = TensorSpec((n_blocks, hk), "float32")
    p = {"spec.one": np.ones((batch,), np.int32)}
    nodes: List[Node] = []
    for j in range(width):
        sfx = f".s{j}"
        last = j == width - 1
        if j == 0:
            start_name = "start"
        else:
            start_name = f"start{sfx}"
            prev = "start" if j == 1 else f"start.s{j - 1}"
            nodes.append(Node(f"step_pos{sfx}", "add", [prev, "spec.one"],
                              [start_name]))
        for i in range(cfg.n_layers):
            ck_in = f"cache_k{i}" if j == 0 else f"cache_k{i}{sfx}"
            cv_in = f"cache_v{i}" if j == 0 else f"cache_v{i}{sfx}"
            cks_in = (f"cache_k{i}_scale" if j == 0
                      else f"cache_k{i}_scale{sfx}")
            cvs_in = (f"cache_v{i}_scale" if j == 0
                      else f"cache_v{i}_scale{sfx}")
            ck_out = f"new_cache_k{i}" if last else f"cache_k{i}.s{j + 1}"
            cv_out = f"new_cache_v{i}" if last else f"cache_v{i}.s{j + 1}"
            cks_out = (f"new_cache_k{i}_scale" if last
                       else f"cache_k{i}_scale.s{j + 1}")
            cvs_out = (f"new_cache_v{i}_scale" if last
                       else f"cache_v{i}_scale.s{j + 1}")
            nodes += [
                Node(f"l{i}.k_commit{sfx}", "paged_cache_update_q",
                     [ck_in, cks_in, f"k_new{i}{sfx}", "block_tables",
                      start_name, f"n_new{sfx}"], [ck_out, cks_out]),
                Node(f"l{i}.v_commit{sfx}", "paged_cache_update_q",
                     [cv_in, cvs_in, f"v_new{i}{sfx}", "block_tables",
                      start_name, f"n_new{sfx}"], [cv_out, cvs_out]),
            ]
    outputs: List[str] = []
    for i in range(cfg.n_layers):
        outputs += [f"new_cache_k{i}", f"new_cache_v{i}",
                    f"new_cache_k{i}_scale", f"new_cache_v{i}_scale"]
    g = Graph(name=f"graph_lm_spec_commit_b{batch}_t{width}", inputs=inputs,
              outputs=outputs, nodes=nodes, params=p)
    g.validate()
    return g


def build_draft_graph(cfg: GraphLMConfig, params: Dict[str, Any], *,
                      batch: int, cache_cap: int, spec_k: int) -> Graph:
    """The draft Program: ``spec_k`` autoregressive greedy steps unrolled
    into ONE graph, plus a final cache-write-only step.

    At serving scale the draft model is dispatch-dominated, so K separate
    decode calls would eat the speculation win; instead the greedy
    feedback loop runs in-graph via the ``greedy_token`` op.  Step ``s``
    embeds its input token (step 0: the ``tokens`` input — the committed
    next token; step s>0: step s-1's ``draft_tok``), runs the decoder over
    the step's dense caches, and emits ``draft_tok.s{s}``.  Position
    arithmetic is in-graph too: a ``spec.one`` ones-vector param advances
    ``start`` / ``kvlen`` per step, so the host passes the same
    (tokens, start, n_new) triple as a plain decode call.

    The final step (``s == spec_k``) writes its input token's cache row
    but computes no logits: after a full accept the draft cache then
    already holds every committed row, so the next draft call needs no
    catch-up.  Rows written for later-rejected proposals are simply
    overwritten by the next call — the draft caches are private per-slot
    dense buffers (capacity ``cache_cap`` = committed cap + spec_k + 1)
    and never roll back.

    Value names carry a ``.s{s}`` suffix; :func:`expand_spec_ranges` maps
    a shared calibration onto them so the draft quantizes with the same
    static activation scales as every other variant.

    Outputs: ``draft_tok.s0 .. draft_tok.s{spec_k-1}`` then the usual
    ``new_cache_k{i}`` / ``new_cache_v{i}`` (from the final step)."""
    if spec_k < 1:
        raise ValueError(f"spec_k must be >= 1, got {spec_k}")
    if spec_k + 1 > cache_cap:
        raise ValueError(f"spec_k {spec_k} + 1 exceeds cache cap {cache_cap}")
    dm, dh, hq, hk = cfg.d_model, cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    inputs: Dict[str, TensorSpec] = {
        "tokens": TensorSpec((batch, 1), "int32"),
        "start": TensorSpec((batch,), "int32"),
        "n_new": TensorSpec((batch,), "int32"),
    }
    for i in range(cfg.n_layers):
        spec = TensorSpec((batch, cache_cap, hk, dh), "float32")
        inputs[f"cache_k{i}"] = spec
        inputs[f"cache_v{i}"] = spec
    p = dict(params)
    p["spec.one"] = np.ones((batch,), np.int32)
    nodes: List[Node] = []
    eps = {"eps": cfg.eps}
    for s in range(spec_k + 1):
        sfx = f".s{s}"
        last = s == spec_k
        tok = "tokens" if s == 0 else f"draft_tok.s{s - 1}"
        if s == 0:
            start_name = "start"
        else:
            start_name = f"start{sfx}"
            prev = "start" if s == 1 else f"start.s{s - 1}"
            nodes.append(Node(f"step_pos{sfx}", "add", [prev, "spec.one"],
                              [start_name]))
        nodes += [
            Node(f"embed_lookup{sfx}", "embedding", [tok, "embed"],
                 [f"x0{sfx}"]),
            Node(f"kv_len{sfx}", "add", [start_name, "n_new"],
                 [f"kvlen{sfx}"]),
        ]
        x = f"x0{sfx}"
        for i in range(cfg.n_layers):
            L = f"l{i}"
            ck_in = f"cache_k{i}" if s == 0 else f"cache_k{i}{sfx}"
            cv_in = f"cache_v{i}" if s == 0 else f"cache_v{i}{sfx}"
            ck_out = f"new_cache_k{i}" if last else f"cache_k{i}.s{s + 1}"
            cv_out = f"new_cache_v{i}" if last else f"cache_v{i}.s{s + 1}"
            nodes += [
                Node(f"{L}.attn_norm{sfx}", "rmsnorm", [x, f"{L}.norm1"],
                     [f"{L}.h1{sfx}"], dict(eps)),
                Node(f"{L}.q_proj{sfx}", "dense", [f"{L}.h1{sfx}", f"{L}.wq"],
                     [f"{L}.q{sfx}"]),
                Node(f"{L}.k_proj{sfx}", "dense", [f"{L}.h1{sfx}", f"{L}.wk"],
                     [f"{L}.k{sfx}"]),
                Node(f"{L}.v_proj{sfx}", "dense", [f"{L}.h1{sfx}", f"{L}.wv"],
                     [f"{L}.v{sfx}"]),
                Node(f"{L}.k_heads{sfx}", "reshape", [f"{L}.k{sfx}"],
                     [f"{L}.k4{sfx}"], {"shape": (batch, 1, hk, dh)}),
                Node(f"{L}.v_heads{sfx}", "reshape", [f"{L}.v{sfx}"],
                     [f"{L}.v4{sfx}"], {"shape": (batch, 1, hk, dh)}),
                Node(f"{L}.k_write{sfx}", "cache_update",
                     [ck_in, f"{L}.k4{sfx}", start_name, "n_new"], [ck_out]),
                Node(f"{L}.v_write{sfx}", "cache_update",
                     [cv_in, f"{L}.v4{sfx}", start_name, "n_new"], [cv_out]),
                Node(f"{L}.q_heads{sfx}", "reshape", [f"{L}.q{sfx}"],
                     [f"{L}.qd{sfx}"], {"shape": (batch, hq, dh)}),
                Node(f"{L}.attn{sfx}", "decode_attention",
                     [f"{L}.qd{sfx}", ck_out, cv_out, f"kvlen{sfx}"],
                     [f"{L}.att{sfx}"]),
                Node(f"{L}.attn_flat{sfx}", "reshape", [f"{L}.att{sfx}"],
                     [f"{L}.attn2{sfx}"], {"shape": (batch, 1, hq * dh)}),
                Node(f"{L}.o_proj{sfx}", "dense",
                     [f"{L}.attn2{sfx}", f"{L}.wo"], [f"{L}.proj{sfx}"]),
                Node(f"{L}.attn_res{sfx}", "add", [x, f"{L}.proj{sfx}"],
                     [f"{L}.xa{sfx}"]),
                Node(f"{L}.mlp_norm{sfx}", "rmsnorm",
                     [f"{L}.xa{sfx}", f"{L}.norm2"], [f"{L}.h2{sfx}"],
                     dict(eps)),
                Node(f"{L}.gate_proj{sfx}", "dense",
                     [f"{L}.h2{sfx}", f"{L}.wg"], [f"{L}.gate{sfx}"]),
                Node(f"{L}.up_proj{sfx}", "dense",
                     [f"{L}.h2{sfx}", f"{L}.wu"], [f"{L}.up{sfx}"]),
                Node(f"{L}.swiglu{sfx}", "swiglu",
                     [f"{L}.gate{sfx}", f"{L}.up{sfx}"], [f"{L}.act{sfx}"]),
                Node(f"{L}.down_proj{sfx}", "dense",
                     [f"{L}.act{sfx}", f"{L}.wd"], [f"{L}.down{sfx}"]),
                Node(f"{L}.mlp_res{sfx}", "add",
                     [f"{L}.xa{sfx}", f"{L}.down{sfx}"], [f"{L}.out{sfx}"]),
            ]
            x = f"{L}.out{sfx}"
        if not last:
            nodes += [
                Node(f"final_norm_n{sfx}", "rmsnorm", [x, "final_norm"],
                     [f"final_h{sfx}"], dict(eps)),
                Node(f"lm_head{sfx}", "dense", [f"final_h{sfx}", "head_w"],
                     [f"logits3{sfx}"]),
                Node(f"logits_flat{sfx}", "reshape", [f"logits3{sfx}"],
                     [f"logits{sfx}"], {"shape": (batch, cfg.vocab)}),
                Node(f"greedy{sfx}", "greedy_token", [f"logits{sfx}"],
                     [f"draft_tok{sfx}"]),
            ]
    outputs = [f"draft_tok.s{s}" for s in range(spec_k)]
    for i in range(cfg.n_layers):
        outputs += [f"new_cache_k{i}", f"new_cache_v{i}"]
    g = Graph(name=f"graph_lm_draft_b{batch}_k{spec_k}", inputs=inputs,
              outputs=outputs, nodes=nodes, params=p)
    g.validate()
    return g


def expand_spec_ranges(ranges: Dict[str, Any], spec_k: int) -> Dict[str, Any]:
    """Map a shared calibration onto the draft graph's step-suffixed value
    names: every base-name range is copied to ``<name>.s{0..spec_k}``.
    The draft's layers are a prefix of the target's, and its per-step
    activations are the same values the decode variant sees — so the
    expanded ranges give the quantized draft the same static activation
    scales as every other Program variant (names that stay unmatched fall
    back to the quantizer's dynamic per-batch scales, which is safe for
    the draft: its proposals are *checked*, never trusted)."""
    out = dict(ranges)
    for name, vr in ranges.items():
        for s in range(spec_k + 1):
            out[f"{name}.s{s}"] = vr
    return out


def partition_roles(graph: Graph) -> Dict[str, str]:
    """Serving-partition role of every value this graph exchanges with the
    engine: maps each graph input and output name to one of ``"col"``
    (column/head-parallel weight), ``"kv_col"`` (column-parallel iff the
    KV-head count divides the TP degree — GQA-small falls back to
    replication), ``"dense_cache"`` / ``"paged_pool"`` / ``"kv_scale"``
    (head-sharded serving state), or ``"replicated"``.

    Thin, mesh-free view over :func:`repro.sharding.specs.serving_value_role`
    — the single source of the rules the ``partition`` compile stage
    (``compile(graph, mesh=...)``) turns into concrete ``PartitionSpec``\\ s.
    Builders need no annotations because every value these graphs emit is
    named by role (``l{i}.wq``, ``cache_k{i}``, ``cache_k{i}_scale``,
    ``block_tables``, ``new_``-prefixed outputs), and this helper makes
    that implicit contract inspectable and testable.
    """
    from repro.core.pipeline import get_pass
    from repro.sharding.specs import serving_value_role

    if any(o not in graph.value_info and o not in graph.inputs
           for o in graph.outputs):
        graph = get_pass("infer_shapes")(graph)
    paged = "block_tables" in graph.inputs
    names = list(graph.inputs) + [o for o in graph.outputs
                                  if o not in graph.inputs]
    return {name: serving_value_role(name, graph.spec_of(name).shape,
                                     paged=paged)
            for name in names}
