"""Decoder-only LM assembled from a LayerPlan: embed -> stack -> norm -> head.

Covers dense (phi3/stablelm/minitron), MoE (qwen2-moe/deepseek-v2-lite),
local:global (gemma3), hybrid (zamba2), SSM (mamba2) and embeds-frontend
(pixtral) architectures — the block composition lives entirely in the
config's LayerPlan.

API (all pure functions of params):
  init_params(key)                         -> params pytree
  train_loss(params, batch)                -> (loss, metrics)
  prefill(params, batch, cache_cap)        -> (last_logits, caches, lengths)
  decode_step(params, tokens, caches, lengths) -> (logits, new_caches)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.layers.common import embed_init, dense_init, norm
from repro.models.stack import init_stack_caches, stack_apply, stack_init

Params = Dict[str, Any]


def mask_vocab(logits: jax.Array, cfg: ArchConfig) -> jax.Array:
    """-inf the padding vocab rows (vocab_padded > vocab)."""
    if cfg.vocab_padded == cfg.vocab:
        return logits
    mask = jnp.arange(logits.shape[-1]) < cfg.vocab
    return jnp.where(mask, logits, -1e30)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  cfg: ArchConfig) -> jax.Array:
    """Token-mean CE in f32; labels < 0 are ignored."""
    logits = mask_vocab(logits, cfg).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1.0)


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    def init_params(self, key: jax.Array, dtype=None) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype) if dtype is None else dtype
        ks = jax.random.split(key, 4)
        p: Params = {
            "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dtype=dtype),
            "stack": stack_init(ks[1], cfg, cfg.plan, dtype=dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_padded,
                                      dtype=dtype)
        return p

    # ------------------------------------------------------------------ #
    def _embed(self, params: Params, batch: Dict[str, jax.Array],
               dtype) -> jax.Array:
        if self.cfg.frontend == "embeds" and "embeds" in batch:
            return batch["embeds"].astype(dtype)
        return params["embed"][batch["tokens"]].astype(dtype)

    def _head(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        return jnp.einsum("...d,dv->...v", h, w.astype(h.dtype))

    # ------------------------------------------------------------------ #
    def forward(self, params: Params, batch: Dict[str, jax.Array], *,
                mode: str, caches=None, lengths=None,
                cache_cap: Optional[int] = None,
                remat: Optional[bool] = None):
        cfg = self.cfg
        remat = cfg.remat if remat is None else remat
        dtype = jnp.dtype(cfg.dtype)
        h = self._embed(params, batch, dtype)
        emb0 = h  # zamba2 shared blocks re-read the initial embedding
        h, new_caches, aux = stack_apply(
            params["stack"], h, cfg.plan, cfg=cfg, mode=mode, caches=caches,
            lengths=lengths, emb0=emb0, cache_cap=cache_cap, remat=remat)
        h = norm(h, params["final_norm"], eps=cfg.norm_eps,
                 backend=cfg.backend("rmsnorm"))
        return h, new_caches, aux

    # ------------------------------------------------------------------ #
    def train_loss(self, params: Params, batch: Dict[str, jax.Array],
                   *, aux_weight: float = 0.01, remat: Optional[bool] = None
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        h, _, aux = self.forward(params, batch, mode="train", remat=remat)
        logits = self._head(params, h)
        ce = cross_entropy(logits, batch["labels"], self.cfg)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ #
    def prefill(self, params: Params, batch: Dict[str, jax.Array], *,
                cache_cap: Optional[int] = None):
        """Returns (last-position logits (B, V), caches, lengths (B,))."""
        seq = (batch["tokens"].shape[1] if "tokens" in batch
               else batch["embeds"].shape[1])
        bsz = (batch["tokens"].shape[0] if "tokens" in batch
               else batch["embeds"].shape[0])
        h, caches, _ = self.forward(params, batch, mode="prefill",
                                    cache_cap=cache_cap or seq)
        logits = self._head(params, h[:, -1])
        lengths = jnp.full((bsz,), seq, jnp.int32)
        return mask_vocab(logits, self.cfg), caches, lengths

    def decode_step(self, params: Params, tokens: jax.Array, caches,
                    lengths: jax.Array):
        """tokens (B,) int32 -> (logits (B, V), new_caches). The caller
        increments lengths afterwards."""
        batch = {"tokens": tokens[:, None]}
        h, new_caches, _ = self.forward(params, batch, mode="decode",
                                        caches=caches, lengths=lengths)
        logits = self._head(params, h[:, 0])
        return mask_vocab(logits, self.cfg), new_caches

    # ------------------------------------------------------------------ #
    def init_caches(self, batch: int, cache_cap: int, dtype=None):
        dtype = jnp.dtype(self.cfg.dtype) if dtype is None else dtype
        return init_stack_caches(self.cfg, self.cfg.plan, batch, cache_cap,
                                 dtype=dtype)
