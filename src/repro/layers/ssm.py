"""Mamba2 block (SSD sequence mixer) — train/prefill/decode.

Block structure (Mamba2, arXiv:2405.21060):

    z  = x @ wz                      (gate,   d -> d_inner)
    xs = silu(conv_x(x @ wx))        (stream, d -> d_inner)
    B  = silu(conv_B(x @ wB))        (d -> G*N)
    C  = silu(conv_C(x @ wC))        (d -> G*N)
    dt = softplus(x @ wdt + bias)    (d -> H)
    y  = SSD(xs, dt, A, B, C) + D*xs  <- registry op: ref/chunked/pallas
    out = (rmsnorm(y * silu(z))) @ out_proj

The projections are stored SEPARATELY (not one fused in_proj) so tensor
parallelism shards each stream on its natural axis: wz/wx column-parallel
over d_inner (and SSD heads H = d_inner/P shard with them), wdt over H,
out_proj row-parallel; B/C streams (G*N each, small) are replicated.
A fused in_proj would put TP shard boundaries mid-stream and force
reshard collectives at every split.

Decode carries two states per block: the conv tails ((B, K-1, ·) per
stream) and the SSM state (B, H, P, N) — O(1) per step, which is why SSM
archs run long_500k at constant memory.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.layers.common import dense, dense_init, norm

Params = Dict[str, Any]
Cache = Optional[Dict[str, jax.Array]]


def mamba_init(key: jax.Array, cfg: ArchConfig, *, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    h = s.n_heads
    gn = s.n_groups * s.state
    ks = jax.random.split(key, 8)
    # dt bias init so softplus(dt_bias) spans [dt_min, dt_max] (mamba2 init)
    u = jax.random.uniform(ks[6], (h,), jnp.float32)
    dt = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus

    def conv_w(k_, c):
        return (jax.random.normal(k_, (s.conv_kernel, c), jnp.float32)
                / math.sqrt(s.conv_kernel)).astype(dtype)

    return {
        "wz": dense_init(ks[0], d, s.d_inner, dtype=dtype),
        "wx": dense_init(ks[1], d, s.d_inner, dtype=dtype),
        "wB": dense_init(ks[2], d, gn, dtype=dtype),
        "wC": dense_init(ks[3], d, gn, dtype=dtype),
        "wdt": dense_init(ks[4], d, h, dtype=dtype),
        "conv_x": conv_w(ks[5], s.d_inner),
        "conv_B": conv_w(jax.random.fold_in(key, 21), gn),
        "conv_C": conv_w(jax.random.fold_in(key, 22), gn),
        "conv_bx": jnp.zeros((s.d_inner,), dtype),
        "conv_bB": jnp.zeros((gn,), dtype),
        "conv_bC": jnp.zeros((gn,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((s.d_inner,), dtype),
        "out_proj": dense_init(ks[7], s.d_inner, d, dtype=dtype),
    }


def _causal_conv(xs: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv, width K. xs (B,S,C), w (K,C). ``tail``
    (B,K-1,C) supplies left context (decode / chunked prefill)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((xs.shape[0], k - 1, xs.shape[2]), xs.dtype)
    xp = jnp.concatenate([tail, xs], axis=1)            # (B, S+K-1, C)
    out = sum(xp[:, i:i + xs.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def mamba_apply(p: Params, x: jax.Array, *, cfg: ArchConfig, mode: str,
                cache: Cache = None, lengths: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Cache]:
    s = cfg.ssm
    h, pd, g, n = s.n_heads, s.head_dim, s.n_groups, s.state
    b = x.shape[0]
    A = -jnp.exp(p["A_log"])
    dt_c = x.dtype

    if mode in ("train", "prefill"):
        _, sl, _ = x.shape
        z = dense(x, p["wz"])
        x_raw = dense(x, p["wx"])
        B_raw = dense(x, p["wB"])
        C_raw = dense(x, p["wC"])
        dt_raw = dense(x, p["wdt"])
        xs = jax.nn.silu(_causal_conv(x_raw, p["conv_x"].astype(dt_c),
                                      p["conv_bx"].astype(dt_c)))
        Bm = jax.nn.silu(_causal_conv(B_raw, p["conv_B"].astype(dt_c),
                                      p["conv_bB"].astype(dt_c)))
        Cm = jax.nn.silu(_causal_conv(C_raw, p["conv_C"].astype(dt_c),
                                      p["conv_bC"].astype(dt_c)))
        xs = xs.reshape(b, sl, h, pd)
        Bm = Bm.reshape(b, sl, g, n)
        Cm = Cm.reshape(b, sl, g, n)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
        y, ssm_state = kops.ssd(xs, dt, A, Bm, Cm, p["D"], chunk=s.chunk,
                                backend=cfg.backend("ssd"))
        y = y.reshape(b, sl, s.d_inner)
        y = norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm_w"], eps=cfg.norm_eps, backend=cfg.backend("rmsnorm"))
        out = dense(y, p["out_proj"])
        new_cache = None
        if mode == "prefill":
            k = s.conv_kernel
            new_cache = {"conv_x": x_raw[:, -(k - 1):, :],
                         "conv_B": B_raw[:, -(k - 1):, :],
                         "conv_C": C_raw[:, -(k - 1):, :],
                         "ssm": ssm_state.astype(jnp.float32)}
        return out, new_cache

    # ---- decode: one step, O(1) state update ----
    assert cache is not None
    xt = x[:, 0]
    z = dense(xt, p["wz"])
    x_new = dense(xt, p["wx"])[:, None]
    B_new = dense(xt, p["wB"])[:, None]
    C_new = dense(xt, p["wC"])[:, None]
    dt_raw = dense(xt, p["wdt"])

    def step_conv(new, tail, w, bias):
        out = jax.nn.silu(_causal_conv(new, w.astype(dt_c), bias.astype(dt_c),
                                       tail=tail))[:, 0]
        new_tail = jnp.concatenate([tail[:, 1:], new], axis=1)
        return out, new_tail

    xs, tail_x = step_conv(x_new, cache["conv_x"], p["conv_x"], p["conv_bx"])
    Bm, tail_B = step_conv(B_new, cache["conv_B"], p["conv_B"], p["conv_bB"])
    Cm, tail_C = step_conv(C_new, cache["conv_C"], p["conv_C"], p["conv_bC"])
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    y, ssm_state = kops.ssd_step(xs.reshape(b, h, pd), dtv, A,
                                 Bm.reshape(b, g, n), Cm.reshape(b, g, n),
                                 p["D"], cache["ssm"])
    y = norm(y.reshape(b, 1, s.d_inner)
             * jax.nn.silu(z[:, None].astype(jnp.float32)).astype(y.dtype),
             p["norm_w"], eps=cfg.norm_eps, backend=cfg.backend("rmsnorm"))
    out = dense(y, p["out_proj"])
    return out, {"conv_x": tail_x, "conv_B": tail_B, "conv_C": tail_C,
                 "ssm": ssm_state}
