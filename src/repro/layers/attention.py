"""Attention blocks: GQA (global + sliding-window), MLA (DeepSeek-V2),
cross-attention (enc-dec), and Zamba2-style shared blocks.

Three modes share one code path per variant:

* ``train``   — full-sequence causal attention, no cache.
* ``prefill`` — same compute; additionally returns the KV cache.
* ``decode``  — one new token per sequence against the cache.

Cache layout (per block):
  global attn:  {"k","v"}: (B, cap, Hkv, Dh) with cap = max context
  local  attn:  rolling buffer, cap = window; slot = position % cap
  MLA:          {"ckv": (B, cap, rank), "kpe": (B, cap, rope_dim)} — the
                latent cache (the whole point of MLA: 576 vs 2*H*Dh floats
                per token); decode uses the absorbed-matmul trick and runs
                MQA-style flash-decode over the latent.
  cross attn:   encoder K/V computed once at prefill, read-only afterwards.

``lengths`` (B,) counts valid cache entries BEFORE the current decode step;
the new token is written at slot ``lengths`` (mod cap for local) and
attention runs over ``lengths + 1`` entries.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.layers.common import apply_rope, dense, dense_init, rope_for_seq, rope_table

Params = Dict[str, Any]
Cache = Optional[Dict[str, jax.Array]]


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #

def attn_init(key: jax.Array, cfg: ArchConfig, *, cross: bool = False,
              dtype=jnp.float32) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, hq * dh, dtype=dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype=dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype=dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype=dtype),
    }


def mla_init(key: jax.Array, cfg: ArchConfig, *, dtype=jnp.float32) -> Params:
    d, hq = cfg.d_model, cfg.n_heads
    m = cfg.mla
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], d, hq * m.qk_dim, dtype=dtype),
        "wdkv": dense_init(ks[1], d, m.kv_lora_rank, dtype=dtype),
        "wkpe": dense_init(ks[2], d, m.rope_dim, dtype=dtype),
        # up-projections from the latent, per head
        "wuk": dense_init(ks[3], m.kv_lora_rank, hq * m.nope_dim, dtype=dtype),
        "wuv": dense_init(ks[4], m.kv_lora_rank, hq * m.v_dim, dtype=dtype),
        "wo": dense_init(jax.random.fold_in(key, 9), hq * m.v_dim, d, dtype=dtype),
    }


# --------------------------------------------------------------------------- #
# GQA attention (global / sliding window / cross)
# --------------------------------------------------------------------------- #

def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def _replicate_q_if_seq_sharded_cache(q: jax.Array, n_kv: int,
                                      batch: int) -> jax.Array:
    """Decode perf fix (EXPERIMENTS.md §Perf-1b): when kv heads don't divide
    the model axis the cache is sequence-sharded over "model"
    (sharding/specs.py).  Column-parallel wq leaves q HEAD-sharded, and XLA
    resolves the mismatch by involuntarily all-gathering the whole cache to
    head-sharded f32 (~100 GB/step for stablelm decode_32k).  Constraining q
    replicated over "model" flips the resolution: scores stay seq-sharded,
    softmax partitions with tiny psums, and the cache is never gathered."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import ambient_mesh, data_axes
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q
    if n_kv % mesh.shape["model"] == 0:
        return q        # head-sharded cache path; head-sharded q is right
    dp = data_axes(mesh)
    import numpy as _np
    dp_size = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bspec = dp if (dp and batch % dp_size == 0 and dp_size > 1) else None
    return jax.lax.with_sharding_constraint(q, P(bspec, None, None))


def attn_apply(p: Params, x: jax.Array, *, cfg: ArchConfig, mode: str,
               window: Optional[int] = None, cache: Cache = None,
               lengths: Optional[jax.Array] = None,
               enc_out: Optional[jax.Array] = None,
               enc_lengths: Optional[jax.Array] = None,
               cross: bool = False, causal: bool = True,
               cache_cap: Optional[int] = None
               ) -> Tuple[jax.Array, Cache]:
    """Returns (output, new_cache). x: (B,S,d) train/prefill, (B,1,d) decode."""
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ab = cfg.backend("attention")
    db = cfg.backend("decode_attention")

    if cross:
        return _cross_attn(p, x, cfg=cfg, mode=mode, cache=cache,
                           enc_out=enc_out, enc_lengths=enc_lengths)

    if mode in ("train", "prefill"):
        b, s, _ = x.shape
        q = _split_heads(dense(x, p["wq"]), hq)
        k = _split_heads(dense(x, p["wk"]), hkv)
        v = _split_heads(dense(x, p["wv"]), hkv)
        cos, sin = rope_for_seq(s, dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        o = kops.attention(q, k, v, causal=causal, window=window, backend=ab)
        y = dense(o.reshape(b, s, hq * dh), p["wo"])
        new_cache = None
        if mode == "prefill":
            cap = cache_cap or s
            if window is not None:
                cap = min(cap, window)
            if cap >= s:       # straight copy into the head of the buffer
                ck = k if cap == s else \
                    jnp.zeros((b, cap, hkv, dh), k.dtype).at[:, :s].set(k)
                cv = v if cap == s else \
                    jnp.zeros((b, cap, hkv, dh), v.dtype).at[:, :s].set(v)
            else:              # rolling buffer: token t lives at slot t % cap
                idx = jnp.arange(s - cap, s) % cap
                ck = jnp.zeros((b, cap, hkv, dh), k.dtype).at[:, idx].set(k[:, s - cap:])
                cv = jnp.zeros((b, cap, hkv, dh), v.dtype).at[:, idx].set(v[:, s - cap:])
            new_cache = {"k": ck, "v": cv}
        return y, new_cache

    # ---- decode ----
    assert cache is not None and lengths is not None
    b = x.shape[0]
    cap = cache["k"].shape[1]
    q = dense(x[:, 0], p["wq"]).reshape(b, hq, dh)
    k_new = dense(x[:, 0], p["wk"]).reshape(b, hkv, dh)
    v_new = dense(x[:, 0], p["wv"]).reshape(b, hkv, dh)
    q = _replicate_q_if_seq_sharded_cache(q, hkv, b)
    cos, sin = rope_table(lengths, dh, cfg.rope_theta)  # (B, rd/2)
    cos, sin = cos[:, None, :], sin[:, None, :]
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    slot = lengths % cap if window is not None else lengths
    bidx = jnp.arange(b)
    ck = cache["k"].at[bidx, slot].set(k_new.astype(cache["k"].dtype))
    cv = cache["v"].at[bidx, slot].set(v_new.astype(cache["v"].dtype))
    eff_len = jnp.minimum(lengths + 1, cap)
    o = kops.decode_attention(q, ck, cv, eff_len, backend=db)
    # row-parallel wo would pull a head-sharded layout back through the
    # attention (re-gathering a seq-sharded cache); pin o replicated so the
    # contraction psums (B,Hq,Dh) instead — see _replicate_q_... docstring
    o = _replicate_q_if_seq_sharded_cache(o, hkv, b)
    y = dense(o.reshape(b, 1, hq * dh), p["wo"])
    return y, {"k": ck, "v": cv}


def _cross_attn(p, x, *, cfg, mode, cache, enc_out, enc_lengths):
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    if mode in ("train", "prefill"):
        assert enc_out is not None
        k = _split_heads(dense(enc_out, p["wk"]), hkv)
        v = _split_heads(dense(enc_out, p["wv"]), hkv)
    else:
        assert cache is not None
        k, v = cache["k"], cache["v"]
    s = x.shape[1]
    q = _split_heads(dense(x, p["wq"]), hq)
    if mode == "decode":
        o = kops.decode_attention(q[:, 0], k, v, enc_lengths,
                                  backend=cfg.backend("decode_attention"))
        o = o[:, None]
    else:
        # non-causal full cross attention (no rope, standard enc-dec)
        o = kops.attention(q, k, v, causal=False,
                           backend=cfg.backend("attention"))
    y = dense(o.reshape(b, s, hq * dh), p["wo"])
    new_cache = {"k": k, "v": v} if mode == "prefill" else (cache if mode == "decode" else None)
    return y, new_cache


# --------------------------------------------------------------------------- #
# MLA (DeepSeek-V2): latent KV cache + absorbed decode
# --------------------------------------------------------------------------- #

def mla_apply(p: Params, x: jax.Array, *, cfg: ArchConfig, mode: str,
              cache: Cache = None, lengths: Optional[jax.Array] = None,
              cache_cap: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    m = cfg.mla
    hq = cfg.n_heads
    scale = 1.0 / math.sqrt(m.qk_dim)
    if mode in ("train", "prefill"):
        b, s, _ = x.shape
        q = dense(x, p["wq"]).reshape(b, s, hq, m.qk_dim)
        q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
        ckv = dense(x, p["wdkv"])                       # (B,S,rank)
        kpe = dense(x, p["wkpe"])                       # (B,S,rope_dim)
        cos, sin = rope_for_seq(s, m.rope_dim, cfg.rope_theta, rotary_dim=m.rope_dim)
        q_pe = apply_rope(q_pe, cos, sin)
        kpe = apply_rope(kpe[:, :, None, :], cos, sin)  # (B,S,1,rd)
        k_nope = dense(ckv, p["wuk"]).reshape(b, s, hq, m.nope_dim)
        v = dense(ckv, p["wuv"]).reshape(b, s, hq, m.v_dim)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(kpe, (b, s, hq, m.rope_dim))], -1)
        qc = jnp.concatenate([q_nope, q_pe], -1)
        o = kops.attention(qc, k, v, causal=True, scale=scale,
                           backend=cfg.backend("attention"))
        y = dense(o.reshape(b, s, hq * m.v_dim), p["wo"])
        new_cache = None
        if mode == "prefill":
            cap = cache_cap or s
            ckv_c, kpe_c = ckv, kpe[:, :, 0, :]
            if cap > s:
                ckv_c = jnp.zeros((b, cap, m.kv_lora_rank), ckv.dtype
                                  ).at[:, :s].set(ckv_c)
                kpe_c = jnp.zeros((b, cap, m.rope_dim), kpe.dtype
                                  ).at[:, :s].set(kpe_c)
            new_cache = {"ckv": ckv_c, "kpe": kpe_c}
        return y, new_cache

    # ---- decode (absorbed): score = q_nope^T Wuk ckv + q_pe^T kpe ----
    assert cache is not None and lengths is not None
    b = x.shape[0]
    q = dense(x[:, 0], p["wq"]).reshape(b, hq, m.qk_dim)
    q_nope, q_pe = q[..., :m.nope_dim], q[..., m.nope_dim:]
    cos, sin = rope_table(lengths, m.rope_dim, cfg.rope_theta, rotary_dim=m.rope_dim)
    q_pe = apply_rope(q_pe, cos[:, None, :], sin[:, None, :])
    ckv_new = dense(x[:, 0], p["wdkv"])                 # (B,rank)
    kpe_new = dense(x[:, 0], p["wkpe"])                 # (B,rd)
    kpe_new = apply_rope(kpe_new[:, None, :], cos[:, None, :], sin[:, None, :])[:, 0]
    bidx = jnp.arange(b)
    ckv = cache["ckv"].at[bidx, lengths].set(ckv_new.astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[bidx, lengths].set(kpe_new.astype(cache["kpe"].dtype))
    # absorb W_uk into q: q_lat[h] = q_nope[h] @ Wuk[h]  -> (B,H,rank)
    wuk = p["wuk"].reshape(m.kv_lora_rank, hq, m.nope_dim)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32)).astype(x.dtype)
    q_cat = jnp.concatenate([q_lat, q_pe], -1)          # (B,H,rank+rd)
    # MLA's latent cache is single-"head": always seq-sharded under TP,
    # so q must be model-replicated (same fix as GQA small-kv decode)
    q_cat = _replicate_q_if_seq_sharded_cache(q_cat, 1, b)
    k_cat = jnp.concatenate([ckv, kpe], -1)[:, :, None, :]  # (B,S,1,rank+rd)
    v_lat = ckv[:, :, None, :]                          # (B,S,1,rank)
    o_lat = kops.decode_attention(q_cat, k_cat, v_lat, lengths + 1, scale=scale,
                                  backend=cfg.backend("decode_attention"))
    # un-absorb W_uv: out[h] = o_lat[h] @ Wuv[h]
    o_lat = _replicate_q_if_seq_sharded_cache(o_lat, 1, b)
    wuv = p["wuv"].reshape(m.kv_lora_rank, hq, m.v_dim)
    o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(jnp.float32),
                   wuv.astype(jnp.float32)).astype(x.dtype)
    y = dense(o.reshape(b, 1, hq * m.v_dim), p["wo"])
    return y, {"ckv": ckv, "kpe": kpe}


# --------------------------------------------------------------------------- #
# Zamba2-style shared attention block (weights shared across periods)
# --------------------------------------------------------------------------- #

def shared_attn_init(key: jax.Array, cfg: ArchConfig, *, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    from repro.layers.mlp import swiglu_init  # local import to avoid cycle
    return {
        "fuse": dense_init(ks[0], 2 * d, d, dtype=dtype),
        "attn": attn_init(ks[1], cfg, dtype=dtype),
        "mlp": swiglu_init(ks[2], d, cfg.d_ff, dtype=dtype),
        "norm1": jnp.ones((d,), dtype),
        "norm2": jnp.ones((d,), dtype),
    }


def shared_attn_apply(p: Params, x: jax.Array, emb0: jax.Array, *,
                      cfg: ArchConfig, mode: str, cache: Cache = None,
                      lengths: Optional[jax.Array] = None,
                      cache_cap: Optional[int] = None
                      ) -> Tuple[jax.Array, Cache]:
    """Zamba2 shared block: fused(concat(h, initial_embedding)) -> attn+MLP.
    Residuals are added by the caller's block wrapper."""
    from repro.layers.mlp import swiglu_apply
    from repro.layers.common import norm
    nb = cfg.backend("rmsnorm")
    h_in = dense(jnp.concatenate([x, emb0], axis=-1), p["fuse"])
    a, new_cache = attn_apply(p["attn"], norm(h_in, p["norm1"], eps=cfg.norm_eps,
                                              backend=nb),
                              cfg=cfg, mode=mode, cache=cache, lengths=lengths,
                              cache_cap=cache_cap)
    h = h_in + a
    h = h + swiglu_apply(p["mlp"], norm(h, p["norm2"], eps=cfg.norm_eps,
                                        backend=nb), cfg=cfg)
    return h, new_cache
