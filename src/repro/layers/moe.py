"""Mixture-of-Experts channel mixer (routed top-k + optional shared experts).

Dispatch is capacity-based (Switch/GShard style), built to be
SPMD-shardable: the routed tokens are scattered into a dense
(E, capacity, d) buffer — expert dim sharded over the `model` axis (EP),
capacity over `data` — and expert FFNs run as batched GEMMs through the
registry (``moe_gemm``: ref einsum or the Pallas batched-GEMM kernel).

Position-within-expert is computed with a sort-based rank (no (T*k, E)
one-hot materialisation — that matrix would be ~400M elements for the
train_4k shape).  Tokens over capacity are dropped (weight 0), standard for
capacity-based MoE; capacity_factor 1.25 default.

Padded experts (e.g. qwen2's 60 -> 64 for even EP): router logits for
padding experts are masked to -inf, so they are never selected; their
(zero-init) weights occupy storage only.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.layers.common import dense, dense_init
from repro.layers.mlp import swiglu_init, swiglu_apply

Params = Dict[str, Any]


def moe_init(key: jax.Array, cfg: ArchConfig, *, dtype=jnp.float32) -> Params:
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_expert, mo.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, e, dtype=jnp.float32, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dtype),
    }
    if mo.n_shared:
        p["shared"] = swiglu_init(ks[4], d, mo.d_shared, dtype=dtype)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    mo = cfg.moe
    c = int(math.ceil(n_tokens * mo.top_k / mo.n_experts * mo.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU-friendly tiles


def route(logits: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """(T, E) router logits -> (top-k weights, top-k expert ids)."""
    mo = cfg.moe
    if mo.n_routed_padded and mo.n_routed_padded > mo.n_routed:
        pad_mask = jnp.arange(logits.shape[-1]) >= mo.n_routed
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, topi = jax.lax.top_k(probs, mo.top_k)
    if mo.router_norm_topk:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return topw, topi


def moe_apply(p: Params, x: jax.Array, *, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    if cfg.moe.dispatch == "local":
        return moe_apply_local(p, x, cfg=cfg)
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = mo.top_k
    e = mo.n_experts
    xt = x.reshape(t, d)

    logits = dense(xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    topw, topi = route(logits, cfg)

    # load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (t * k)
    frac_probs = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # ---- sort-based position-within-expert ----
    cap = _capacity(t, cfg)
    fi = topi.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(fi, stable=True)
    counts = jnp.zeros((e,), jnp.int32).at[fi].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[fi[order]]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    # ---- dispatch: (E, cap, d); dropped tokens contribute 0 ----
    tok_idx = jnp.repeat(jnp.arange(t), k)
    xe = jnp.zeros((e, cap, d), x.dtype)
    xe = xe.at[fi, pos_c].add(xt[tok_idx] * keep[:, None].astype(x.dtype))

    # ---- expert FFNs (batched GEMMs via registry) ----
    mb = cfg.backend("moe_gemm")
    g = kops.moe_gemm(xe, p["w_gate"].astype(x.dtype), backend=mb)
    u = kops.moe_gemm(xe, p["w_up"].astype(x.dtype), backend=mb)
    h = kops.swiglu(g, u, backend=cfg.backend("swiglu"))
    ye = kops.moe_gemm(h, p["w_down"].astype(x.dtype), backend=mb)  # (E,cap,d)

    # ---- combine ----
    gathered = ye[fi, pos_c] * (keep[:, None] * topw.reshape(-1)[:, None]
                                ).astype(x.dtype)        # (T*k, d)
    y = jnp.zeros((t, d), x.dtype).at[tok_idx].add(gathered)

    if mo.n_shared:
        y = y + swiglu_apply(p["shared"], xt, cfg=cfg)
    return y.reshape(b, s, d), aux


def moe_apply_local(p: Params, x: jax.Array, *, cfg: ArchConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    """Batch-local dispatch: capacity pools, cumsum ranks and scatters are
    computed PER BATCH ROW (vmapped), so with the batch dim sharded over the
    DP axes every routing index op is shard-local — the cross-device traffic
    of the MoE block reduces to the token->expert-owner movement plus weight
    gradients.  Semantics: per-row drops instead of global drops (the
    standard per-device-capacity trade; same expected drop rate)."""
    mo = cfg.moe
    b, s, d = x.shape
    k = mo.top_k
    e = mo.n_experts
    cap = _capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    topw, topi = route(logits.reshape(b * s, e), cfg)
    topw = topw.reshape(b, s, k)
    topi = topi.reshape(b, s, k)

    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) \
        / (b * s * k)
    aux = e * jnp.sum(frac_tokens * probs.mean((0, 1)))

    def row_dispatch(xt, fi_k, w_k):
        """xt (S,d), fi_k (S,k), w_k (S,k) -> (xe (E,cap,d), pos, keep)."""
        fi = fi_k.reshape(-1)
        order = jnp.argsort(fi, stable=True)
        counts = jnp.zeros((e,), jnp.int32).at[fi].add(1)
        starts = jnp.cumsum(counts) - counts
        pos_sorted = jnp.arange(s * k, dtype=jnp.int32) - starts[fi[order]]
        pos = jnp.zeros((s * k,), jnp.int32).at[order].set(pos_sorted)
        keep = pos < cap
        pos_c = jnp.minimum(pos, cap - 1)
        tok = jnp.repeat(jnp.arange(s), k)
        xe = jnp.zeros((e, cap, d), xt.dtype)
        xe = xe.at[fi, pos_c].add(xt[tok] * keep[:, None].astype(xt.dtype))
        return xe, fi, pos_c, keep, tok

    xe, fi, pos_c, keep, tok = jax.vmap(row_dispatch)(x, topi, topw)

    # pin the dispatched buffer to (batch over DP, experts over model): the
    # scatter output's sharding is otherwise unconstrained and XLA falls
    # back to replication (measured: +8s collective on qwen2 train_4k)
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import ambient_mesh, constrain, data_axes
    mesh = ambient_mesh()
    if mesh is not None:
        dp = data_axes(mesh)
        ep = ("model" if ("model" in mesh.axis_names
                          and e % mesh.shape["model"] == 0) else None)
        bs = dp if (dp and b % int(np.prod([mesh.shape[a] for a in dp])) == 0) \
            else None
        xe = constrain(xe, P(bs, ep, None, None))

    mb = cfg.backend("moe_gemm")
    wg = p["w_gate"].astype(x.dtype)
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    g = jax.vmap(lambda xb: kops.moe_gemm(xb, wg, backend=mb))(xe)
    u = jax.vmap(lambda xb: kops.moe_gemm(xb, wu, backend=mb))(xe)
    h = kops.swiglu(g, u, backend=cfg.backend("swiglu"))
    ye = jax.vmap(lambda hb: kops.moe_gemm(hb, wd, backend=mb))(h)  # (B,E,cap,d)
    if mesh is not None:
        ye = constrain(ye, P(bs, ep, None, None))

    def row_combine(ye_b, fi_b, pos_b, keep_b, tok_b, w_b):
        gathered = ye_b[fi_b, pos_b] * (keep_b[:, None]
                                        * w_b.reshape(-1)[:, None]
                                        ).astype(ye_b.dtype)
        return jnp.zeros((s, d), ye_b.dtype).at[tok_b].add(gathered)

    y = jax.vmap(row_combine)(ye, fi, pos_c, keep, tok, topw)

    if mo.n_shared:
        y = y + swiglu_apply(p["shared"], x.reshape(b * s, d),
                             cfg=cfg).reshape(b, s, d)
    return y, aux
