"""Shared layer primitives: linear, embedding, RoPE, norm dispatch.

Functional style throughout: ``init(key, ...) -> params`` (a dict pytree)
and pure ``apply(params, x, ...)``.  All matmul-bearing layers route through
the registry-dispatched kernel ops so backend selection (ref / pallas)
applies uniformly (the Orpheus model: layers are first-class, impls swap).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #

def dense_init(key: jax.Array, d_in: int, d_out: int, *,
               dtype=jnp.float32, scale: Optional[float] = None) -> jax.Array:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, *, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def dense(x: jax.Array, w: jax.Array, *, backend: str = "ref") -> jax.Array:
    """Registry-dispatched matmul; computes in x.dtype with f32 accumulate."""
    from repro.core.registry import get_impl
    return get_impl("dense", backend)([x, w.astype(x.dtype)], {})[0]


def norm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
         residual: Optional[jax.Array] = None, backend: str = "ref") -> jax.Array:
    return kops.rmsnorm(x, w, eps=eps, residual=residual, backend=backend)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #

def rope_table(positions: jax.Array, head_dim: int, theta: float = 1e4,
               rotary_dim: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``positions`` (any shape) -> (..., rotary_dim/2)."""
    rd = head_dim if rotary_dim is None else rotary_dim
    assert rd % 2 == 0
    inv_freq = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., rd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., H, D); cos/sin broadcastable to (..., 1, D_rot/2).
    Rotates the first ``2 * cos.shape[-1]`` features (pair-interleaved
    halves, GPT-NeoX style); the rest pass through."""
    rd2 = cos.shape[-1]
    xr, xp = x[..., :2 * rd2], x[..., 2 * rd2:]
    x1, x2 = xr[..., :rd2], xr[..., rd2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2, xp], axis=-1).astype(x.dtype)


def rope_for_seq(seq_len: int, head_dim: int, theta: float = 1e4,
                 offset: int = 0, rotary_dim: Optional[int] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """cos/sin shaped (seq, 1, rd/2) — broadcast over (B, S, H, D)."""
    pos = jnp.arange(offset, offset + seq_len)
    cos, sin = rope_table(pos, head_dim, theta, rotary_dim)
    return cos[:, None, :], sin[:, None, :]
