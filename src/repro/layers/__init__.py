"""Composable NN layers (functional: init/apply pairs), all dispatching
matmuls and mixers through the Orpheus backend registry."""

from repro.layers import attention, common, mlp, moe, ssm  # noqa: F401
