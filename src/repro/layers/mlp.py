"""Channel mixers: SwiGLU (gated) and plain 2-matrix MLP."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.layers.common import dense, dense_init

Params = Dict[str, Any]


def swiglu_init(key: jax.Array, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, d_ff, dtype=dtype),
        "w_up": dense_init(ks[1], d, d_ff, dtype=dtype),
        "w_down": dense_init(ks[2], d_ff, d, dtype=dtype),
    }


def swiglu_apply(p: Params, x: jax.Array, *, cfg: ArchConfig) -> jax.Array:
    b = cfg.backend("dense")
    g = dense(x, p["w_gate"], backend=b)
    u = dense(x, p["w_up"], backend=b)
    h = kops.swiglu(g, u, backend=cfg.backend("swiglu"))
    return dense(h, p["w_down"], backend=b)


def mlp_init(key: jax.Array, d: int, d_ff: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "w_in": dense_init(ks[0], d, d_ff, dtype=dtype),
        "w_out": dense_init(ks[1], d_ff, d, dtype=dtype),
    }


def mlp_apply(p: Params, x: jax.Array, *, cfg: ArchConfig) -> jax.Array:
    b = cfg.backend("dense")
    h = dense(x, p["w_in"], backend=b)
    if cfg.act == "relu":
        h = jnp.maximum(h, 0)
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.silu(h)
    return dense(h, p["w_out"], backend=b)
