"""repro — Orpheus-JAX: a multi-backend DNN framework for TPU pods.

Importing ``repro`` registers all standard ops (core.nnops) and all Pallas
TPU backends (kernels.ops) in the global backend registry.
"""

from repro import core  # noqa: F401  (registers standard ops)

try:  # Pallas backends are optional at import time (e.g. minimal installs)
    from repro.kernels import ops as _kernel_ops  # noqa: F401
    from repro.kernels import serving_ops as _serving_ops  # noqa: F401
except ImportError:  # pragma: no cover
    _kernel_ops = None
    _serving_ops = None

__version__ = "1.0.0"
