"""Cluster-membership simulation: heartbeats, failure detection, elastic
membership decisions.

On a real TPU fleet this sits on the coordination service (or
jax.distributed's barrier); here hosts are simulated so the policy logic —
who is alive, when to declare a failure, what the new mesh should be after
losing a pod — is unit-testable.  The elastic path it drives is real:
checkpoints are mesh-agnostic (see checkpoint/io.py), so the coordinator's
"rescale to N hosts" decision is executed by restoring the latest
checkpoint with the new mesh's shardings.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["Coordinator", "plan_mesh_after_failure"]


@dataclass
class _Member:
    host_id: str
    last_beat: float
    alive: bool = True


class Coordinator:
    """Heartbeat registry with a failure deadline."""

    def __init__(self, deadline: float = 1.0):
        self.deadline = deadline
        self._members: Dict[str, _Member] = {}
        self._lock = threading.Lock()
        self.generation = 0          # bumps on every membership change

    def register(self, host_id: str) -> int:
        with self._lock:
            self._members[host_id] = _Member(host_id, time.monotonic())
            self.generation += 1
            return self.generation

    def heartbeat(self, host_id: str) -> None:
        with self._lock:
            m = self._members.get(host_id)
            if m is None:
                raise KeyError(f"unknown host {host_id}")
            m.last_beat = time.monotonic()

    def sweep(self) -> List[str]:
        """Mark members beyond the deadline dead; returns newly dead."""
        now = time.monotonic()
        dead = []
        with self._lock:
            for m in self._members.values():
                if m.alive and now - m.last_beat > self.deadline:
                    m.alive = False
                    dead.append(m.host_id)
            if dead:
                self.generation += 1
        return dead

    def alive(self) -> List[str]:
        with self._lock:
            return sorted(m.host_id for m in self._members.values() if m.alive)


def plan_mesh_after_failure(n_alive_chips: int, model_parallel: int = 16
                            ) -> Optional[Tuple[Tuple[int, int], Tuple[str, str]]]:
    """Largest (data, model) mesh that fits the survivors, keeping the TP
    degree fixed (params were sharded for it).  Returns None if fewer than
    one TP group survives."""
    if n_alive_chips < model_parallel:
        return None
    data = n_alive_chips // model_parallel
    return (data, model_parallel), ("data", "model")
