"""Straggler / hang detection for the training loop.

``StepWatchdog`` tracks per-step wall times and flags stragglers against a
rolling median (real fleets: a slow HBM or thermal-throttled chip shows up
exactly like this).  ``HangDetector`` arms a timer around each step; if a
step exceeds the deadline the registered callback fires (checkpoint and
abort, typically) — on a real cluster that converts a hung collective into
a clean restart instead of a silent stall.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

__all__ = ["StepWatchdog", "HangDetector"]


@dataclass
class StepWatchdog:
    window: int = 50
    threshold: float = 2.0     # x median => straggler
    _times: Deque[float] = field(default_factory=deque)
    stragglers: List[int] = field(default_factory=list)
    _step: int = 0
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record one step; returns True if it was a straggler."""
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = False
        if len(self._times) >= 5:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.threshold * med:
                self.stragglers.append(self._step)
                is_straggler = True
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.popleft()
        return is_straggler

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        return sorted(self._times)[len(self._times) // 2]


class HangDetector:
    """Arms a deadline around a step; fires ``on_hang`` if exceeded."""

    def __init__(self, timeout: float, on_hang: Callable[[], None]):
        self.timeout = timeout
        self.on_hang = on_hang
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def __enter__(self):
        # re-armable: one detector can guard many steps (the serving
        # engine arms it around every tick), so each arm starts clean
        self.fired = False

        def fire():
            self.fired = True
            self.on_hang()

        self._timer = threading.Timer(self.timeout, fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        # disarm; if the timer already fired this is a no-op (cancel() on
        # a completed Timer does nothing), so the callback runs at most
        # once per arm — there is no disarm/fire double-report race
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        return False
