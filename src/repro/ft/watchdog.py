"""Straggler / hang detection for the training loop.

``StepWatchdog`` tracks per-step wall times and flags stragglers against a
rolling median (real fleets: a slow HBM or thermal-throttled chip shows up
exactly like this).  ``HangDetector`` arms a timer around each step; if a
step exceeds the deadline the registered callback fires (checkpoint and
abort, typically) — on a real cluster that converts a hung collective into
a clean restart instead of a silent stall.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

__all__ = ["StepWatchdog", "HangDetector"]


@dataclass
class StepWatchdog:
    window: int = 50
    threshold: float = 2.0     # x median => straggler
    _times: Deque[float] = field(default_factory=deque)
    stragglers: List[int] = field(default_factory=list)
    _step: int = 0
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record one step; returns True if it was a straggler."""
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._step += 1
        is_straggler = False
        if len(self._times) >= 5:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.threshold * med:
                self.stragglers.append(self._step)
                is_straggler = True
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.popleft()
        return is_straggler

    @property
    def median(self) -> float:
        if not self._times:
            return 0.0
        return sorted(self._times)[len(self._times) // 2]


class HangDetector:
    """Arms a deadline around a step; fires ``on_hang`` if exceeded.

    Re-armable: one detector guards many steps (the serving engine arms
    it around every tick), and back-to-back arms must each observe their
    own overrun.  Two races make the naive Timer-only version drop
    hangs:

    * a step that overruns the deadline but whose Timer thread has not
      been scheduled by the time ``__exit__`` cancels it — the hang is
      real (the deadline elapsed) but ``fired`` never flips, so a second
      hang in the same recovery window is silently missed;
    * a stale Timer from a PREVIOUS arm that slips past ``cancel()`` and
      fires after the next arm reset ``fired`` — reporting a phantom
      hang against a healthy step.

    Each arm therefore carries a generation number (a stale fire against
    a newer generation is ignored, under a lock) and ``__exit__`` checks
    the elapsed ``time.perf_counter()`` clock against the deadline
    directly — deterministic, thread-free, and what makes the overrun
    path testable with a fake clock.  ``on_hang`` runs at most once per
    arm: whichever of the Timer thread and ``__exit__`` flips ``fired``
    first makes the call, the other sees the flag and stands down.
    """

    def __init__(self, timeout: float, on_hang: Callable[[], None]):
        self.timeout = timeout
        self.on_hang = on_hang
        self._timer: Optional[threading.Timer] = None
        self.fired = False
        self._gen = 0
        self._armed_at: Optional[float] = None
        self._lock = threading.Lock()

    def __enter__(self):
        with self._lock:
            self._gen += 1
            gen = self._gen
            self.fired = False
        self._armed_at = time.perf_counter()

        def fire(gen: int = gen) -> None:
            with self._lock:
                if gen != self._gen or self.fired:
                    return          # stale arm, or __exit__ beat us to it
                self.fired = True
            self.on_hang()

        self._timer = threading.Timer(self.timeout, fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, *exc):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        overran = (self._armed_at is not None
                   and time.perf_counter() - self._armed_at >= self.timeout)
        missed = False
        with self._lock:
            # invalidate the cancelled Timer even if its thread is past
            # the cancel window — it must not touch the next arm's flag
            self._gen += 1
            if overran and not self.fired:
                self.fired = True
                missed = True
        if missed:
            self.on_hang()
        return False
