"""Fault tolerance: straggler watchdog, hang detection, membership/elastic."""

from repro.ft.coordinator import Coordinator, plan_mesh_after_failure
from repro.ft.watchdog import HangDetector, StepWatchdog

__all__ = ["Coordinator", "plan_mesh_after_failure", "HangDetector",
           "StepWatchdog"]
