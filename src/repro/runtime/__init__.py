"""Runtime: train/serve step factories, continuous batching, the
Program-backed serving engine, and trace-driven load generation."""

from repro.runtime.batching import ContinuousBatcher, Request, SlotScheduler
from repro.runtime.engine import (AsyncEngine, CheckpointSlot, Engine,
                                  EngineCheckpoint, EngineMetrics,
                                  EngineRequest, PagedProgramStepper,
                                  ProgramStepper, TickFailure,
                                  UnbatchedReference, build_lm_serving)
from repro.runtime.kv_cache import BlockPool
from repro.runtime.loadgen import (SLO, PrefixPopulation, TierSpec, Trace,
                                   TraceConfig, TraceRequest, generate_trace,
                                   run_load)
from repro.runtime.serve import make_decode_step, make_prefill_step, serve_shardings
from repro.runtime.train import make_train_step, train_state_shardings

__all__ = ["ContinuousBatcher", "Request", "SlotScheduler",
           "AsyncEngine", "Engine", "EngineMetrics", "EngineRequest",
           "ProgramStepper", "PagedProgramStepper", "UnbatchedReference",
           "BlockPool", "build_lm_serving",
           "EngineCheckpoint", "CheckpointSlot", "TickFailure",
           "SLO", "TierSpec", "PrefixPopulation", "Trace", "TraceConfig",
           "TraceRequest", "generate_trace", "run_load",
           "make_decode_step", "make_prefill_step", "serve_shardings",
           "make_train_step", "train_state_shardings"]
