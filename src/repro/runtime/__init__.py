"""Runtime: train/serve step factories, continuous batching, and the
Program-backed serving engine."""

from repro.runtime.batching import ContinuousBatcher, Request, SlotScheduler
from repro.runtime.engine import (AsyncEngine, Engine, EngineMetrics,
                                  EngineRequest, PagedProgramStepper,
                                  ProgramStepper, UnbatchedReference,
                                  build_lm_serving)
from repro.runtime.kv_cache import BlockPool
from repro.runtime.serve import make_decode_step, make_prefill_step, serve_shardings
from repro.runtime.train import make_train_step, train_state_shardings

__all__ = ["ContinuousBatcher", "Request", "SlotScheduler",
           "AsyncEngine", "Engine", "EngineMetrics", "EngineRequest",
           "ProgramStepper", "PagedProgramStepper", "UnbatchedReference",
           "BlockPool", "build_lm_serving",
           "make_decode_step", "make_prefill_step", "serve_shardings",
           "make_train_step", "train_state_shardings"]
