"""Runtime: train/serve step factories, continuous batching."""

from repro.runtime.batching import ContinuousBatcher, Request
from repro.runtime.serve import make_decode_step, make_prefill_step, serve_shardings
from repro.runtime.train import make_train_step, train_state_shardings

__all__ = ["ContinuousBatcher", "Request", "make_decode_step",
           "make_prefill_step", "serve_shardings", "make_train_step",
           "train_state_shardings"]
