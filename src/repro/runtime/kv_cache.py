"""Paged KV cache: a refcounted block pool with prefix reuse.

The dense serving cache (one ``(n_slots, cache_cap, Hk, D)`` buffer per
layer) reserves worst-case memory for every slot — capacity scales with
``slots x cache_cap`` no matter how short the actual sequences are, and
identical prompt prefixes (system prompts, few-shot headers) are
recomputed and stored once per request.  This module is the host-side
half of the paged alternative (vLLM-style): KV rows live in fixed-size
**pages** drawn from one shared pool, each sequence owns a **block
table** mapping logical page -> physical block, and pages holding
identical token prefixes are **shared** between sequences via a prefix
index.

:class:`BlockPool` is pure bookkeeping — numpy-free, jax-free — so the
property suite (``tests/test_kv_cache.py``) can drive millions of random
admit/append/finish/fork steps cheaply.  The device arrays and the
compiled paged Programs live in
:class:`repro.runtime.engine.PagedProgramStepper`, which consumes this
pool's block tables and applies its pending copy-on-write copies.

Invariants (``check_integrity`` asserts them; hypothesis hammers them):

* every block is in exactly one state — free, cached (refcount 0 but
  retained in the prefix index, evictable LRU), or live (refcount >= 1);
* a block's refcount equals the number of sequence block tables that
  contain it;
* reservations never exceed what the pool can provide, so an admitted
  sequence can always grow to its declared ``max_new_tokens`` without a
  mid-flight allocation failure;
* indexed blocks are frozen (immutable): any write that would land in a
  frozen or shared (refcount > 1) block first copies it (copy-on-write)
  into a private block, and the device-side page copy is queued in
  ``pending_copies`` for the stepper to apply before the next Program
  call.

Prefix sharing has two granularities:

* **full pages** — registered the moment a page fills; keyed by the
  token ids of the sequence from position 0 through the end of that page
  (content-addressed, so it is correct for generated tokens too);
* **partial tail pages** — registered when a sequence finishes; a new
  prompt that matches `m < page_size` leading rows of a cached tail
  shares the block read-only, and its first append into that page
  triggers the copy-on-write divergence path.

Reuse is capped at ``len(prompt) - 1`` tokens so at least one prompt
position is always prefilled — the first output token comes from that
position's logits.

The pool is also the engine's **resume substrate**: a sequence parked
back in the queue by fault recovery or tier-aware preemption keeps its
:class:`SeqState` (block table, tokens, reservation) live in the pool,
and re-admission fast-forwards past every committed row instead of
re-prefilling — :meth:`BlockPool.snapshot` / :meth:`BlockPool.restore`
roll the bookkeeping back to the failed tick's start, and
:meth:`BlockPool.truncate` unwinds rejected speculative rows (dropping
their prefix-index registrations) the same transactional way.
``tests/test_pool_properties.py`` drives random interleavings of all
three against the pool invariants.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["BlockPool", "SeqState", "pages_needed", "kv_page_bytes"]

# bytes per element of the supported KV storage dtypes (kept as a plain
# table so this module stays numpy-free)
_KV_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1}
# per-row scale-sidecar bytes: int8 pages carry one float32 scale per
# (page, kv head) for K and V each -> 2 * 4 bytes per kv head per page
_SCALE_BYTES = 4


def kv_page_bytes(n_layers: int, n_kv_heads: int, d_head: int,
                  page_size: int, kv_dtype: str = "float32") -> int:
    """Device bytes one pool page occupies across all layers, K and V,
    including the float32 scale sidecars for quantized dtypes.  This is
    the number honest equal-memory comparisons must use: an int8 pool
    with the same *page count* as an fp32 pool is ~4x smaller, not equal.
    """
    if kv_dtype not in _KV_ITEMSIZE:
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r}; "
                         f"known: {sorted(_KV_ITEMSIZE)}")
    per_kv = n_layers * 2 * page_size * n_kv_heads * d_head
    total = per_kv * _KV_ITEMSIZE[kv_dtype]
    if kv_dtype == "int8":
        total += n_layers * 2 * n_kv_heads * _SCALE_BYTES
    return total


def pages_needed(prompt_len: int, max_new_tokens: int, page_size: int) -> int:
    """Worst-case pages a request can ever occupy.  The cache stores
    ``prompt_len + max_new_tokens - 1`` rows at most: the last generated
    token is emitted but never written back (there is no step after it)."""
    rows = max(prompt_len + max_new_tokens - 1, 1)
    return -(-rows // page_size)


@dataclass
class _Block:
    bid: int
    ref: int = 0
    frozen: bool = False                  # indexed => immutable
    tokens: List[int] = field(default_factory=list)   # rows written so far
    index_key: Optional[Tuple[Any, ...]] = None


@dataclass
class SeqState:
    """One live sequence's view of the pool (block table + bookkeeping)."""

    sid: int
    table: List[int] = field(default_factory=list)    # logical page -> bid
    tokens: List[int] = field(default_factory=list)   # all rows, in order
    n_tokens: int = 0                                 # == len(tokens)
    reserved: int = 0                                 # blocks still owed to us


class BlockPool:
    """Fixed-size page pool with refcounting, prefix index, CoW and LRU
    reclamation of cached (refcount-0 but indexed) blocks.

    Sharding-oblivious by design: the pool tracks *block ids*, never
    tensor data, so it works unchanged when the engine serves
    tensor-parallel and the device page arrays ``(N_pages, page, Hk, D)``
    are head-sharded over the mesh's "model" axis (dim 2 — see
    ``repro.sharding.specs.cache_specs``).  The CoW copies it schedules
    (``pending_copies`` → the engine's ``arr.at[dst].set(arr[src])``)
    index axis 0, which is never sharded, so each device copies exactly
    its own head slice and ``snapshot()``/``restore()`` of the id-level
    bookkeeping stays correct without touching device state."""

    def __init__(self, n_blocks: int, page_size: int, *,
                 kv_dtype: str = "float32",
                 page_bytes: Optional[int] = None):
        if n_blocks < 1 or page_size < 1:
            raise ValueError("need n_blocks >= 1 and page_size >= 1")
        if kv_dtype not in _KV_ITEMSIZE:
            raise ValueError(f"unsupported kv_dtype {kv_dtype!r}; "
                             f"known: {sorted(_KV_ITEMSIZE)}")
        self.n_blocks = n_blocks
        self.page_size = page_size
        # storage dtype of the device page arrays this pool describes,
        # and the per-page device footprint (scale sidecars included) —
        # pure metadata here, but it makes ``stats()`` report bytes so
        # equal-memory comparisons across kv dtypes stay honest
        self.kv_dtype = kv_dtype
        self.page_bytes = page_bytes
        self._blocks = [_Block(i) for i in range(n_blocks)]
        self._free: deque = deque(range(n_blocks))
        self._evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        self._full: Dict[Tuple[int, ...], int] = {}
        self._partial: Dict[Tuple[int, ...], Dict[int, Tuple[int, ...]]] = {}
        self._seqs: Dict[int, SeqState] = {}
        self._next_sid = 0
        self._reserved_total = 0
        self.pending_copies: List[Tuple[int, int]] = []   # (src bid, dst bid)
        # bumped whenever availability may have GROWN (a block reached
        # refcount 0, or a reservation was returned) — lets callers skip
        # re-running an admission lookup that cannot succeed until then
        self.version = 0
        # stats
        self.n_admitted = 0
        self.n_admit_deferred = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.cow_count = 0
        self.evictions = 0

    # ------------------------------------------------------------------ #
    # capacity
    # ------------------------------------------------------------------ #
    @property
    def available_blocks(self) -> int:
        """Blocks an admission may claim right now: free + evictable
        cache, minus blocks already promised to live sequences."""
        return len(self._free) + len(self._evictable) - self._reserved_total

    def fits_ever(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Could this request run on an otherwise empty pool?"""
        return pages_needed(prompt_len, max_new_tokens,
                            self.page_size) <= self.n_blocks

    # ------------------------------------------------------------------ #
    # prefix lookup
    # ------------------------------------------------------------------ #
    def lookup(self, prompt: Sequence[int]) -> Tuple[List[int], Optional[int], int]:
        """Longest cached prefix of ``prompt``: full-page block chain, an
        optional partial tail block, and the reusable token count (capped
        at ``len(prompt) - 1``)."""
        page = self.page_size
        limit = len(prompt) - 1
        blocks: List[int] = []
        k = 0
        while (k + 1) * page <= limit:
            bid = self._full.get(tuple(prompt[:(k + 1) * page]))
            if bid is None:
                break
            blocks.append(bid)
            k += 1
        tail = tuple(prompt[k * page:limit])
        best_bid, best_m = None, 0
        for bid, rows in self._partial.get(tuple(prompt[:k * page]), {}).items():
            m = 0
            for a, b in zip(rows, tail):
                if a != b:
                    break
                m += 1
            if m > best_m:
                best_bid, best_m = bid, m
        return blocks, best_bid, k * page + best_m

    # ------------------------------------------------------------------ #
    # sequence lifecycle
    # ------------------------------------------------------------------ #
    def admit(self, prompt: Sequence[int],
              max_new_tokens: int) -> Optional[Tuple[int, int]]:
        """Admit a request: claim its cached prefix and reserve every
        block it could still need.  Returns ``(sid, reused_tokens)``, or
        ``None`` when the pool cannot currently cover the worst case (the
        caller should leave the request queued)."""
        if len(prompt) < 1 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens >= 1")
        blocks, tail_bid, reused = self.lookup(prompt)
        total = pages_needed(len(prompt), max_new_tokens, self.page_size)
        # pages k..total-1 each cost one allocation over the sequence's
        # lifetime; a shared partial tail is replaced (CoW) on first write,
        # so it is already counted in ``total - len(blocks)``
        need = total - len(blocks)
        table = list(blocks)
        if tail_bid is not None and reused > len(blocks) * self.page_size:
            table.append(tail_bid)
        # claiming a cached (refcount-0) prefix block removes it from the
        # reclaimable set, so it costs availability just like an allocation
        claimed = sum(1 for bid in table if self._blocks[bid].ref == 0)
        if need + claimed > self.available_blocks:
            self.n_admit_deferred += 1
            return None
        self.n_admitted += 1
        self.lookup_tokens += len(prompt)
        self.hit_tokens += reused
        sid = self._next_sid
        self._next_sid += 1
        for bid in table:
            self._incref(bid)
        self._seqs[sid] = SeqState(sid=sid, table=table,
                                    tokens=list(prompt[:reused]),
                                    n_tokens=reused, reserved=need)
        self._reserved_total += need
        return sid, reused

    def append(self, sid: int, tokens: Sequence[int]) -> None:
        """Record ``tokens`` written at the sequence's next positions.
        Allocates pages as they are entered and performs copy-on-write
        when a write would land in a frozen or shared block (the device
        copy is queued in ``pending_copies``)."""
        seq = self._seqs[sid]
        page = self.page_size
        for t in tokens:
            pi, row = divmod(seq.n_tokens, page)
            if pi == len(seq.table):
                seq.table.append(self._alloc(seq))
            bid = seq.table[pi]
            blk = self._blocks[bid]
            if blk.frozen or blk.ref > 1:
                nb = self._alloc(seq)
                self._blocks[nb].tokens = list(blk.tokens[:row])
                self.pending_copies.append((bid, nb))
                self.cow_count += 1
                self._decref(bid)
                seq.table[pi] = nb
                bid, blk = nb, self._blocks[nb]
            assert len(blk.tokens) == row, "non-append write to a page"
            blk.tokens.append(int(t))
            seq.tokens.append(int(t))
            seq.n_tokens += 1
            if len(blk.tokens) == page:
                self._register_full(seq, pi, bid)

    def truncate(self, sid: int, n_keep: int) -> None:
        """Roll a sequence back to its first ``n_keep`` rows — the
        speculative-decoding reject path.  A verify step appends the
        committed next token plus K draft proposals in one write; after
        acceptance the rejected tail rows must vanish from the
        bookkeeping (their device rows become garbage past the
        sequence's length, which attention masking already ignores —
        the same append-only-page argument :meth:`snapshot` relies on).

        Only rows the sequence itself appended can be dropped: every row
        past ``n_keep`` was written after admission (a frozen or shared
        page would have been copied-on-write first), so dropped blocks
        are private (``ref == 1``).  A block the speculative write
        filled — and therefore registered in the prefix index — is
        de-indexed before it is dropped or trimmed: its content encodes
        rejected tokens and must not be donated.  Whole dropped blocks
        return to the free list and their allocation is re-credited to
        the sequence's reservation (it may regrow to the same worst
        case it was admitted for)."""
        seq = self._seqs[sid]
        if not 0 <= n_keep <= seq.n_tokens:
            raise ValueError(f"truncate to {n_keep} outside "
                             f"[0, {seq.n_tokens}]")
        if n_keep == seq.n_tokens:
            return
        page = self.page_size
        n_before = seq.n_tokens
        keep_blocks = -(-n_keep // page)
        for bid in seq.table[keep_blocks:]:
            blk = self._blocks[bid]
            assert blk.ref == 1, \
                f"truncate dropping shared block {bid} (ref {blk.ref})"
            if blk.index_key is not None:
                self._drop_index(bid)
            self._decref(bid)
            seq.reserved += 1
            self._reserved_total += 1
        del seq.table[keep_blocks:]
        if keep_blocks:
            bid = seq.table[-1]
            blk = self._blocks[bid]
            row_keep = n_keep - (keep_blocks - 1) * page
            # rows of OURS in the tail block; blk.tokens may hold more
            # (a shared donor tail we only reused a prefix of) — those
            # are not ours to trim, and none of our rows live past them
            our_rows = min(page, n_before - (keep_blocks - 1) * page)
            if our_rows > row_keep:
                assert blk.ref == 1, \
                    f"truncate trimming shared block {bid} (ref {blk.ref})"
                if blk.index_key is not None:
                    self._drop_index(bid)
                del blk.tokens[row_keep:]
        del seq.tokens[n_keep:]
        seq.n_tokens = n_keep

    def fork(self, sid: int, max_new_tokens: int) -> Optional[int]:
        """Clone a sequence sharing every block (beam/speculative-style
        divergence): both copies keep reading the shared pages; the first
        write into the shared tail triggers copy-on-write.  Reserves the
        clone's worst-case growth; returns ``None`` when it cannot."""
        seq = self._seqs[sid]
        total = pages_needed(seq.n_tokens, max_new_tokens + 1, self.page_size)
        # worst case for the clone: every page beyond the current table,
        # plus a CoW replacement of the (now shared) tail page.  The PARENT
        # also gains a potential CoW (its next write hits a ref-2 block),
        # so it is granted one extra reserved block too.
        tail_cow = 1 if (seq.table and
                         len(self._blocks[seq.table[-1]].tokens)
                         < self.page_size) else 0
        need = max(total - len(seq.table), 0) + tail_cow
        if need + tail_cow > self.available_blocks:
            return None
        nsid = self._next_sid
        self._next_sid += 1
        for bid in seq.table:
            self._incref(bid)
        self._seqs[nsid] = SeqState(sid=nsid, table=list(seq.table),
                                     tokens=list(seq.tokens),
                                     n_tokens=seq.n_tokens, reserved=need)
        seq.reserved += tail_cow
        self._reserved_total += need + tail_cow
        return nsid

    def release(self, sid: int, *, register: bool = True) -> None:
        """Finish (``register=True``) or drop a sequence.  Finishing
        registers the partial tail page in the prefix index so future
        prompts can share it; every block is decref'd and refcount-0
        blocks return to the free list (unindexed) or the evictable LRU
        (indexed)."""
        seq = self._seqs.pop(sid)
        if register and seq.table:
            bid = seq.table[-1]
            blk = self._blocks[bid]
            if (0 < len(blk.tokens) < self.page_size and not blk.frozen
                    and blk.ref == 1 and blk.index_key is None):
                chain = tuple(seq.tokens[:(len(seq.table) - 1) * self.page_size])
                self._partial.setdefault(chain, {})[bid] = tuple(blk.tokens)
                blk.frozen = True
                blk.index_key = ("partial", chain)
        for bid in seq.table:
            self._decref(bid)
        self._reserved_total -= seq.reserved
        if seq.reserved:
            self.version += 1

    def block_table(self, sid: int) -> List[int]:
        return list(self._seqs[sid].table)

    def sequence(self, sid: int) -> SeqState:
        return self._seqs[sid]

    def take_copies(self) -> List[Tuple[int, int]]:
        """Drain the queued CoW (src, dst) page copies — the stepper must
        apply them to the device page arrays before its next Program call."""
        out, self.pending_copies = self.pending_copies, []
        return out

    # ------------------------------------------------------------------ #
    # snapshot / restore (engine self-healing)
    # ------------------------------------------------------------------ #
    def snapshot(self) -> Dict[str, Any]:
        """Full pure-python copy of the pool's bookkeeping state.

        The self-healing engine captures one at the start of every guarded
        tick: a tick that crashes or hangs mid-flight may have recorded
        appends (and registered full pages in the prefix index) whose
        device writes never happened — :meth:`restore` rolls the pool back
        to the pre-tick state so bookkeeping matches the device arrays
        again.  Blocks are append-only and frozen blocks are never
        rewritten, so every row the restored state considers written is
        still bit-valid on device; rows written by the failed tick become
        garbage past each sequence's length, which the attention masking
        already ignores."""
        return {
            "blocks": [(b.ref, b.frozen, list(b.tokens), b.index_key)
                       for b in self._blocks],
            "free": list(self._free),
            "evictable": list(self._evictable),
            "full": dict(self._full),
            "partial": {k: dict(v) for k, v in self._partial.items()},
            "seqs": {sid: (list(s.table), list(s.tokens), s.reserved)
                     for sid, s in self._seqs.items()},
            "next_sid": self._next_sid,
            "reserved_total": self._reserved_total,
            "pending_copies": list(self.pending_copies),
            "version": self.version,
            "counters": (self.n_admitted, self.n_admit_deferred,
                         self.hit_tokens, self.lookup_tokens,
                         self.cow_count, self.evictions),
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        """Restore state captured by :meth:`snapshot` (in place, so
        steppers holding a reference keep it).  Deep-copies out of the
        snapshot — the same snapshot can be restored repeatedly (a
        recovered engine may crash again).  Ends with
        :meth:`check_integrity`: a restore that does not satisfy every
        pool invariant is an error, not a latent corruption."""
        if len(snap["blocks"]) != self.n_blocks:
            raise ValueError(f"snapshot has {len(snap['blocks'])} blocks, "
                             f"pool has {self.n_blocks}")
        for blk, (ref, frozen, tokens, key) in zip(self._blocks,
                                                   snap["blocks"]):
            blk.ref, blk.frozen = ref, frozen
            blk.tokens = list(tokens)
            blk.index_key = key
        self._free = deque(snap["free"])
        self._evictable = OrderedDict((bid, None)
                                      for bid in snap["evictable"])
        self._full = dict(snap["full"])
        self._partial = {k: dict(v) for k, v in snap["partial"].items()}
        self._seqs = {
            sid: SeqState(sid=sid, table=list(table), tokens=list(tokens),
                          n_tokens=len(tokens), reserved=reserved)
            for sid, (table, tokens, reserved) in snap["seqs"].items()}
        self._next_sid = snap["next_sid"]
        self._reserved_total = snap["reserved_total"]
        self.pending_copies = list(snap["pending_copies"])
        self.version = snap["version"]
        (self.n_admitted, self.n_admit_deferred, self.hit_tokens,
         self.lookup_tokens, self.cow_count, self.evictions) = snap["counters"]
        self.check_integrity()

    # ------------------------------------------------------------------ #
    # internals
    # ------------------------------------------------------------------ #
    def _register_full(self, seq: SeqState, pi: int, bid: int) -> None:
        key = tuple(seq.tokens[:(pi + 1) * self.page_size])
        blk = self._blocks[bid]
        if key in self._full or blk.index_key is not None:
            return          # identical content already cached; keep private
        self._full[key] = bid
        blk.frozen = True
        blk.index_key = ("full", key)

    def _incref(self, bid: int) -> None:
        blk = self._blocks[bid]
        blk.ref += 1
        if blk.ref == 1:
            self._evictable.pop(bid, None)

    def _decref(self, bid: int) -> None:
        blk = self._blocks[bid]
        assert blk.ref > 0, f"double free of block {bid}"
        blk.ref -= 1
        if blk.ref == 0:
            if blk.index_key is not None:
                self._evictable[bid] = None
                self._evictable.move_to_end(bid)
            else:
                self._free.append(bid)
            self.version += 1

    def _alloc(self, seq: SeqState) -> int:
        assert seq.reserved > 0, (
            f"sequence {seq.sid} grew past its reservation")
        seq.reserved -= 1
        self._reserved_total -= 1
        if self._free:
            bid = self._free.popleft()
        else:
            bid = self._evict()
        blk = self._blocks[bid]
        assert blk.ref == 0 and blk.index_key is None
        blk.ref = 1
        blk.frozen = False
        blk.tokens = []
        return bid

    def _evict(self) -> int:
        bid, _ = self._evictable.popitem(last=False)     # LRU
        self._drop_index(bid)
        self.evictions += 1
        return bid

    def _drop_index(self, bid: int) -> None:
        blk = self._blocks[bid]
        kind, key = blk.index_key[0], blk.index_key[1]
        if kind == "full":
            if self._full.get(key) == bid:
                del self._full[key]
        else:
            group = self._partial.get(key, {})
            group.pop(bid, None)
            if not group:
                self._partial.pop(key, None)
        blk.index_key = None
        blk.frozen = False

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    @property
    def live_sequences(self) -> int:
        return len(self._seqs)

    def stats(self) -> Dict[str, Any]:
        """Pool health: occupancy, internal fragmentation (allocated rows
        never written, over live blocks), prefix hit rate, CoW and
        eviction counters."""
        live = [b for b in self._blocks if b.ref > 0]
        used_rows = sum(len(b.tokens) for b in live)
        cap_rows = len(live) * self.page_size
        pb = self.page_bytes
        return {
            "n_blocks": self.n_blocks,
            "page_size": self.page_size,
            "kv_dtype": self.kv_dtype,
            "page_bytes": pb,
            "pool_bytes": None if pb is None else pb * self.n_blocks,
            "live_bytes": None if pb is None else pb * len(live),
            "free_blocks": len(self._free),
            "cached_blocks": len(self._evictable),
            "live_blocks": len(live),
            "reserved_blocks": self._reserved_total,
            "indexed_full_pages": len(self._full),
            "indexed_partial_pages": sum(len(g) for g in self._partial.values()),
            "fragmentation": 1.0 - used_rows / cap_rows if cap_rows else 0.0,
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "hit_rate": (self.hit_tokens / self.lookup_tokens
                         if self.lookup_tokens else 0.0),
            "n_admitted": self.n_admitted,
            "n_admit_deferred": self.n_admit_deferred,
            "cow_count": self.cow_count,
            "evictions": self.evictions,
        }

    def check_integrity(self) -> None:
        """Assert the conservation invariants (see module docstring)."""
        free = list(self._free)
        assert len(free) == len(set(free)), "duplicate block in free list"
        refs = {i: 0 for i in range(self.n_blocks)}
        for seq in self._seqs.values():
            assert seq.n_tokens == len(seq.tokens)
            assert len(seq.table) == len(set(seq.table)), \
                "block repeated within one table"
            for bid in seq.table:
                refs[bid] += 1
        for blk in self._blocks:
            assert blk.ref == refs[blk.bid], (
                f"block {blk.bid}: ref {blk.ref} != {refs[blk.bid]} tables")
            states = [blk.bid in set(free), blk.bid in self._evictable,
                      blk.ref > 0]
            assert sum(states) == 1, f"block {blk.bid} in states {states}"
            if blk.bid in self._evictable:
                assert blk.index_key is not None, \
                    f"cached block {blk.bid} not indexed"
            if blk.index_key is not None:
                assert blk.frozen, f"indexed block {blk.bid} not frozen"
        assert self._reserved_total == sum(s.reserved
                                           for s in self._seqs.values())
        assert self._reserved_total <= len(free) + len(self._evictable), \
            "reservations exceed reclaimable blocks"
        for key, bid in self._full.items():
            assert self._blocks[bid].index_key == ("full", key)
        for chain, group in self._partial.items():
            for bid, rows in group.items():
                assert self._blocks[bid].index_key == ("partial", chain)
                assert tuple(self._blocks[bid].tokens) == rows
