"""Program-backed serving engine: async request scheduling, chunked
prefill, per-token streaming.

This is where the repo's two halves meet: the staged compilation pipeline
(``compile()`` → :class:`~repro.core.program.Program`) becomes the serving
hot path.  Both engine steps are compiled Programs over the GraphIR LM
(:mod:`repro.models.graph_lm`) — so backend selection policies, int8
quantization and the persistent autotune cache all apply to sustained
traffic, not just offline evaluation:

* decode Program — tokens (B, 1) + caches → next-token logits, one call
  per engine decode tick over the whole fixed slot batch;
* prefill Program — tokens (B, chunk) + caches → per-position logits; long
  prompts are split into fixed-size chunks *interleaved with decode ticks*
  so a newly admitted long prompt never stalls in-flight decodes for more
  than ~one chunk (the bounded inter-token gap serve_bench measures).

Scheduling is deterministic and tick-based (wall-clock only feeds
metrics): :class:`~repro.runtime.batching.SlotScheduler` supplies priority
FIFO admission with bounded-queue admission control; per-request deadlines
(in ticks) drop expired work from the queue and from slots.  Tokens stream
to the caller via ``on_token`` callbacks the moment they are decoded;
:class:`AsyncEngine` wraps that into ``async for`` iteration.

Exactness contract: under greedy decoding the engine's outputs are
token-exact against :class:`UnbatchedReference` — a no-batching loop over
B=1 Programs compiled from the same graphs — for both fp32 and int8
Programs.  For int8 this requires every Program variant to share one set
of calibrated activation scales (see :func:`build_lm_serving`), because
dynamic per-batch scales would make a request's tokens depend on its
batch neighbours.

Self-healing (``self_heal=True``): every tick's Program call runs under
the :mod:`repro.ft` watchdogs — a :class:`~repro.ft.watchdog.HangDetector`
deadline (``hang_timeout``) and a :class:`~repro.ft.watchdog.StepWatchdog`
straggler tracker.  A tick that raises, or that overruns the hang
deadline, is DISCARDED: the engine restores the block pool to the
checkpoint taken at the start of the tick (:meth:`Engine.checkpoint` —
per-slot prompt + generated tokens + committed row count + block table,
plus a :meth:`~repro.runtime.kv_cache.BlockPool.snapshot`), tears the
slots down, and requeues every in-flight request at its original queue
position.  Resume is PAGE-LEVEL on every stepper: a requeued request
keeps every committed KV row it had — the paged stepper keeps its
sequence and block tables (int8 scale sidecars live in the same
block-id-indexed arrays, so they survive with their pages), and the
dense stepper keeps its per-slot cache rows, relocating them when the
request is re-admitted to a different slot — so prefill fast-forwards
past everything already computed and only the failed tick's token
position is re-executed.  The exactness contract extends across
recovery: greedy output after a crash or hang is token-identical to an
uninterrupted run, and no token is ever re-emitted to a streaming
callback (``tests/test_fault_injection.py``).

Tier-aware overload control (``tier_aware=True``): admission shedding
and preemption become scheduling decisions driven by request priority
(the loadgen's :class:`~repro.runtime.loadgen.TierSpec` tiers).  A full
queue sheds the lowest-priority queued request to make room for a
higher-priority arrival instead of turning the arrival away, and when
the highest-priority queued request is about to blow its TTFT budget
(``slo_ttft_ticks`` and/or its deadline) while every slot is busy, the
engine preempts the lowest-priority running slot.  A preempted request
requeues at its original position and resumes through the page-level
path above — preemption costs pages (they stay reserved), not
recompute.
"""

from __future__ import annotations

import asyncio
import time
from contextlib import nullcontext
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import compile
from repro.core.selector import BackendPolicy, FixedPolicy
from repro.ft.coordinator import Coordinator
from repro.ft.watchdog import HangDetector, StepWatchdog
from repro.models.graph_lm import (GraphLMConfig, build_decode_graph,
                                   build_draft_graph,
                                   build_paged_decode_graph,
                                   build_paged_prefill_graph,
                                   build_paged_verify_graph,
                                   build_paged_verify_seq_graph,
                                   build_prefill_graph,
                                   build_spec_commit_graph,
                                   build_verify_graph,
                                   expand_spec_ranges, init_cache_inputs,
                                   init_lm_params, init_paged_cache_inputs)
from repro.runtime.batching import SlotScheduler
from repro.runtime.kv_cache import BlockPool, kv_page_bytes

__all__ = [
    "EngineRequest", "EngineMetrics", "Engine", "AsyncEngine",
    "ProgramStepper", "PagedProgramStepper", "UnbatchedReference",
    "build_lm_serving", "padded_len",
    "EngineCheckpoint", "CheckpointSlot", "TickFailure",
]


def padded_len(n: int, chunk: int) -> int:
    """Prompt length rounded up to a whole number of prefill chunks."""
    return -(-max(n, 1) // chunk) * chunk


# --------------------------------------------------------------------------- #
# Requests and metrics
# --------------------------------------------------------------------------- #

@dataclass
class EngineRequest:
    """One generation request.  Terminal states are mutually exclusive:
    ``done`` (finished normally) or ``dropped`` (reason string — admission
    rejection or deadline expiry); partial output survives a drop."""

    uid: int
    prompt: np.ndarray                      # (prompt_len,) int32
    max_new_tokens: int
    priority: int = 0
    tier: Optional[str] = None              # workload tier label (loadgen)
    deadline_tick: Optional[int] = None     # absolute engine tick to finish by
    on_token: Optional[Callable[["EngineRequest", int], None]] = None
    on_finish: Optional[Callable[["EngineRequest"], None]] = None

    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    dropped: Optional[str] = None
    submit_tick: int = -1
    first_token_tick: Optional[int] = None
    finish_tick: Optional[int] = None
    n_requeues: int = 0                     # times we were requeued
    #                                         (recovery or tier preemption)
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    max_gap_s: float = 0.0                  # max wall gap between our tokens
    max_gap_ticks: int = 0                  # same, in deterministic ticks
    _t_last_token: Optional[float] = None
    _last_token_tick: Optional[int] = None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def ttft_ticks(self) -> Optional[int]:
        """Deterministic TTFT: engine ticks from submit to first token.
        A prefix hit shrinks this (prefill fast-forwards past the reused
        rows), which is how the paged cache's latency win is asserted
        without wall-clock noise."""
        return (None if self.first_token_tick is None
                else self.first_token_tick - self.submit_tick)


def _pct(xs: Sequence[float], q: float) -> Optional[float]:
    """Percentile of a sample list; ``None`` for an empty window.  A run
    with zero finished requests has NO latency data — serializing that as
    0.0 would report a perfect p99, so "no data" is ``null`` in the JSON
    record and rendered as "—" by ``repro.tools.report``.  Single-sample
    and all-equal windows return that value for every q (linear
    interpolation over one distinct point) — edge cases pinned by
    ``tests/test_engine_metrics.py``.
    """
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def _pct_dict(xs: Sequence[float]) -> Dict[str, Any]:
    """p50/p95/p99 plus ``n_samples`` so a consumer can tell "fast" from
    "no data" (percentiles are ``None`` iff ``n_samples == 0``)."""
    return {"p50": _pct(xs, 50), "p95": _pct(xs, 95), "p99": _pct(xs, 99),
            "n_samples": len(xs)}


@dataclass
class EngineMetrics:
    """Aggregated serving metrics — the record ``serve_bench`` emits as
    JSON and ``repro.tools.report.serving_table`` renders."""

    n_finished: int = 0
    n_dropped: int = 0
    n_rejected: int = 0
    ticks: int = 0
    decode_ticks: int = 0
    prefill_ticks: int = 0
    busy_slot_ticks: int = 0    # slots doing real work, summed over ticks
    n_slots: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0
    latencies_s: List[float] = field(default_factory=list)
    ttfts_s: List[float] = field(default_factory=list)
    max_intertoken_gap_s: float = 0.0
    # self-healing counters (all zero when self_heal is off)
    failed_ticks: int = 0       # discarded ticks (crash + hang)
    n_crash_failures: int = 0
    n_hang_failures: int = 0
    n_recoveries: int = 0
    requeued_requests: int = 0  # slot preemptions summed over recoveries
    straggler_ticks: int = 0    # StepWatchdog rolling-median flags
    recovered_rows: int = 0     # KV rows resumed from surviving state
    #                             (pages / dense slot rows) instead of
    #                             being re-prefilled after a requeue
    # tier-aware overload counters (all zero when tier_aware is off)
    n_preempted: int = 0        # running low-tier slots preempted for
    #                             a high-tier request at TTFT risk
    n_tier_shed: int = 0        # queued low-tier requests shed to make
    #                             room for a higher-tier arrival
    # speculative decoding (all zero when spec_k == 0)
    spec_ticks: int = 0         # draft+verify ticks (counted in decode_ticks)
    spec_proposed: int = 0      # draft tokens offered to verification
    spec_accepted: int = 0      # draft tokens the target model agreed with
    # decode-phase throughput: tokens emitted by decode/spec ticks over the
    # wall time spent inside those ticks — the honest numerator/denominator
    # for a speculative-vs-baseline speedup (prefill is identical in both)
    decode_tokens: int = 0
    decode_wall_s: float = 0.0

    @property
    def busy_slot_fraction(self) -> float:
        return self.busy_slot_ticks / max(self.ticks * self.n_slots, 1)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def accept_rate(self) -> float:
        return (self.spec_accepted / self.spec_proposed
                if self.spec_proposed > 0 else 0.0)

    @property
    def decode_tokens_per_s(self) -> float:
        return (self.decode_tokens / self.decode_wall_s
                if self.decode_wall_s > 0 else 0.0)

    def summary(self) -> Dict[str, Any]:
        return {
            "n_finished": self.n_finished,
            "n_dropped": self.n_dropped,
            "n_rejected": self.n_rejected,
            "ticks": self.ticks,
            "decode_ticks": self.decode_ticks,
            "prefill_ticks": self.prefill_ticks,
            "tokens_out": self.tokens_out,
            "wall_s": self.wall_s,
            "tokens_per_s": self.tokens_per_s,
            "busy_slot_fraction": self.busy_slot_fraction,
            "latency_s": _pct_dict(self.latencies_s),
            "ttft_s": _pct_dict(self.ttfts_s),
            "max_intertoken_gap_s": self.max_intertoken_gap_s,
            "self_heal": {
                "failed_ticks": self.failed_ticks,
                "n_crash_failures": self.n_crash_failures,
                "n_hang_failures": self.n_hang_failures,
                "n_recoveries": self.n_recoveries,
                "requeued_requests": self.requeued_requests,
                "straggler_ticks": self.straggler_ticks,
                "recovered_rows": self.recovered_rows,
            },
            "overload": {
                "n_preempted": self.n_preempted,
                "n_tier_shed": self.n_tier_shed,
            },
            "spec": {
                "spec_ticks": self.spec_ticks,
                "proposed": self.spec_proposed,
                "accepted": self.spec_accepted,
                "accept_rate": self.accept_rate,
                "decode_tokens": self.decode_tokens,
                "decode_wall_s": self.decode_wall_s,
                "decode_tokens_per_s": self.decode_tokens_per_s,
            },
        }


# --------------------------------------------------------------------------- #
# Program-backed step functions
# --------------------------------------------------------------------------- #

class _TPFirstPolicy(BackendPolicy):
    """Delegating wrapper used when serving on a mesh: the attention ops
    take their ``tp`` (shard_map-over-heads) backend whenever it is
    supported — i.e. the mesh's "model" axis divides both head counts —
    and every other decision goes to the wrapped policy.  GQA-small
    models simply never satisfy ``tp``'s supports() and fall through to
    the replicated backends."""

    def __init__(self, base: BackendPolicy):
        self.base = base

    def choose(self, node, in_specs):
        from repro.core.registry import backends_for
        from repro.kernels.serving_ops import TP_ATTENTION_OPS
        if node.op in TP_ATTENTION_OPS and \
                "tp" in backends_for(node.op, in_specs, node.attrs):
            return "tp"
        return self.base.choose(node, in_specs)


class ProgramStepper:
    """Owns the two compiled Programs plus the cache arrays they thread.

    Step dispatch goes through :meth:`Program.bind` — the positional
    fast-call path — because at serving batch sizes the per-call Python
    overhead of the kwargs path is a measurable fraction of a decode tick
    (``serve_bench`` reports both).
    """

    paged = False

    def __init__(self, cfg: GraphLMConfig, params: Mapping[str, Any], *,
                 n_slots: int, chunk: int, cache_cap: int,
                 policy: Optional[BackendPolicy] = None,
                 quantize: Optional[str] = None,
                 calib_ranges: Optional[Mapping[str, Any]] = None,
                 spec_k: int = 0, draft_layers: Optional[int] = None,
                 mesh: Optional[Any] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.chunk = chunk
        self.cache_cap = cache_cap
        self.mesh = mesh
        if mesh is not None:
            policy = _TPFirstPolicy(policy or FixedPolicy())
        with self._mesh_ctx():
            dec_g = build_decode_graph(cfg, params, batch=n_slots,
                                       cache_cap=cache_cap)
            pre_g = build_prefill_graph(cfg, params, batch=n_slots,
                                        chunk=chunk, cache_cap=cache_cap)
            self.decode_program = compile(dec_g, policy=policy,
                                          quantize=quantize,
                                          calib_ranges=calib_ranges,
                                          mesh=mesh)
            self.prefill_program = compile(pre_g, policy=policy,
                                           quantize=quantize,
                                           calib_ranges=calib_ranges,
                                           mesh=mesh)
            self.cache_names = [v for v in dec_g.outputs[1:]]  # new_cache_*
            cache_inputs = sorted(init_cache_inputs(cfg, 1, 1))
            self._cache_input_names = cache_inputs
            self._input_names = ("tokens", "start", "n_new", *cache_inputs)
            # caches are threaded call-to-call and never reused -> donate
            # them (aliased in place on backends that support it)
            self._dec = self.decode_program.bind(*self._input_names,
                                                 donate=cache_inputs)
            self._pre = self.prefill_program.bind(*self._input_names,
                                                  donate=cache_inputs)
            self.caches: Dict[str, Any] = self._place_caches(
                init_cache_inputs(cfg, n_slots, cache_cap))
            verify_g = None
            if spec_k > 0:
                verify_g = build_verify_graph(cfg, params, batch=n_slots,
                                              width=spec_k + 1,
                                              cache_cap=cache_cap)
            self._init_spec(params, policy=policy, quantize=quantize,
                            calib_ranges=calib_ranges, spec_k=spec_k,
                            draft_layers=draft_layers, verify_graph=verify_g)

    def _mesh_ctx(self):
        """serving-mesh context for compiles and Program calls (no-op when
        single-device): publishes the mesh to the ``tp`` backends' supports
        guards at compile time and their shard_map bodies at trace time."""
        if self.mesh is None:
            return nullcontext()
        from repro.kernels.serving_ops import serving_mesh
        return serving_mesh(self.mesh)

    def _place_caches(self, caches: Mapping[str, Any]) -> Dict[str, Any]:
        """Device cache arrays; on a mesh each is ``jax.device_put`` to the
        NamedSharding the decode Program's partition stamped for it, so
        pools/caches/sidecars start life sharded instead of being
        resharded on the first call."""
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in caches.items()}
        specs = self.decode_program.partition["specs"]
        return {k: jax.device_put(
                    jnp.asarray(v),
                    jax.sharding.NamedSharding(self.mesh, specs[k]))
                for k, v in caches.items()}

    def _call(self, fn, tokens, start, n_new, *extra):
        cache_args = [self.caches[n] for n in sorted(self.caches)]
        with self._mesh_ctx():
            outs = fn(jnp.asarray(tokens), jnp.asarray(start),
                      jnp.asarray(n_new),
                      *[jnp.asarray(e) for e in extra], *cache_args)
        logits = np.asarray(outs[0])
        for name, arr in zip(self.cache_names, outs[1:]):
            self.caches[name.replace("new_", "")] = arr
        return logits

    def _init_spec(self, params: Mapping[str, Any], *,
                   policy: Optional[BackendPolicy],
                   quantize: Optional[str],
                   calib_ranges: Optional[Mapping[str, Any]],
                   spec_k: int, draft_layers: Optional[int],
                   verify_graph, verify_donate: bool = True,
                   verify_bind_names: Optional[Tuple[str, ...]] = None,
                   verify_spec_ranges: bool = False) -> None:
        """Compile the speculative-decoding Programs (shared by the dense
        and paged steppers; ``verify_graph`` is the flavor-specific
        batched-verify variant of the target model).

        The DRAFT model is early-exit self-speculative: the target's
        first ``draft_layers`` layers plus its embedding and head, so no
        second set of weights exists and — because layer value names
        match the target's lower layers — the one shared calibration
        covers it (:func:`~repro.models.graph_lm.expand_spec_ranges`
        maps the ranges onto the unrolled step-suffixed names).  Its
        caches are PRIVATE per-slot dense buffers sized
        ``cache_cap + spec_k + 1`` (a draft call writes up to spec_k+1
        rows past the committed length and is never rolled back — stale
        rows are simply overwritten by the next catch-up or draft call,
        and draft attention never reads past its kv length)."""
        self.spec_k = spec_k
        if spec_k == 0:
            return
        cfg = self.cfg
        dl = (draft_layers if draft_layers is not None
              else max(1, cfg.n_layers // 2))
        if not 1 <= dl <= cfg.n_layers:
            raise ValueError(f"draft_layers {dl} outside "
                             f"[1, {cfg.n_layers}]")
        self.draft_layers = dl
        draft_cfg = replace(cfg, n_layers=dl)
        self.draft_cap = self.cache_cap + spec_k + 1
        draft_ranges = (expand_spec_ranges(dict(calib_ranges), spec_k)
                        if calib_ranges is not None else None)
        draft_g = build_draft_graph(draft_cfg, dict(params),
                                    batch=self.n_slots,
                                    cache_cap=self.draft_cap, spec_k=spec_k)
        draft_pre_g = build_prefill_graph(draft_cfg, dict(params),
                                          batch=self.n_slots,
                                          chunk=self.chunk,
                                          cache_cap=self.draft_cap)
        self.draft_program = compile(draft_g, policy=policy,
                                     quantize=quantize,
                                     calib_ranges=draft_ranges)
        self.draft_prefill_program = compile(draft_pre_g, policy=policy,
                                             quantize=quantize,
                                             calib_ranges=calib_ranges)
        # the kv8 seq verify's value names are step-suffixed like the
        # draft's, so it needs the expanded calibration to see the same
        # static scales the decode Program uses
        self.verify_program = compile(
            verify_graph, policy=policy, quantize=quantize,
            calib_ranges=draft_ranges if verify_spec_ranges
            else calib_ranges)
        draft_cache_inputs = sorted(init_cache_inputs(draft_cfg, 1, 1))
        names = ("tokens", "start", "n_new", *draft_cache_inputs)
        self._draft = self.draft_program.bind(*names,
                                              donate=draft_cache_inputs)
        self._draft_pre = self.draft_prefill_program.bind(
            *names, donate=draft_cache_inputs)
        # the kv8 verify program only READS the pages (its cache inputs
        # are not threaded back out), so donating them would invalidate
        # live buffers — the commit program gets the donation instead
        self._ver = self.verify_program.bind(
            *(verify_bind_names if verify_bind_names is not None
              else self._input_names),
            donate=self._cache_input_names if verify_donate else ())
        self._draft_cache_names = [v for v in draft_g.outputs[spec_k:]]
        self.draft_caches: Dict[str, Any] = {
            k: jnp.asarray(v)
            for k, v in init_cache_inputs(draft_cfg, self.n_slots,
                                          self.draft_cap).items()}

    def relocate_slots(self, moves: Sequence[Tuple[int, int]]) -> None:
        """Copy per-slot cache rows ``src -> dst`` — dense page-level
        resume for a request re-admitted to a different slot than the
        one whose rows it committed.  One batched gather per cache
        array (axis 0 is the slot axis): every source is read before
        any destination is written, so a pair of swapped slots
        relocates correctly.  Only the main caches move; private draft
        caches are rebuilt by draft catch-up (resume resets
        ``draft_len`` to 0), the same path a cold admission takes."""
        if not moves:
            return
        src = jnp.asarray([m[0] for m in moves], jnp.int32)
        dst = jnp.asarray([m[1] for m in moves], jnp.int32)
        for name in list(self.caches):
            arr = self.caches[name]
            self.caches[name] = arr.at[dst].set(arr[src])

    def backend_summary(self) -> Dict[str, Dict[str, Dict[str, int]]]:
        """Per-phase, per-op backend assignment counts — what the policy
        actually chose for the serving hot path.  Shape:
        ``{"prefill"|"decode"[|"verify"|"draft"]: {op: {backend:
        node_count}}}``; rendered by ``serve_bench --json`` and
        ``repro.tools.report.backend_table``."""
        phases = [("prefill", self.prefill_program),
                  ("decode", self.decode_program)]
        if self.spec_k:
            phases += [("verify", self.verify_program),
                       ("draft", self.draft_program)]
        out: Dict[str, Dict[str, Dict[str, int]]] = {}
        for phase, prog in phases:
            per_op: Dict[str, Dict[str, int]] = {}
            assignment = prog.assignment
            for node in prog.graph.nodes:
                counts = per_op.setdefault(node.op, {})
                b = assignment[node.name]
                counts[b] = counts.get(b, 0) + 1
            out[phase] = per_op
        return out

    def prefill(self, tokens: np.ndarray, start: np.ndarray,
                n_new: np.ndarray) -> np.ndarray:
        """tokens (B, chunk) → logits (B, chunk, V); caches advance."""
        return self._call(self._pre, tokens, start, n_new)

    def decode(self, tokens: np.ndarray, start: np.ndarray,
               n_new: np.ndarray) -> np.ndarray:
        """tokens (B, 1) → logits (B, V); caches advance."""
        return self._call(self._dec, tokens, start, n_new)

    def verify(self, tokens: np.ndarray, start: np.ndarray,
               n_new: np.ndarray) -> np.ndarray:
        """tokens (B, spec_k+1) — committed next token + draft proposals —
        → per-position logits (B, spec_k+1, V); MAIN caches advance by
        ``n_new[b]`` rows (rejected rows are garbage past the committed
        length the engine rolls the bookkeeping back to)."""
        return self._call(self._ver, tokens, start, n_new)

    def _draft_cache_args(self) -> List[Any]:
        return [self.draft_caches[n] for n in sorted(self.draft_caches)]

    def draft_prefill(self, tokens: np.ndarray, start: np.ndarray,
                      n_new: np.ndarray) -> np.ndarray:
        """Advance the private draft caches over already-committed tokens
        (cold start, prefix-hit fast-forward and post-recovery resume are
        all just ``draft_len < length`` catch-up).  Logits are returned
        for symmetry but unused — drafting starts from the committed next
        token, not from these."""
        with self._mesh_ctx():
            outs = self._draft_pre(jnp.asarray(tokens), jnp.asarray(start),
                                   jnp.asarray(n_new),
                                   *self._draft_cache_args())
        for name, arr in zip(self._draft_cache_names, outs[1:]):
            self.draft_caches[name.replace("new_", "")] = arr
        return np.asarray(outs[0])

    def draft(self, tokens: np.ndarray, start: np.ndarray,
              n_new: np.ndarray) -> np.ndarray:
        """One unrolled draft call: tokens (B, 1) — the committed next
        token — → (B, spec_k) greedy proposals; draft caches advance
        spec_k+1 rows (the final row makes a full accept need no
        catch-up before the next draft)."""
        with self._mesh_ctx():
            outs = self._draft(jnp.asarray(tokens), jnp.asarray(start),
                               jnp.asarray(n_new), *self._draft_cache_args())
        k = self.spec_k
        for name, arr in zip(self._draft_cache_names, outs[k:]):
            self.draft_caches[name.replace("new_", "")] = arr
        return np.concatenate([np.asarray(o) for o in outs[:k]], axis=1)


class PagedProgramStepper(ProgramStepper):
    """Paged variant: the per-slot dense caches are replaced by one shared
    page pool per layer plus per-sequence block tables
    (:class:`repro.runtime.kv_cache.BlockPool` owns the host-side block
    bookkeeping; this class owns the device page arrays and the compiled
    paged Programs).

    The engine's view is unchanged — same ``prefill(tokens, start,
    n_new)`` / ``decode(...)`` signatures — because this class records the
    written rows with the pool itself (it sees the token values and
    ``n_new``), applies any pending copy-on-write page copies to the
    device arrays, and threads the freshly built block tables into the
    Program call.  What the engine gains on top is the admission
    interface: :meth:`try_admit` (claim cached prefix blocks + reserve
    worst-case growth; ``None`` = not enough blocks right now),
    :meth:`attach` and :meth:`release`.
    """

    paged = True

    def __init__(self, cfg: GraphLMConfig, params: Mapping[str, Any], *,
                 n_slots: int, chunk: int, page_size: int, n_blocks: int,
                 max_pages: int, kv_dtype: str = "float32",
                 policy: Optional[BackendPolicy] = None,
                 quantize: Optional[str] = None,
                 calib_ranges: Optional[Mapping[str, Any]] = None,
                 spec_k: int = 0, draft_layers: Optional[int] = None,
                 mesh: Optional[Any] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.chunk = chunk
        self.page_size = page_size
        self.n_blocks = n_blocks
        self.max_pages = max_pages
        self.kv_dtype = kv_dtype
        self.cache_cap = max_pages * page_size   # per-sequence logical cap
        self.mesh = mesh
        if mesh is not None:
            policy = _TPFirstPolicy(policy or FixedPolicy())
        with self._mesh_ctx():
            self._paged_init(params, policy=policy, quantize=quantize,
                             calib_ranges=calib_ranges, spec_k=spec_k,
                             draft_layers=draft_layers)

    def _paged_init(self, params, *, policy, quantize, calib_ranges,
                    spec_k, draft_layers):
        cfg, n_slots, chunk = self.cfg, self.n_slots, self.chunk
        page_size, n_blocks = self.page_size, self.n_blocks
        max_pages, kv_dtype = self.max_pages, self.kv_dtype
        mesh = self.mesh
        dec_g = build_paged_decode_graph(cfg, params, batch=n_slots,
                                         n_blocks=n_blocks,
                                         page_size=page_size,
                                         max_pages=max_pages,
                                         kv_dtype=kv_dtype)
        pre_g = build_paged_prefill_graph(cfg, params, batch=n_slots,
                                          chunk=chunk, n_blocks=n_blocks,
                                          page_size=page_size,
                                          max_pages=max_pages,
                                          kv_dtype=kv_dtype)
        self.decode_program = compile(dec_g, policy=policy, quantize=quantize,
                                      calib_ranges=calib_ranges, mesh=mesh)
        self.prefill_program = compile(pre_g, policy=policy, quantize=quantize,
                                       calib_ranges=calib_ranges, mesh=mesh)
        self.cache_names = [v for v in dec_g.outputs[1:]]
        cache_inputs = sorted(init_paged_cache_inputs(cfg, 1, 1,
                                                      kv_dtype=kv_dtype))
        self._cache_input_names = cache_inputs
        self._input_names = ("tokens", "start", "n_new", "block_tables",
                             *cache_inputs)
        self._dec = self.decode_program.bind(*self._input_names,
                                             donate=cache_inputs)
        self._pre = self.prefill_program.bind(*self._input_names,
                                              donate=cache_inputs)
        self.caches: Dict[str, Any] = self._place_caches(
            init_paged_cache_inputs(cfg, n_blocks, page_size,
                                    kv_dtype=kv_dtype))
        self.pool = BlockPool(
            n_blocks, page_size, kv_dtype=kv_dtype,
            page_bytes=kv_page_bytes(cfg.n_layers, cfg.n_kv_heads,
                                     cfg.d_head, page_size, kv_dtype))
        self._slot_seq: Dict[int, int] = {}
        verify_g = None
        ver_bind: Optional[Tuple[str, ...]] = None
        w = spec_k + 1
        if spec_k > 0 and kv_dtype == "int8":
            # quantize-on-write makes int8 page bytes history-dependent,
            # so the kv8 verify is the decode step unrolled width times in
            # one Program (bit-identical logits to plain decode) rather
            # than the chunk-shaped batched verify the fp32 flavors use
            verify_g = build_paged_verify_seq_graph(
                cfg, params, batch=n_slots, width=w, n_blocks=n_blocks,
                page_size=page_size, max_pages=max_pages)
            ver_bind = ("start", "block_tables",
                        *[f"tokens.s{j}" for j in range(w)],
                        *[f"n_new.s{j}" for j in range(w)],
                        *cache_inputs)
        elif spec_k > 0:
            verify_g = build_paged_verify_graph(cfg, params, batch=n_slots,
                                                width=w,
                                                n_blocks=n_blocks,
                                                page_size=page_size,
                                                max_pages=max_pages,
                                                kv_dtype=kv_dtype)
        self._init_spec(params, policy=policy, quantize=quantize,
                        calib_ranges=calib_ranges, spec_k=spec_k,
                        draft_layers=draft_layers, verify_graph=verify_g,
                        verify_donate=kv_dtype != "int8",
                        verify_bind_names=ver_bind,
                        verify_spec_ranges=kv_dtype == "int8")
        if spec_k > 0 and kv_dtype == "int8":
            commit_g = build_spec_commit_graph(
                cfg, batch=n_slots, width=w, n_blocks=n_blocks,
                page_size=page_size, max_pages=max_pages)
            self.spec_commit_program = compile(commit_g, policy=policy)
            # j-major, i-minor: the exact order the seq verify graph
            # emits its per-stage fp32 rows in
            kv_names = [x for j in range(w) for i in range(cfg.n_layers)
                        for x in (f"k_new{i}.s{j}", f"v_new{i}.s{j}")]
            self._commit = self.spec_commit_program.bind(
                "start", "block_tables",
                *[f"n_new.s{j}" for j in range(w)], *kv_names,
                *cache_inputs, donate=cache_inputs)
            self._pending_kv: Optional[List[Any]] = None

    # ---------------------------- admission --------------------------- #
    def try_admit(self, prompt: np.ndarray,
                  max_new_tokens: int) -> Optional[Tuple[int, int]]:
        """Claim the request's cached prefix and reserve its worst-case
        block count.  Returns ``(sequence id, reused_tokens)`` or ``None``
        when the pool cannot currently cover it (leave it queued)."""
        return self.pool.admit([int(t) for t in prompt], max_new_tokens)

    def attach(self, slot: int, sid: int) -> None:
        self._slot_seq[slot] = sid

    def release(self, slot: int, *, register: bool = True) -> None:
        """Return the slot's blocks to the pool; a finished sequence
        (``register=True``) leaves its pages in the prefix index for
        future prompts to share."""
        self.pool.release(self._slot_seq.pop(slot), register=register)

    # ------------------------------ steps ----------------------------- #
    def _record_writes(self, tokens: np.ndarray, start: np.ndarray,
                       n_new: np.ndarray) -> None:
        """Mirror this step's row writes into the pool (allocating pages
        and triggering CoW), then apply the resulting page copies to the
        device arrays BEFORE the Program call overwrites the new rows."""
        for s in range(self.n_slots):
            n = int(n_new[s])
            if n == 0:
                continue
            sid = self._slot_seq[s]
            seq = self.pool.sequence(sid)
            assert seq.n_tokens == int(start[s]), \
                f"slot {s}: pool at {seq.n_tokens}, engine writing {start[s]}"
            self.pool.append(sid, [int(t) for t in tokens[s, :n]])
        copies = self.pool.take_copies()
        if copies:
            src = jnp.asarray([c[0] for c in copies], jnp.int32)
            dst = jnp.asarray([c[1] for c in copies], jnp.int32)
            # axis 0 is the block id for every cache array — the int8
            # page pools AND their (N, Hk) scale sidecars — so one gather/
            # scatter keeps a quantized CoW copy bit-identical to its source
            for name in list(self.caches):
                arr = self.caches[name]
                self.caches[name] = arr.at[dst].set(arr[src])

    def _tables(self) -> np.ndarray:
        bt = np.zeros((self.n_slots, self.max_pages), np.int32)
        for s, sid in self._slot_seq.items():
            table = self.pool.block_table(sid)
            bt[s, :len(table)] = table
        return bt

    def prefill(self, tokens: np.ndarray, start: np.ndarray,
                n_new: np.ndarray) -> np.ndarray:
        self._record_writes(tokens, start, n_new)
        return self._call(self._pre, tokens, start, n_new, self._tables())

    def decode(self, tokens: np.ndarray, start: np.ndarray,
               n_new: np.ndarray) -> np.ndarray:
        self._record_writes(tokens, start, n_new)
        return self._call(self._dec, tokens, start, n_new, self._tables())

    def verify(self, tokens: np.ndarray, start: np.ndarray,
               n_new: np.ndarray) -> np.ndarray:
        """fp32 pages: speculative rows go through the normal paged write
        path and the engine calls :meth:`BlockPool.truncate` afterward to
        roll the rejected tail back (pages past the committed length are
        appended-to-only this tick, the same argument
        ``BlockPool.snapshot`` relies on for recovery — and fp32 page
        writes are exact, so rejected rows leave no residue).

        int8 pages: the verify program is the decode step unrolled
        ``n_new``-wide with its quantize-on-write page state threaded
        INTERNALLY and then discarded — each stage's logits are
        bit-identical to what plain decode would produce at that
        position, but the live pages are left untouched (a rejected
        row raising a page scale would lossily requantize its committed
        neighbours).  Pool bookkeeping + CoW still happen up front so
        the block tables cover the speculative rows; the per-stage fp32
        K/V rows come back and are stashed for :meth:`commit_spec` to
        replay after acceptance."""
        if self.kv_dtype == "int8":
            self._record_writes(tokens, start, n_new)
            w = self.spec_k + 1
            cols = [jnp.asarray(tokens[:, j:j + 1]) for j in range(w)]
            masks = [jnp.asarray((n_new > j).astype(np.int32))
                     for j in range(w)]
            cache_args = [self.caches[n] for n in sorted(self.caches)]
            outs = self._ver(jnp.asarray(start),
                             jnp.asarray(self._tables()),
                             *cols, *masks, *cache_args)
            self._pending_kv = list(outs[w:])
            return np.stack([np.asarray(o) for o in outs[:w]], axis=1)
        self._record_writes(tokens, start, n_new)
        return self._call(self._ver, tokens, start, n_new, self._tables())

    def commit_spec(self, start: np.ndarray, n_acc: np.ndarray) -> None:
        """kv8 only: replay the accepted prefix (``n_acc[b]`` rows) of the
        verify call's write sequence against the live pages.  The pool
        already covers these rows (recorded before the verify call, then
        :meth:`BlockPool.truncate`\\ d back to the accepted length), so
        there is no pool work here — just the write-chain Program.
        Replaying a write that already happened is bit-idempotent
        (identical rows quantize to identical bytes and never raise a
        page scale), which is what makes a crashed-and-retried or
        hang-discarded commit recoverable."""
        w = self.spec_k + 1
        masks = [jnp.asarray((n_acc > j).astype(np.int32))
                 for j in range(w)]
        cache_args = [self.caches[n] for n in sorted(self.caches)]
        outs = self._commit(jnp.asarray(start),
                            jnp.asarray(self._tables()),
                            *masks, *self._pending_kv, *cache_args)
        for name, arr in zip(self.cache_names, outs):
            self.caches[name.replace("new_", "")] = arr
        self._pending_kv = None


# --------------------------------------------------------------------------- #
# The engine
# --------------------------------------------------------------------------- #

@dataclass
class _SlotState:
    req: EngineRequest
    pos: int = 0          # stream tokens prefilled so far
    length: int = 0       # valid cache entries
    next_token: int = 0
    decoding: bool = False
    # committed rows present in the PRIVATE draft cache (speculative
    # engines only).  Starts at 0 — cold start, prefix-hit fast-forward
    # and post-recovery resume are all the same "draft_len < length"
    # catch-up, which is why recovery never has to roll draft caches back
    draft_len: int = 0
    # the token stream prefill walks: the request's prompt, or — for a
    # request requeued by recovery — prompt + tokens generated before the
    # failure (re-prefilling them rebuilds the cache rows; argmax at the
    # final position is the NEXT token, so nothing is re-emitted)
    stream: Optional[np.ndarray] = None

    @property
    def prompt(self) -> np.ndarray:
        return self.req.prompt if self.stream is None else self.stream


class TickFailure(RuntimeError):
    """A guarded tick crashed or overran the hang deadline.  With
    ``self_heal`` the engine recovers internally; this escapes only when
    recovery is disabled or ``max_recoveries`` consecutive failures give
    up (a deterministic crash loop is not something to retry forever)."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclass
class CheckpointSlot:
    """In-flight state of one slot, sufficient to rebuild it: the original
    request identity, every token generated so far (the resume stream is
    ``prompt + out_tokens``), the number of committed KV rows the slot
    had written (``rows`` — what page-level resume fast-forwards past),
    and — paged — the sequence id and block table whose pages survive
    recovery."""

    slot: int
    uid: int
    prompt: np.ndarray
    out_tokens: List[int]
    rows: int = 0
    sid: Optional[int] = None
    block_table: List[int] = field(default_factory=list)

    @property
    def stream(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.prompt, np.int32),
             np.asarray(self.out_tokens, np.int32)])


@dataclass
class EngineCheckpoint:
    """Host-side engine state captured at the start of a guarded tick —
    everything recovery needs (queued requests stay in the scheduler and
    are only mutated between ticks, so they need no snapshot)."""

    tick: int
    slots: List[CheckpointSlot]
    pool: Optional[Dict[str, Any]] = None    # BlockPool.snapshot()


@dataclass
class _Resume:
    """Pending resume of a requeued in-flight request (keyed by uid).
    ``slot``/``rows`` drive dense page-level resume: the per-slot cache
    rows this request committed in ``slot`` are still valid unless an
    intervening admission overwrote them (``Engine._dense_rows`` tracks
    the current owner of every slot's rows)."""

    stream: np.ndarray
    sid: Optional[int] = None
    slot: Optional[int] = None
    rows: int = 0


class Engine:
    """Deterministic tick-based serving loop over a :class:`ProgramStepper`.

    Each :meth:`step` is one tick: expire deadlines, admit queued requests
    to free slots, then run either one prefill-chunk Program call or one
    decode Program call over the whole slot batch.  When both phases have
    work the engine alternates, which bounds any request's inter-token gap
    to roughly one chunk of someone else's prompt.
    """

    def __init__(self, stepper: ProgramStepper, *, eos_id: int = -1,
                 max_queue: Optional[int] = None,
                 self_heal: bool = False,
                 hang_timeout: Optional[float] = None,
                 max_recoveries: int = 8,
                 coordinator: Optional[Coordinator] = None,
                 host_id: str = "engine",
                 tier_aware: bool = False,
                 slo_ttft_ticks: Optional[int] = None):
        self.stepper = stepper
        self.n_slots = stepper.n_slots
        self.chunk = stepper.chunk
        self.cache_cap = stepper.cache_cap
        self.paged = stepper.paged
        self.spec_k = getattr(stepper, "spec_k", 0)
        self.eos_id = eos_id
        self.sched = SlotScheduler(self.n_slots, max_queue=max_queue)
        self.slots: List[Optional[_SlotState]] = [None] * self.n_slots
        self.tick = 0
        self.finished: List[EngineRequest] = []
        self.dropped: List[EngineRequest] = []
        self.metrics = EngineMetrics(n_slots=self.n_slots)
        self._last_was_prefill = False
        self._t0: Optional[float] = None
        # (head uid, pool version) of the last admission gate refusal —
        # skips re-running the prefix lookup every tick while nothing that
        # could free blocks has happened
        self._gate_blocked: Optional[Tuple[int, int]] = None
        # ---- tier-aware overload control ----
        self.tier_aware = tier_aware
        self.slo_ttft_ticks = slo_ttft_ticks
        # dense page-level resume: slot -> uid whose cache rows currently
        # occupy that slot (an admission overwrites them; resume checks
        # this before trusting surviving rows)
        self._dense_rows: Dict[int, int] = {}
        # ---- self-healing (ft/ watchdogs wired into the tick loop) ----
        self.self_heal = self_heal
        self.hang_timeout = hang_timeout
        self.max_recoveries = max_recoveries
        self._watchdog = StepWatchdog()
        self._hang = (HangDetector(hang_timeout, lambda: None)
                      if hang_timeout is not None else None)
        self._resume: Dict[int, _Resume] = {}      # uid -> pending resume
        self._consec_failures = 0
        self.coordinator = coordinator
        self.host_id = host_id
        if coordinator is not None:
            coordinator.register(host_id)

    # ------------------------------------------------------------------ #
    def submit(self, req: EngineRequest) -> bool:
        """Admission control: False (with ``req.dropped`` set) when the
        queue is full or the request could never fit the cache.

        The fit check uses the UNPADDED prompt length: the cache stores
        ``len(prompt) + max_new_tokens - 1`` rows at most (the final
        generated token is emitted, never written back), and prefill
        padding rows are masked out of the cache write — so a prompt of
        exactly ``cache_cap`` tokens with ``max_new_tokens == 1`` is
        admissible.  (It used to be rejected after rounding the prompt up
        to a whole number of chunks.)"""
        req.submit_tick = self.tick
        req.t_submit = time.perf_counter()
        if len(req.prompt) == 0 or req.max_new_tokens < 1:
            return self._reject(req, "empty")
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.cache_cap:
            return self._reject(req, "too_long")
        if self.paged and not self.stepper.pool.fits_ever(
                len(req.prompt), req.max_new_tokens):
            return self._reject(req, "too_long")
        if (self.tier_aware and self.sched.max_queue is not None
                and self.sched.queue_len >= self.sched.max_queue):
            # tier-aware shedding: a full queue evicts its lowest-priority
            # member (strictly below the arrival's tier) instead of turning
            # the arrival away — overload degrades the low tiers first
            victim = self.sched.shed_lowest(getattr(req, "priority", 0))
            if victim is not None:
                victim.dropped = "shed_low_tier"
                self.metrics.n_rejected += 1
                self.metrics.n_tier_shed += 1
                res = self._resume.pop(victim.uid, None)
                if res is not None and res.sid is not None:
                    # a preempted request shed from the queue still owns
                    # its pool sequence; those blocks must come back
                    self.stepper.pool.release(res.sid, register=False)
                self._finalize(victim)
        if not self.sched.submit(req):
            req.dropped = "queue_full"
            self.metrics.n_rejected += 1
            self._finalize(req)
            return False
        return True

    def _reject(self, req: EngineRequest, reason: str) -> bool:
        req.dropped = reason
        self.sched.reject(req)
        self.metrics.n_rejected += 1
        self._finalize(req)
        return False

    def _finalize(self, req: EngineRequest) -> None:
        req.finish_tick = self.tick
        req.t_done = time.perf_counter()
        if req.on_finish is not None:
            req.on_finish(req)

    # ------------------------------------------------------------------ #
    def _emit(self, st: _SlotState, tok: int) -> None:
        req = st.req
        now = time.perf_counter()
        req.out_tokens.append(tok)
        self.metrics.tokens_out += 1
        if req.t_first is None:
            req.t_first = now
            req.first_token_tick = self.tick
            self.metrics.ttfts_s.append(req.ttft_s or 0.0)
        if req._t_last_token is not None:
            gap = now - req._t_last_token
            req.max_gap_s = max(req.max_gap_s, gap)
            self.metrics.max_intertoken_gap_s = max(
                self.metrics.max_intertoken_gap_s, gap)
        req._t_last_token = now
        if req._last_token_tick is not None:
            req.max_gap_ticks = max(req.max_gap_ticks,
                                    self.tick - req._last_token_tick)
        req._last_token_tick = self.tick
        if req.on_token is not None:
            req.on_token(req, tok)

    def _finish_slot(self, slot: int) -> None:
        st = self.slots[slot]
        req = self.sched.finish(slot)
        assert req is st.req
        req.done = True
        self.slots[slot] = None
        if self.paged:
            # finished sequences donate their pages to the prefix index
            self.stepper.release(slot, register=True)
        self.finished.append(req)
        self.metrics.n_finished += 1
        self._finalize(req)
        self.metrics.latencies_s.append(req.latency_s or 0.0)

    def _drop_slot(self, slot: int, reason: str) -> None:
        st = self.slots[slot]
        req = self.sched.drop(slot)
        assert req is st.req
        req.dropped = reason
        self.slots[slot] = None
        if self.paged:
            self.stepper.release(slot, register=False)
        self.dropped.append(req)
        self.metrics.n_dropped += 1
        self._finalize(req)

    def _expire(self) -> None:
        expired = self.sched.drop_queued(
            lambda r: r.deadline_tick is not None and self.tick >= r.deadline_tick)
        for req in expired:
            req.dropped = "deadline"
            # a requeued in-flight request still owns its pool sequence;
            # expiring in the queue must return those blocks
            res = self._resume.pop(req.uid, None)
            if res is not None and res.sid is not None:
                self.stepper.pool.release(res.sid, register=False)
            self.dropped.append(req)
            self.metrics.n_dropped += 1
            self._finalize(req)
        for slot, st in enumerate(self.slots):
            if st is not None and st.req.deadline_tick is not None \
                    and self.tick >= st.req.deadline_tick:
                self._drop_slot(slot, "deadline")

    # ------------------------------------------------------------------ #
    # tier-aware overload control
    # ------------------------------------------------------------------ #
    def _ttft_budget(self, req: EngineRequest) -> Optional[int]:
        """Absolute tick by which ``req`` must emit its first token: the
        tighter of the engine-wide TTFT SLO (relative to submit) and the
        request's own deadline.  ``None`` when neither applies."""
        budget = (None if self.slo_ttft_ticks is None
                  else req.submit_tick + self.slo_ttft_ticks)
        if req.deadline_tick is not None:
            budget = (req.deadline_tick if budget is None
                      else min(budget, req.deadline_tick))
        return budget

    def _overload_control(self) -> None:
        """Preempt a running low-tier slot when the highest-priority
        queued request would otherwise blow its TTFT budget.

        Deterministic trigger: every slot is busy, the queue head
        outranks the lowest-priority running request, and the head's
        remaining budget no longer covers its own chunked prefill (with
        decode interleaving, one chunk lands roughly every other tick)
        plus one tick of slack.  At most one slot is preempted per tick,
        bounding the disruption; the victim is the lowest-priority slot,
        ties broken toward the most remaining work (it would hold the
        slot longest).  The victim requeues at its original position and
        resumes via the page-level path — its pages stay live, so the
        preemption costs pool capacity, not recompute."""
        head = self.sched.peek()
        if head is None or any(s is None for s in self.slots):
            return
        budget = self._ttft_budget(head)
        if budget is None:
            return
        need = 2 * -(-len(head.prompt) // self.chunk) + 1
        if self.tick + need < budget:
            return
        pri = getattr(head, "priority", 0)
        victim: Optional[Tuple[Tuple[int, int], int]] = None
        for slot, st in enumerate(self.slots):
            p = getattr(st.req, "priority", 0)
            if p >= pri:
                continue
            remaining = st.req.max_new_tokens - len(st.req.out_tokens)
            key = (p, -remaining)
            if victim is None or key < victim[0]:
                victim = (key, slot)
        if victim is not None:
            self._preempt_slot(victim[1])

    def _preempt_slot(self, slot: int) -> None:
        """Move a running request back to the queue at its original
        submit position, keeping everything it computed: its pool
        sequence (paged — pages and reservations stay live) or its dense
        cache rows, plus ``prompt + out_tokens`` as the resume stream.
        Not a terminal state: busy -> queued keeps conservation, exactly
        like recovery's requeue."""
        st = self.slots[slot]
        req = self.sched.preempt(slot)
        assert req is st.req
        req.n_requeues += 1
        rows = st.length if st.decoding else st.pos
        stream = np.concatenate([np.asarray(req.prompt, np.int32),
                                 np.asarray(req.out_tokens, np.int32)])
        sid = self.stepper._slot_seq.pop(slot) if self.paged else None
        self._resume[req.uid] = _Resume(stream=stream, sid=sid,
                                        slot=slot, rows=rows)
        self.slots[slot] = None
        self.metrics.n_preempted += 1
        self._gate_blocked = None

    # ------------------------------------------------------------------ #
    def step(self) -> None:
        """One scheduling tick (see class docstring)."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self.tick += 1
        self.metrics.ticks += 1
        self._expire()
        if self.tier_aware:
            self._overload_control()
        if self.paged:
            # admission is gated on BLOCK availability, not slot count
            # alone.  The gate performs the pool admission (claims cached
            # prefix blocks + reserves worst-case growth) so consecutive
            # admissions in one tick see each other's reservations.
            pool = self.stepper.pool
            head = self.sched.peek()
            if head is None or self._gate_blocked != (head.uid, pool.version):
                claims: Dict[int, Tuple[int, int]] = {}
                refused: List[EngineRequest] = []

                def gate(req: EngineRequest) -> bool:
                    res = self._resume.get(req.uid)
                    if res is not None and res.sid is not None:
                        # requeued in-flight request: it kept its sequence
                        # (blocks + reservations) across recovery, so no
                        # pool admission is needed — or possible
                        return True
                    admitted = self.stepper.try_admit(req.prompt,
                                                      req.max_new_tokens)
                    if admitted is None:
                        refused.append(req)
                        return False
                    claims[id(req)] = admitted
                    return True

                for slot, req in self.sched.admit(gate):
                    res = self._resume.pop(req.uid, None)
                    if res is not None and res.sid is not None:
                        # resume from the surviving block table: prefill
                        # fast-forwards past every row already in the pool
                        self.stepper.attach(slot, res.sid)
                        done = self.stepper.pool.sequence(res.sid).n_tokens
                        self.slots[slot] = _SlotState(req=req, pos=done,
                                                      stream=res.stream)
                        self.metrics.recovered_rows += done
                        continue
                    sid, reused = claims[id(req)]
                    self.stepper.attach(slot, sid)
                    # a prefix hit fast-forwards prefill past the reused rows
                    self.slots[slot] = _SlotState(req=req, pos=reused)
                # remember a refused head: until a block reaches refcount 0
                # or a reservation returns (pool.version bump), re-running
                # its prefix lookup every tick cannot change the answer
                self._gate_blocked = ((refused[0].uid, pool.version)
                                      if refused else None)
        else:
            # dense page-level resume: committed per-slot cache rows
            # survive a discarded tick or a preemption (writes are
            # positional, and rows a failed tick wrote past the committed
            # length are overwritten before they are ever read), so a
            # resumed request fast-forwards past them — relocating the
            # rows when it lands in a different slot.  An intervening
            # admission overwrites a slot's rows; ``owners`` is checked
            # against the pre-tick map (nothing is written until the
            # prefill call later this tick), and a clobbered resume falls
            # back to the always-correct full re-prefill of the stream.
            owners = dict(self._dense_rows)
            moves: List[Tuple[int, int]] = []
            for slot, req in self.sched.admit():
                res = self._resume.pop(req.uid, None)
                if res is None:
                    self.slots[slot] = _SlotState(req=req)
                elif (res.rows > 0 and res.slot is not None
                        and owners.get(res.slot) == req.uid):
                    if res.slot != slot:
                        moves.append((res.slot, slot))
                    self.slots[slot] = _SlotState(req=req, pos=res.rows,
                                                  stream=res.stream)
                    self.metrics.recovered_rows += res.rows
                else:
                    self.slots[slot] = _SlotState(req=req, stream=res.stream)
                self._dense_rows[slot] = req.uid
            if moves:
                self.stepper.relocate_slots(moves)
        prefill = [i for i, st in enumerate(self.slots)
                   if st is not None and not st.decoding]
        decode = [i for i, st in enumerate(self.slots)
                  if st is not None and st.decoding]
        ckpt = (self.checkpoint() if self.self_heal and (prefill or decode)
                else None)
        try:
            if prefill and (not decode or not self._last_was_prefill):
                self._prefill_tick(prefill)
                self._last_was_prefill = True
            elif decode:
                if self.spec_k:
                    self._spec_decode_tick(decode)
                else:
                    self._decode_tick(decode)
                self._last_was_prefill = False
            self._consec_failures = 0
            if self.coordinator is not None:
                self.coordinator.heartbeat(self.host_id)
        except TickFailure as failure:
            if not self.self_heal:
                raise
            self._recover(ckpt, failure)
        self.metrics.wall_s = time.perf_counter() - self._t0

    def _guarded_call(self, fn, *args) -> np.ndarray:
        """One stepper Program call under the ft/ watchdogs.

        With ``self_heal``, a raised exception becomes a
        :class:`TickFailure` ("crash"), and a call that returns after the
        :class:`~repro.ft.watchdog.HangDetector` deadline fired is treated
        as hung — its result is DISCARDED by raising before any slot state
        or emission is touched.  (A real hung device call never returns;
        in this single-process simulation "returns too late" is the
        observable equivalent, and either way the recovery path is
        identical: restore the pre-tick checkpoint and requeue.)  The
        :class:`~repro.ft.watchdog.StepWatchdog` rolling median flags
        straggler ticks into the metrics either way."""
        self._watchdog.start()
        try:
            if self.self_heal and self._hang is not None:
                with self._hang as hd:
                    out = fn(*args)
                if hd.fired:
                    raise TickFailure("hang")
            else:
                out = fn(*args)
        except TickFailure:
            raise
        except Exception as e:
            if self.self_heal:
                raise TickFailure(f"crash: {type(e).__name__}: {e}") from e
            raise
        finally:
            if self._watchdog.stop():
                self.metrics.straggler_ticks += 1
        return out

    def _prefill_tick(self, slots: List[int]) -> None:
        b, c = self.n_slots, self.chunk
        tokens = np.zeros((b, c), np.int32)
        start = np.zeros((b,), np.int32)
        n_new = np.zeros((b,), np.int32)
        for s in slots:
            st = self.slots[s]
            stream = st.prompt
            n = min(c, len(stream) - st.pos)
            tokens[s, :n] = stream[st.pos:st.pos + n]
            start[s] = st.pos
            n_new[s] = n
        logits = self._guarded_call(self.stepper.prefill, tokens, start, n_new)
        self.metrics.prefill_ticks += 1
        self.metrics.busy_slot_ticks += len(slots)
        for s in slots:
            st = self.slots[s]
            n = int(n_new[s])
            st.pos += n
            if st.pos >= len(st.prompt):
                st.decoding = True
                st.length = len(st.prompt)
                first = int(np.argmax(logits[s, n - 1]))
                st.next_token = first
                self._emit(st, first)
                self._maybe_finish(s, first)

    def _decode_tick(self, slots: List[int]) -> None:
        t_begin = time.perf_counter()
        b = self.n_slots
        tokens = np.zeros((b, 1), np.int32)
        start = np.zeros((b,), np.int32)
        n_new = np.zeros((b,), np.int32)
        for s in slots:
            st = self.slots[s]
            tokens[s, 0] = st.next_token
            start[s] = st.length
            n_new[s] = 1
        logits = self._guarded_call(self.stepper.decode, tokens, start, n_new)
        self.metrics.decode_ticks += 1
        self.metrics.busy_slot_ticks += len(slots)
        for s in slots:
            st = self.slots[s]
            st.length += 1
            tok = int(np.argmax(logits[s]))
            st.next_token = tok
            self._emit(st, tok)
            self._maybe_finish(s, tok)
        self.metrics.decode_tokens += len(slots)
        self.metrics.decode_wall_s += time.perf_counter() - t_begin

    def _draft_catch_up(self, slots: List[int]) -> None:
        """Bring every slot's private draft cache up to its committed
        length with batched draft-prefill chunks over the committed token
        stream (original prompt + all generated tokens — the resume
        stream plus post-resume emissions collapse to exactly that)."""
        b, c = self.n_slots, self.chunk
        while True:
            behind = [s for s in slots
                      if self.slots[s].draft_len < self.slots[s].length]
            if not behind:
                return
            tokens = np.zeros((b, c), np.int32)
            start = np.zeros((b,), np.int32)
            n_new = np.zeros((b,), np.int32)
            for s in behind:
                st = self.slots[s]
                full = np.concatenate(
                    [np.asarray(st.req.prompt, np.int32),
                     np.asarray(st.req.out_tokens, np.int32)])
                n = min(c, st.length - st.draft_len)
                tokens[s, :n] = full[st.draft_len:st.draft_len + n]
                start[s] = st.draft_len
                n_new[s] = n
            self._guarded_call(self.stepper.draft_prefill,
                               tokens, start, n_new)
            for s in behind:
                self.slots[s].draft_len += int(n_new[s])

    def _spec_decode_tick(self, slots: List[int]) -> None:
        """Speculative decode tick: one draft call proposes ``spec_k``
        greedy tokens per slot, one verify call scores all of them (plus
        the committed next token) against the target in a single
        prefill-shaped Program call, and the greedy acceptance walk emits
        every proposal that matches the target's own argmax — so the
        emitted stream is token-identical to plain decode, just produced
        in fewer Program calls.  Rejected speculative cache rows are
        rolled back with :meth:`BlockPool.truncate` (paged) or simply
        overwritten by the next write at the committed position (dense:
        ``cache_update`` writes are positional)."""
        t_begin = time.perf_counter()
        b, k = self.n_slots, self.spec_k
        width = k + 1
        self._draft_catch_up(slots)
        tokens = np.zeros((b, 1), np.int32)
        start = np.zeros((b,), np.int32)
        n_new = np.zeros((b,), np.int32)
        for s in slots:
            st = self.slots[s]
            tokens[s, 0] = st.next_token
            start[s] = st.length
            n_new[s] = 1
        draft_toks = self._guarded_call(self.stepper.draft,
                                        tokens, start, n_new)
        vtokens = np.zeros((b, width), np.int32)
        vstart = np.zeros((b,), np.int32)
        vn_new = np.zeros((b,), np.int32)
        for s in slots:
            st = self.slots[s]
            remaining = st.req.max_new_tokens - len(st.req.out_tokens)
            n = min(width, remaining)   # never write past the request cap
            vtokens[s, 0] = st.next_token
            vtokens[s, 1:n] = draft_toks[s, :n - 1]
            vstart[s] = st.length
            vn_new[s] = n
        logits = self._guarded_call(self.stepper.verify,
                                    vtokens, vstart, vn_new)
        self.metrics.decode_ticks += 1
        self.metrics.spec_ticks += 1
        self.metrics.busy_slot_ticks += len(slots)
        # greedy acceptance walk: position i's argmax is what plain decode
        # would emit after vtokens[:i+1]; keep walking while the next fed
        # draft token IS that argmax.  Walk every slot BEFORE touching any
        # state — the kv8 commit below is one batched (guarded) call.
        emits: Dict[int, List[int]] = {}
        for s in slots:
            st = self.slots[s]
            n = int(vn_new[s])
            emit: List[int] = []
            for i in range(n):
                g = int(np.argmax(logits[s, i]))
                emit.append(g)
                if g == self.eos_id or \
                        len(st.req.out_tokens) + len(emit) \
                        >= st.req.max_new_tokens:
                    break
                if i + 1 < n and int(vtokens[s, i + 1]) == g:
                    continue
                break
            emits[s] = emit         # len >= 1: position 0 re-scores the
            #                         committed token, so it always emits
        if self.paged:
            # roll back the rejected speculative rows; rows
            # 0..length+e-1 hold exactly the committed stream
            for s in slots:
                sid = self.stepper._slot_seq[s]
                self.stepper.pool.truncate(
                    sid, self.slots[s].length + len(emits[s]))
        if self.paged and getattr(self.stepper, "kv_dtype", None) == "int8":
            # the kv8 verify left the live pages untouched; replay the
            # accepted prefix of its write chain now that the block
            # tables are truncated back to exactly those rows
            commit_n = np.zeros((b,), np.int32)
            for s in slots:
                commit_n[s] = len(emits[s])
            self._guarded_call(self.stepper.commit_spec, vstart, commit_n)
        emitted_total = 0
        for s in slots:
            st = self.slots[s]
            emit = emits[s]
            e = len(emit)
            n = int(vn_new[s])
            self.metrics.spec_proposed += n - 1
            self.metrics.spec_accepted += e - 1
            st.length += e
            st.draft_len = st.length   # accepted rows == draft-cache rows
            st.next_token = emit[-1]
            for tok in emit:
                self._emit(st, tok)
            emitted_total += e
            self._maybe_finish(s, emit[-1])
        self.metrics.decode_tokens += emitted_total
        self.metrics.decode_wall_s += time.perf_counter() - t_begin

    def _maybe_finish(self, slot: int, tok: int) -> None:
        st = self.slots[slot]
        if tok == self.eos_id or len(st.req.out_tokens) >= st.req.max_new_tokens:
            self._finish_slot(slot)

    # ------------------------------------------------------------------ #
    # self-healing: checkpoint / recover
    # ------------------------------------------------------------------ #
    def checkpoint(self) -> EngineCheckpoint:
        """In-flight state as of now: per-slot prompt + generated tokens
        (+ sequence id and block table when paged) and a full
        :meth:`~repro.runtime.kv_cache.BlockPool.snapshot`.  Taken at the
        start of every guarded tick; host-side slot state is only mutated
        after a successful Program call, so the checkpoint stays valid
        through any failure of the tick it guards."""
        slots: List[CheckpointSlot] = []
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            entry = CheckpointSlot(slot=slot, uid=st.req.uid,
                                   prompt=st.req.prompt,
                                   out_tokens=list(st.req.out_tokens),
                                   rows=st.length if st.decoding else st.pos)
            if self.paged:
                sid = self.stepper._slot_seq[slot]
                entry.sid = sid
                entry.block_table = self.stepper.pool.block_table(sid)
            slots.append(entry)
        pool = self.stepper.pool.snapshot() if self.paged else None
        return EngineCheckpoint(tick=self.tick, slots=slots, pool=pool)

    def _recover(self, ckpt: EngineCheckpoint, failure: TickFailure) -> None:
        """Discard the failed tick and rebuild from ``ckpt``: restore the
        pool (bookkeeping back in lockstep with the device pages — the
        failed tick's recorded-but-unwritten rows and index entries
        vanish), preempt every slot back into the queue at its original
        position, and stage each request's resume stream.  The next ticks
        re-admit them FIFO; paged requests keep their sequence, so prefill
        fast-forwards past every surviving row."""
        self.metrics.failed_ticks += 1
        if failure.reason == "hang":
            self.metrics.n_hang_failures += 1
        else:
            self.metrics.n_crash_failures += 1
        self._consec_failures += 1
        if self._consec_failures > self.max_recoveries:
            raise TickFailure(
                f"giving up after {self._consec_failures} consecutive "
                f"tick failures (last: {failure.reason})") from failure
        if self.paged:
            self.stepper.pool.restore(ckpt.pool)   # ends in check_integrity
            self.stepper._slot_seq.clear()
        for entry in ckpt.slots:
            req = self.sched.preempt(entry.slot)
            assert req.uid == entry.uid, \
                f"slot {entry.slot}: checkpoint uid {entry.uid}, live {req.uid}"
            req.n_requeues += 1
            self._resume[req.uid] = _Resume(stream=entry.stream,
                                            sid=entry.sid,
                                            slot=entry.slot,
                                            rows=entry.rows)
            self.slots[entry.slot] = None
            self.metrics.requeued_requests += 1
        self._gate_blocked = None
        self._last_was_prefill = False
        self.metrics.n_recoveries += 1
        if self.coordinator is not None:
            # a hang past the membership deadline shows up as a death;
            # re-registering is the "restarted engine" membership event
            self.coordinator.sweep()
            self.coordinator.register(self.host_id)

    # ------------------------------------------------------------------ #
    def reset_metrics(self) -> None:
        """Zero the metrics window (e.g. after warmup) without touching
        scheduler state, slots or caches."""
        self.metrics = EngineMetrics(n_slots=self.n_slots)
        self._t0 = None

    def has_work(self) -> bool:
        return self.sched.has_work()

    def run(self, max_ticks: int = 100_000) -> List[EngineRequest]:
        """Drive until queue and slots drain; returns newly finished
        requests (handed out exactly once)."""
        while self.has_work() and self.tick < max_ticks:
            self.step()
        out, self.finished = self.finished, []
        return out


# --------------------------------------------------------------------------- #
# Async front-end
# --------------------------------------------------------------------------- #

_DONE = object()


class AsyncEngine:
    """Cooperative asyncio facade: per-token streaming via ``async for``.

    Single-threaded and deterministic — :meth:`run` interleaves engine
    ticks with consumer wakeups on the current event loop; no background
    threads.
    """

    def __init__(self, engine: Engine):
        self.engine = engine
        self._uid = 0

    async def generate(self, prompt: np.ndarray, max_new_tokens: int, *,
                       priority: int = 0, deadline_tick: Optional[int] = None):
        """Async iterator of generated token ids for one request."""
        q: asyncio.Queue = asyncio.Queue()
        self._uid += 1
        req = EngineRequest(
            uid=self._uid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens, priority=priority,
            deadline_tick=deadline_tick,
            on_token=lambda r, t: q.put_nowait(t),
            on_finish=lambda r: q.put_nowait(_DONE))
        if not self.engine.submit(req):
            raise RuntimeError(f"request rejected: {req.dropped}")
        while True:
            tok = await q.get()
            if tok is _DONE:
                break
            yield tok
        if req.dropped is not None:
            # a mid-flight drop (deadline) must not look like completion —
            # the consumer has only a truncated stream
            raise RuntimeError(
                f"request {req.uid} dropped after "
                f"{len(req.out_tokens)} tokens: {req.dropped}")

    async def run(self, max_ticks: int = 100_000) -> None:
        """Drive the engine until drained, yielding to consumers between
        ticks."""
        while self.engine.has_work() and self.engine.tick < max_ticks:
            self.engine.step()
            await asyncio.sleep(0)


# --------------------------------------------------------------------------- #
# Unbatched reference + the serving factory
# --------------------------------------------------------------------------- #

class UnbatchedReference:
    """No-batching greedy loop over B=1 Programs compiled from the same
    graphs (and, for int8, the same calibration ranges) as the engine's —
    the token-exactness oracle and serve_bench's baseline.

    ``chunk=None`` prefills the whole prompt in one Program call
    (one-shot); an integer chunk reproduces the engine's chunked prefill.
    Programs are compiled lazily per distinct (chunk,) shape and cached.
    """

    def __init__(self, cfg: GraphLMConfig, params: Mapping[str, Any], *,
                 cache_cap: int, policy: Optional[BackendPolicy] = None,
                 quantize: Optional[str] = None,
                 calib_ranges: Optional[Mapping[str, Any]] = None):
        self.cfg = cfg
        self.params = dict(params)
        self.cache_cap = cache_cap
        self._policy = policy
        self._quantize = quantize
        self._ranges = calib_ranges
        self._decode: Optional[Tuple[Any, List[str]]] = None
        self._prefills: Dict[int, Tuple[Any, List[str]]] = {}

    def _compiled(self, graph) -> Tuple[Any, List[str]]:
        prog = compile(graph, policy=self._policy, quantize=self._quantize,
                       calib_ranges=self._ranges)
        cache_inputs = sorted(init_cache_inputs(self.cfg, 1, 1))
        names = ("tokens", "start", "n_new", *cache_inputs)
        return (prog.bind(*names, donate=cache_inputs),
                [v for v in graph.outputs[1:]])

    def _prefill_for(self, chunk: int) -> Tuple[Any, List[str]]:
        if chunk not in self._prefills:
            g = build_prefill_graph(self.cfg, self.params, batch=1,
                                    chunk=chunk, cache_cap=self.cache_cap)
            self._prefills[chunk] = self._compiled(g)
        return self._prefills[chunk]

    def _decode_fn(self) -> Tuple[Any, List[str]]:
        if self._decode is None:
            g = build_decode_graph(self.cfg, self.params, batch=1,
                                   cache_cap=self.cache_cap)
            self._decode = self._compiled(g)
        return self._decode

    def generate(self, prompt: np.ndarray, max_new_tokens: int, *,
                 chunk: Optional[int] = None, eos_id: int = -1,
                 record: Optional[List] = None) -> List[int]:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0 or max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and max_new_tokens >= 1")
        c = len(prompt) if chunk is None else chunk
        # unpadded admission, matching Engine.submit: at most
        # len(prompt) + max_new - 1 rows are ever written (chunk padding
        # rows are masked out of the cache write)
        if len(prompt) + max_new_tokens - 1 > self.cache_cap:
            raise ValueError(f"prompt {len(prompt)} + {max_new_tokens} new "
                             f"tokens exceeds cache cap {self.cache_cap}")
        pre, cache_outs = self._prefill_for(c)
        caches = {k: jnp.asarray(v) for k, v in
                  init_cache_inputs(self.cfg, 1, self.cache_cap).items()}

        def call(fn, outs, tokens, start, n_new, kind):
            inputs = {"tokens": tokens, "start": start, "n_new": n_new,
                      **{k: np.asarray(v) for k, v in caches.items()}}
            if record is not None:
                record.append((kind, inputs))
            res = fn(jnp.asarray(tokens), jnp.asarray(start),
                     jnp.asarray(n_new), *[caches[k] for k in sorted(caches)])
            for name, arr in zip(outs, res[1:]):
                caches[name.replace("new_", "")] = arr
            return np.asarray(res[0])

        pos = 0
        logits = None
        while pos < len(prompt):
            n = min(c, len(prompt) - pos)
            toks = np.zeros((1, c), np.int32)
            toks[0, :n] = prompt[pos:pos + n]
            logits = call(pre, cache_outs,
                          toks, np.asarray([pos], np.int32),
                          np.asarray([n], np.int32), "prefill")
            pos += n
        out = [int(np.argmax(logits[0, n - 1]))]
        dec, dec_outs = self._decode_fn()
        length = len(prompt)
        while out[-1] != eos_id and len(out) < max_new_tokens:
            logits = call(dec, dec_outs,
                          np.asarray([[out[-1]]], np.int32),
                          np.asarray([length], np.int32),
                          np.asarray([1], np.int32), "decode")
            length += 1
            out.append(int(np.argmax(logits[0])))
        return out


def _merge_ranges(*range_dicts: Mapping[str, Any]) -> Dict[str, Any]:
    """Union of calibration ranges over value names: min lo, max hi.

    ``channel_mean`` is taken from the first dict that has the value —
    exact averaging would need per-batch counts.  It only feeds
    quantize-time bias correction, which never fires for the bias-free
    graph-LM dense nodes; revisit if the builder grows fused biases."""
    from repro.core.quant import ValueRange
    out: Dict[str, Any] = {}
    for d in range_dicts:
        for name, vr in d.items():
            if name in out:
                prev = out[name]
                out[name] = ValueRange(min(prev[0], vr[0]), max(prev[1], vr[1]),
                                       getattr(prev, "channel_mean", None))
            else:
                out[name] = vr
    return out


def shared_calibration(cfg: GraphLMConfig, params: Mapping[str, Any], *,
                       chunk: int, cache_cap: int, seed: int = 0,
                       n_prompts: int = 3,
                       max_new_tokens: int = 4) -> Dict[str, Any]:
    """One calibration for every Program variant of this model.

    Records real serving traffic (a few fp32 reference generations) as
    input batches for the B=1 prefill and decode graphs, calibrates each,
    and merges the ranges by value name.  Because the graph builders use
    identical value names across batch/chunk variants, the result drives
    ``compile(..., quantize="int8", calib_ranges=...)`` for the engine's
    batched Programs and the unbatched reference alike — giving every
    variant the same static activation scales (the precondition for
    batched-vs-unbatched token-exactness under int8)."""
    from repro.core.quant import calibrate
    ref = UnbatchedReference(cfg, params, cache_cap=cache_cap)
    rng = np.random.default_rng(seed)
    record: List[Tuple[str, Dict[str, Any]]] = []
    for _ in range(n_prompts):
        plen = int(rng.integers(1, max(2, 2 * chunk)))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        ref.generate(prompt, max_new_tokens, chunk=chunk, record=record)
    pre_batches = [inputs for kind, inputs in record if kind == "prefill"]
    dec_batches = [inputs for kind, inputs in record if kind == "decode"]
    g_pre = build_prefill_graph(cfg, params, batch=1, chunk=chunk,
                                cache_cap=cache_cap)
    g_dec = build_decode_graph(cfg, params, batch=1, cache_cap=cache_cap)
    return _merge_ranges(calibrate(g_pre, pre_batches),
                         calibrate(g_dec, dec_batches))


def build_lm_serving(cfg: Optional[GraphLMConfig] = None, *,
                     n_slots: int = 4, chunk: int = 8, cache_cap: int = 64,
                     quantize: Optional[str] = None,
                     policy: Optional[BackendPolicy] = None,
                     seed: int = 0, eos_id: int = -1,
                     max_queue: Optional[int] = None,
                     params: Optional[Mapping[str, Any]] = None,
                     paged: bool = False, page_size: int = 8,
                     n_blocks: Optional[int] = None,
                     max_pages: Optional[int] = None,
                     kv_dtype: str = "float32",
                     self_heal: bool = False,
                     hang_timeout: Optional[float] = None,
                     max_recoveries: int = 8,
                     coordinator: Optional[Coordinator] = None,
                     spec_k: int = 0,
                     draft_layers: Optional[int] = None,
                     mesh: Optional[Any] = None,
                     tp: Optional[int] = None,
                     tier_aware: bool = False,
                     slo_ttft_ticks: Optional[int] = None,
                     ) -> Tuple[Engine, UnbatchedReference]:
    """Compile the serving Programs for a graph LM and return the engine
    plus its unbatched reference (sharing weights and, under int8, the
    calibrated activation scales).

    ``paged=True`` swaps the dense per-slot caches for the paged KV cache
    (:class:`PagedProgramStepper`): ``cache_cap`` becomes the per-sequence
    logical capacity (rounded up to whole pages of ``page_size``) and
    ``n_blocks`` sizes the shared pool — defaulting to the same total
    memory as the dense layout (``n_slots * ceil(cache_cap / page_size)``
    pages).  ``kv_dtype="int8"`` (paged only) stores the pools in int8
    with per-(page, kv-head) scale sidecars and routes the hot path
    through the fused-dequant ``*_q`` ops; at equal pool BYTES that is
    ~4x the page count of fp32.  The reference stays dense fp32 either
    way: it is the paged engine's token-exactness oracle.

    ``spec_k > 0`` turns on greedy speculative decoding: every decode
    tick drafts ``spec_k`` tokens with an early-exit draft model (the
    target's first ``draft_layers`` layers, default ``n_layers // 2``)
    and verifies them in one batched call — output stays token-identical
    to plain decode; only the number of Program calls per emitted token
    changes.

    ``tier_aware=True`` turns on tier-aware overload control: a full
    queue sheds its lowest-priority member to admit a higher-priority
    arrival, and a running low-tier slot is preempted (resuming later via
    the page-level path) when the highest-priority queued request would
    otherwise miss its TTFT budget (``slo_ttft_ticks`` and/or its
    deadline).

    ``mesh`` (a ``jax.sharding.Mesh`` with a "model" axis) or ``tp`` (a
    tensor-parallel degree, turned into such a mesh over the first ``tp``
    local devices) serves the engine multi-device: Programs compile with
    ``compile(mesh=...)``, caches/pools/sidecars are ``device_put`` onto
    their stamped NamedShardings, and attention runs the shard_map ``tp``
    backends — token-identical to the single-device engine (heads are
    computed whole per device; the only collective is an exact output
    all-gather).  The reference stays single-device: it is the oracle."""
    cfg = cfg or GraphLMConfig()
    if kv_dtype != "float32" and not paged:
        raise ValueError("kv_dtype requires paged=True")
    if tp is not None:
        if mesh is not None:
            raise ValueError("pass mesh or tp, not both")
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(tp)
    params = dict(params) if params is not None else init_lm_params(cfg, seed)
    ranges = None
    if quantize is not None:
        ranges = shared_calibration(cfg, params, chunk=chunk,
                                    cache_cap=cache_cap, seed=seed)
    if paged:
        mp = max_pages if max_pages is not None else -(-cache_cap // page_size)
        nb = n_blocks if n_blocks is not None else n_slots * mp
        stepper: ProgramStepper = PagedProgramStepper(
            cfg, params, n_slots=n_slots, chunk=chunk, page_size=page_size,
            n_blocks=nb, max_pages=mp, kv_dtype=kv_dtype, policy=policy,
            quantize=quantize, calib_ranges=ranges,
            spec_k=spec_k, draft_layers=draft_layers, mesh=mesh)
    else:
        stepper = ProgramStepper(cfg, params, n_slots=n_slots, chunk=chunk,
                                 cache_cap=cache_cap, policy=policy,
                                 quantize=quantize, calib_ranges=ranges,
                                 spec_k=spec_k, draft_layers=draft_layers,
                                 mesh=mesh)
    engine = Engine(stepper, eos_id=eos_id, max_queue=max_queue,
                    self_heal=self_heal, hang_timeout=hang_timeout,
                    max_recoveries=max_recoveries, coordinator=coordinator,
                    tier_aware=tier_aware, slo_ttft_ticks=slo_ttft_ticks)
    reference = UnbatchedReference(cfg, params,
                                   cache_cap=max(cache_cap,
                                                 stepper.cache_cap),
                                   policy=policy, quantize=quantize,
                                   calib_ranges=ranges)
    return engine, reference
