"""Pipeline parallelism over the "pod" axis (GPipe-style microbatching).

The multi-pod mesh maps "pod" to data-parallel by default (only gradient
all-reduces cross the DCN).  When activations are smaller than gradients —
long-seq training of narrow models — pipelining the pods is the better
trade: each pod owns a contiguous block of layers and only (microbatch,
seq, d_model) activations cross pods, on a 1F schedule with
collective_permute.

``pipeline_apply`` is the schedule primitive: stage s computes microbatch m
at tick t = s + m; activations hop stage->stage+1 each tick.  Bubble
fraction = (S-1)/(M+S-1), the GPipe bound — tests assert both the numerics
(== sequential composition) and the tick count.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(mesh: Mesh, stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, *,
                   axis: str = "pod") -> jax.Array:
    """Run ``n_stages = mesh.shape[axis]`` pipeline stages over microbatches.

    stage_params: pytree whose leaves are stacked (n_stages, ...) — stage s
    uses leaf[s] (sharded over ``axis``, one stage per device group).
    x: (n_micro, mb, ...) microbatched input, replicated.
    Returns (n_micro, mb, ...) outputs of the last stage, replicated.
    """
    n = mesh.shape[axis]
    m = x.shape[0]

    def local(params_s, xs):
        params_stage = jax.tree.map(lambda a: a[0], params_s)  # my shard
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        perm = [(i, i + 1) for i in range(n - 1)]

        def tick(carry, t):
            inbox, outputs = carry
            # stage 0 reads microbatch t from the feed; others read inbox
            feed = jnp.where(t < m, xs[jnp.minimum(t, m - 1)],
                             jnp.zeros(mb_shape, xs.dtype))
            inp = jnp.where(stage == 0, feed, inbox)
            active = (t >= stage) & (t < stage + m)
            act = jnp.where(active, stage_fn(params_stage, inp), 0.0)
            # last stage banks its result for microbatch (t - stage)
            slot = jnp.clip(t - stage, 0, m - 1)
            outputs = jnp.where(
                active & (stage == n - 1),
                outputs.at[slot].set(act), outputs)
            inbox = jax.lax.ppermute(act, axis, perm)
            return (inbox, outputs), None

        inbox0 = jnp.zeros(mb_shape, xs.dtype)
        outputs0 = jnp.zeros((m,) + mb_shape, xs.dtype)
        inbox0 = jax.lax.pcast(inbox0, (axis,), to="varying")
        outputs0 = jax.lax.pcast(outputs0, (axis,), to="varying")
        (_, outputs), _ = jax.lax.scan(tick, (inbox0, outputs0),
                                       jnp.arange(m + n - 1))
        return outputs[None]  # (1, m, mb, ...) per stage group

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    ys = jax.shard_map(local, mesh=mesh,
                       in_specs=(spec_params, P()),
                       out_specs=P(axis), check_vma=False)(stage_params, x)
    return ys[-1]  # the last stage's bank
