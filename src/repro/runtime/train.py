"""Distributed train-step factory.

``make_train_step`` builds a donated, sharded ``jax.jit`` step:

    (params, opt_state, batch) -> (params, opt_state, metrics)

Shardings come from :mod:`repro.sharding.specs` (TP/EP on "model",
DP over "pod"+"data", ZeRO-1 moments over "data").  The same factory serves
the real trainer (launch/train.py), the smoke tests (1-device mesh) and the
multi-pod dry-run (512 fake devices; ``.lower(...)`` only).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.sharding.specs import (batch_specs, named_shardings,
                                  opt_state_specs, param_specs)

__all__ = ["make_train_step", "train_state_shardings"]


def train_state_shardings(model, cfg: ArchConfig, mesh: Mesh,
                          batch_example: Dict[str, Any],
                          opt_cfg: AdamWConfig):
    """Returns (param_sharding, opt_sharding, batch_sharding) NamedSharding
    pytrees (from eval_shape — no allocation)."""
    key = jax.random.PRNGKey(0)
    p_shape = jax.eval_shape(model.init_params, key)
    p_spec = param_specs(p_shape, cfg, mesh)
    o_shape = jax.eval_shape(partial(adamw.init, cfg=opt_cfg), p_shape)

    def o_spec_fn(path, leaf):
        # step scalar: replicated; mu/nu/master mirror param specs + ZeRO-1
        return P()

    # mu/nu/master share the param tree structure under their subtree
    o_spec = {
        "step": P(),
        "mu": opt_state_specs(p_shape, p_spec, mesh),
        "nu": opt_state_specs(p_shape, p_spec, mesh),
    }
    if opt_cfg.master_fp32:
        o_spec["master"] = opt_state_specs(p_shape, p_spec, mesh)
    b_shape = jax.eval_shape(lambda b: b, batch_example)
    b_spec = batch_specs(b_shape, mesh)
    return (named_shardings(p_spec, mesh), named_shardings(o_spec, mesh),
            named_shardings(b_spec, mesh))


def make_train_step(model, cfg: ArchConfig, opt_cfg: AdamWConfig,
                    mesh: Optional[Mesh] = None,
                    batch_example: Optional[Dict[str, Any]] = None,
                    donate: bool = True) -> Callable:
    """Build the jitted step.  Without a mesh: plain jit (CPU tests)."""

    def step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw.update(grads, opt_state,
                                                        params, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    assert batch_example is not None
    p_sh, o_sh, b_sh = train_state_shardings(model, cfg, mesh, batch_example,
                                             opt_cfg)
    metric_sh = NamedSharding(mesh, P())
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
