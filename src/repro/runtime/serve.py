"""Distributed serve-step factories: prefill and decode.

``make_decode_step`` is what the decode_* dry-run shapes lower: one new
token per sequence against the sharded KV cache.  Cache shardings follow
:func:`repro.sharding.specs.cache_specs` — batch over DP axes, KV heads
over "model"; for batch==1 long-context the cache LENGTH dim shards over
"data" (sequence parallelism; XLA inserts the exact masked-softmax
reductions, and the shard_map tree-decode in sharding/collectives.py is
the hand-scheduled alternative backend).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.sharding.specs import (batch_specs, cache_specs, data_axes,
                                  named_shardings, param_specs)

__all__ = ["make_prefill_step", "make_decode_step", "serve_shardings"]


def serve_shardings(model, cfg: ArchConfig, mesh: Mesh, batch: int,
                    cache_cap: int, enc_len: int = 0,
                    seq_shard_fallback: bool = True):
    key = jax.random.PRNGKey(0)
    p_shape = jax.eval_shape(model.init_params, key)
    p_spec = param_specs(p_shape, cfg, mesh)
    if enc_len:
        c_shape = jax.eval_shape(
            partial(model.init_caches, batch, cache_cap, enc_len))
    else:
        c_shape = jax.eval_shape(partial(model.init_caches, batch, cache_cap))
    c_spec = cache_specs(c_shape, cfg, mesh, batch,
                         seq_shard_fallback=seq_shard_fallback)
    return named_shardings(p_spec, mesh), named_shardings(c_spec, mesh)


def make_decode_step(model, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                     batch: int = 1, cache_cap: int = 1024,
                     enc_len: int = 0, donate_cache: bool = True,
                     seq_shard_fallback: bool = True) -> Callable:
    """(params, tokens (B,), caches, lengths) -> (logits, new_caches)."""

    if enc_len:
        def step(params, tokens, caches, lengths):
            return model.decode_step(params, tokens, caches, lengths,
                                     jnp.full_like(lengths, enc_len))
    else:
        def step(params, tokens, caches, lengths):
            return model.decode_step(params, tokens, caches, lengths)

    if mesh is None:
        return jax.jit(step, donate_argnums=(2,) if donate_cache else ())

    p_sh, c_sh = serve_shardings(model, cfg, mesh, batch, cache_cap, enc_len,
                                 seq_shard_fallback=seq_shard_fallback)
    dp = data_axes(mesh)
    tok_spec = P(dp) if batch > 1 else P()
    tok_sh = NamedSharding(mesh, tok_spec)
    logit_sh = NamedSharding(mesh, P(dp if batch > 1 else None, "model"))
    return jax.jit(
        step,
        in_shardings=(p_sh, tok_sh, c_sh, tok_sh),
        out_shardings=(logit_sh, c_sh),
        donate_argnums=(2,) if donate_cache else (),
    )


def make_prefill_step(model, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                      batch: int = 1, seq: int = 1024,
                      cache_cap: Optional[int] = None) -> Callable:
    """(params, batch_inputs) -> (last_logits, caches, lengths)."""
    cap = cache_cap or seq

    def step(params, inputs):
        return model.prefill(params, inputs, cache_cap=cap)

    if mesh is None:
        return jax.jit(step)

    p_sh, c_sh = serve_shardings(model, cfg, mesh, batch, cap,
                                 getattr(model, "enc_len", 0) or 0)
    dp = data_axes(mesh)
    in_sh = None  # let XLA infer input layout from batch_specs at call site
    return jax.jit(step, in_shardings=(p_sh, None),
                   out_shardings=(NamedSharding(mesh, P(dp if batch > 1 else None, "model")),
                                  c_sh,
                                  NamedSharding(mesh, P(dp if batch > 1 else None))))
