"""Trace-driven load generation and SLO goodput evaluation.

``serve_bench`` historically measured steady smoke traffic and reported
raw tokens/s.  Production serving is judged differently: traffic is
bursty, requests come in priority tiers with latency expectations, many
prompts share long prefixes, and what matters is **goodput under SLO** —
how many requests per second finish while meeting their time-to-first-
token and inter-token-gap targets — plus what happens to the rest
(shed at admission, dropped at deadline; never silently lost).

This module is the workload half of that story:

* :func:`generate_trace` — a **seeded, deterministic** trace of
  :class:`TraceRequest`\\ s: the same :class:`TraceConfig` always yields a
  byte-identical trace (:meth:`Trace.digest` pins this).  Arrivals are
  bursty (gamma interarrivals with configurable squared coefficient of
  variation, or a 2-state Markov-modulated process), prompt/output
  lengths are lognormal mixtures, requests are assigned weighted priority
  **tiers**, and a configurable fraction draws its prompt head from
  shared **prefix populations** — the workload shape that exercises the
  BlockPool's content-addressed prefix reuse.

* :func:`run_load` — drives a :class:`~repro.runtime.engine.Engine`
  through a trace (submitting each request at its arrival tick) and
  scores the outcome against an :class:`SLO`: per-tier and overall
  goodput, p50/p95/p99 TTFT and inter-token gap (in deterministic engine
  ticks AND wall seconds), and full shed/drop accounting.  Offered ==
  finished + shed + dropped per tier, always.

Everything here is host-side and model-agnostic; ``benchmarks/
serve_bench.py`` wires it to the example graph LM as the ``load`` section
of ``BENCH_serve.json``.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.engine import Engine, EngineRequest, _pct_dict

__all__ = ["TierSpec", "PrefixPopulation", "TraceConfig", "TraceRequest",
           "Trace", "SLO", "generate_trace", "run_load"]


# --------------------------------------------------------------------------- #
# trace model
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class TierSpec:
    """One priority tier of the workload.  ``weight`` is the sampling
    weight; ``deadline_ticks`` (optional) becomes each request's absolute
    engine deadline relative to its submit tick — the overload-shedding
    knob (expired work is dropped, and reported as dropped)."""

    name: str
    priority: int = 0
    weight: float = 1.0
    deadline_ticks: Optional[int] = None


@dataclass(frozen=True)
class PrefixPopulation:
    """A shared prompt head.  Requests drawn from a population start with
    the same ``prefix_len`` tokens, so a paged engine's prefix index
    serves them from cached pages after the first arrival."""

    name: str
    prefix_len: int
    weight: float = 1.0


@dataclass(frozen=True)
class TraceConfig:
    """Knobs for one deterministic workload trace (see module docstring).

    ``burstiness`` is the squared coefficient of variation of the gamma
    interarrivals — 1.0 is Poisson, larger is burstier (many near-zero
    gaps separated by long quiet stretches).  ``arrival="mmpp"`` instead
    alternates exponential arrivals between a burst state (rate x
    ``mmpp_burst_factor``) and a compensating idle state, switching with
    probability ``mmpp_p_switch`` per arrival; the stationary mean stays
    ``mean_interarrival_ticks``."""

    seed: int = 0
    n_requests: int = 64
    vocab: int = 61
    # arrivals
    mean_interarrival_ticks: float = 2.0
    arrival: str = "gamma"                  # "gamma" | "mmpp"
    burstiness: float = 4.0                 # gamma cv^2 (1.0 = Poisson)
    mmpp_burst_factor: float = 4.0          # burst-state rate multiplier
    mmpp_p_switch: float = 0.1              # state-switch prob per arrival
    # lengths (lognormal, clipped)
    prompt_len_mean: float = 12.0
    prompt_len_sigma: float = 0.5
    prompt_len_max: int = 48
    new_tokens_mean: float = 8.0
    new_tokens_sigma: float = 0.5
    new_tokens_max: int = 32
    # mix
    tiers: Tuple[TierSpec, ...] = (
        TierSpec("interactive", priority=1, weight=0.5, deadline_ticks=None),
        TierSpec("batch", priority=0, weight=0.5),
    )
    prefix_populations: Tuple[PrefixPopulation, ...] = ()
    prefix_share_p: float = 0.0             # P(request joins a population)


@dataclass(frozen=True)
class TraceRequest:
    """One request of a generated trace (pure data, engine-agnostic)."""

    uid: int
    arrival_tick: int
    prompt: np.ndarray                      # (prompt_len,) int32
    max_new_tokens: int
    tier: str
    priority: int
    deadline_ticks: Optional[int] = None    # relative to submit
    population: Optional[str] = None


@dataclass
class Trace:
    """A generated trace plus its shared-prefix dictionary."""

    config: TraceConfig
    requests: List[TraceRequest]
    prefixes: Dict[str, np.ndarray] = field(default_factory=dict)

    def digest(self) -> str:
        """sha256 over a canonical byte serialization — equal configs
        must produce equal digests (the determinism bar of
        ``tests/test_loadgen.py``)."""
        h = hashlib.sha256()
        for r in self.requests:
            head = (f"{r.uid}|{r.arrival_tick}|{r.max_new_tokens}|"
                    f"{r.tier}|{r.priority}|{r.deadline_ticks}|"
                    f"{r.population}|").encode()
            h.update(head)
            h.update(np.asarray(r.prompt, np.int32).tobytes())
        return h.hexdigest()

    def stats(self) -> Dict[str, Any]:
        """Empirical trace shape — what the property tests hold against
        the configured means."""
        arrivals = [r.arrival_tick for r in self.requests]
        inter = np.diff(arrivals) if len(arrivals) > 1 else np.asarray([0.0])
        tiers: Dict[str, int] = {}
        pops: Dict[str, int] = {}
        for r in self.requests:
            tiers[r.tier] = tiers.get(r.tier, 0) + 1
            if r.population is not None:
                pops[r.population] = pops.get(r.population, 0) + 1
        return {
            "n_requests": len(self.requests),
            "digest": self.digest(),
            "span_ticks": arrivals[-1] if arrivals else 0,
            "mean_interarrival_ticks": float(np.mean(inter)),
            "mean_prompt_len": float(np.mean(
                [len(r.prompt) for r in self.requests])),
            "mean_new_tokens": float(np.mean(
                [r.max_new_tokens for r in self.requests])),
            "tiers": tiers,
            "populations": pops,
            "shared_prefix_requests": sum(pops.values()),
        }


def _lognormal(rng: np.random.Generator, mean: float, sigma: float,
               hi: int) -> int:
    """Integer lognormal with the given MEAN (mu compensated for sigma),
    clipped to [1, hi]."""
    mu = np.log(mean) - 0.5 * sigma * sigma
    return int(np.clip(round(rng.lognormal(mu, sigma)), 1, hi))


def _weighted(rng: np.random.Generator, items: Sequence[Any]) -> Any:
    w = np.asarray([it.weight for it in items], np.float64)
    return items[int(rng.choice(len(items), p=w / w.sum()))]


def generate_trace(cfg: TraceConfig) -> Trace:
    """Deterministically expand ``cfg`` into a :class:`Trace`."""
    if not cfg.tiers:
        raise ValueError("need at least one tier")
    if cfg.arrival not in ("gamma", "mmpp"):
        raise ValueError(f"unknown arrival process {cfg.arrival!r}")
    rng = np.random.default_rng(cfg.seed)
    prefixes = {
        p.name: rng.integers(0, cfg.vocab, size=p.prefix_len).astype(np.int32)
        for p in cfg.prefix_populations}

    mean = cfg.mean_interarrival_ticks
    shape = 1.0 / cfg.burstiness          # gamma: cv^2 == burstiness
    burst_mean = mean / cfg.mmpp_burst_factor
    # idle-state mean chosen so the 50/50 stationary mix preserves `mean`
    idle_mean = 2.0 * mean - burst_mean
    in_burst = True

    reqs: List[TraceRequest] = []
    t = 0.0
    for uid in range(cfg.n_requests):
        if uid > 0:
            if cfg.arrival == "gamma":
                t += rng.gamma(shape, mean / shape)
            else:
                if rng.random() < cfg.mmpp_p_switch:
                    in_burst = not in_burst
                t += rng.exponential(burst_mean if in_burst else idle_mean)
        tier = _weighted(rng, cfg.tiers)
        plen = _lognormal(rng, cfg.prompt_len_mean, cfg.prompt_len_sigma,
                          cfg.prompt_len_max)
        max_new = _lognormal(rng, cfg.new_tokens_mean, cfg.new_tokens_sigma,
                             cfg.new_tokens_max)
        population = None
        if cfg.prefix_populations and rng.random() < cfg.prefix_share_p:
            population = _weighted(rng, cfg.prefix_populations).name
        # the fresh tail is drawn even for population members, AFTER the
        # membership decision, so every request consumes an identical
        # number of rng draws per branch and the trace stays reproducible
        if population is not None:
            head = prefixes[population]
            tail_len = max(plen, 1)
            tail = rng.integers(0, cfg.vocab, size=tail_len).astype(np.int32)
            prompt = np.concatenate([head, tail])
        else:
            prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        reqs.append(TraceRequest(
            uid=uid, arrival_tick=int(t), prompt=prompt,
            max_new_tokens=max_new, tier=tier.name, priority=tier.priority,
            deadline_ticks=tier.deadline_ticks, population=population))
    return Trace(config=cfg, requests=reqs, prefixes=prefixes)


# --------------------------------------------------------------------------- #
# SLO scoring
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class SLO:
    """Per-request latency objectives in deterministic engine ticks (the
    tick clock is what makes goodput reproducible across machines; the
    report carries wall-second percentiles alongside for operators).  A
    finished request MEETS the SLO iff its TTFT and its worst inter-token
    gap are both within bounds."""

    ttft_ticks: int = 20
    gap_ticks: int = 4

    def met(self, req: EngineRequest) -> bool:
        return (req.done
                and req.ttft_ticks is not None
                and req.ttft_ticks <= self.ttft_ticks
                and req.max_gap_ticks <= self.gap_ticks)


# admission-time rejection reasons = "shed" (the request was turned away
# by admission control — including a queued victim evicted by tier-aware
# overload shedding); anything else with `dropped` set (deadline expiry)
# is a mid-flight drop
_SHED_REASONS = ("queue_full", "too_long", "empty", "shed_low_tier")


def _tier_summary(reqs: List[EngineRequest], slo: SLO,
                  wall_s: float) -> Dict[str, Any]:
    fin = [r for r in reqs if r.done]
    shed = [r for r in reqs if r.dropped in _SHED_REASONS]
    dropped = [r for r in reqs
               if r.dropped is not None and r.dropped not in _SHED_REASONS]
    incomplete = [r for r in reqs if not r.done and r.dropped is None]
    met = [r for r in fin if slo.met(r)]
    ttfts = [r.ttft_ticks for r in fin if r.ttft_ticks is not None]
    gaps = [r.max_gap_ticks for r in fin]
    good_tokens = sum(len(r.out_tokens) for r in met)
    return {
        "n_offered": len(reqs),
        "n_finished": len(fin),
        "n_shed": len(shed),
        "n_dropped": len(dropped),
        "n_incomplete": len(incomplete),   # 0 unless max_ticks cut us off
        "n_slo_met": len(met),
        # None, not 0.0, when nothing finished: a tier with no data has
        # no attainment — the same no-data-is-null contract as `_pct`
        # (repro.tools.report renders it as an em-dash)
        "slo_attainment": len(met) / len(fin) if fin else None,
        "goodput_requests_per_s": len(met) / wall_s if wall_s > 0 else 0.0,
        "goodput_tokens_per_s": good_tokens / wall_s if wall_s > 0 else 0.0,
        "ttft_ticks": _pct_dict(ttfts),
        "gap_ticks": _pct_dict(gaps),
        "ttft_s": _pct_dict([r.ttft_s for r in fin if r.ttft_s is not None]),
        "p99_within_slo": bool(ttfts and gaps
                               and _pct_dict(ttfts)["p99"] <= slo.ttft_ticks
                               and _pct_dict(gaps)["p99"] <= slo.gap_ticks),
    }


def run_load(engine: Engine, trace: Trace, slo: SLO, *,
             max_ticks: int = 200_000,
             tier_blind: bool = False) -> Dict[str, Any]:
    """Drive ``engine`` through ``trace`` and score it against ``slo``.

    Each request is submitted when the engine's tick clock reaches its
    arrival tick (ticks advance even while the engine idles, so quiet
    stretches of a bursty trace really are quiet).  Returns the load
    report: overall + per-tier goodput/shedding/percentiles, trace stats,
    the engine metrics summary, and pool stats when paged.  Conservation
    (offered == finished + shed + dropped) is asserted, not assumed.

    ``tier_blind=True`` strips every request's priority at submit (tier
    labels are kept for scoring): the engine schedules pure FIFO with
    tier-blind queue-full shedding — the baseline the serve_bench
    ``overload`` section compares tier-aware scheduling against."""
    pending = sorted(trace.requests, key=lambda r: (r.arrival_tick, r.uid))
    base = engine.tick      # engine may have been warmed already
    submitted: List[EngineRequest] = []
    i = 0
    while (i < len(pending) or engine.has_work()) \
            and engine.tick - base < max_ticks:
        now = engine.tick - base
        while i < len(pending) and pending[i].arrival_tick <= now:
            tr = pending[i]
            req = EngineRequest(
                uid=tr.uid, prompt=tr.prompt,
                max_new_tokens=tr.max_new_tokens,
                priority=0 if tier_blind else tr.priority,
                tier=tr.tier,
                deadline_tick=(None if tr.deadline_ticks is None
                               else engine.tick + tr.deadline_ticks))
            submitted.append(req)
            engine.submit(req)      # False -> shed; req.dropped says why
            i += 1
        engine.step()
    wall_s = engine.metrics.wall_s
    report: Dict[str, Any] = {
        "slo": {"ttft_ticks": slo.ttft_ticks, "gap_ticks": slo.gap_ticks},
        "trace": trace.stats(),
        "ticks": engine.tick - base,
        "wall_s": wall_s,
        "overall": _tier_summary(submitted, slo, wall_s),
        "tiers": {
            tier.name: _tier_summary(
                [r for r in submitted if r.tier == tier.name], slo, wall_s)
            for tier in trace.config.tiers},
        "engine": engine.metrics.summary(),
    }
    if engine.paged:
        report["pool"] = engine.stepper.pool.stats()
    ov = report["overall"]
    assert (ov["n_finished"] + ov["n_shed"] + ov["n_dropped"]
            + ov["n_incomplete"] == ov["n_offered"]), \
        "load accounting lost a request"
    return report
