"""Continuous batching for serving (slot-based, vLLM-style scheduling on a
fixed decode batch).

A fixed decode batch of ``n_slots`` sequences runs every step; finished
slots (EOS or max_new_tokens) are immediately refilled from the request
queue via a single-sequence prefill whose cache is spliced into the slot.
Throughput = busy-slot fraction x decode rate, so the scheduler's job is
keeping slots busy — the test asserts slot reuse and per-request output
correctness against a no-batching reference.

The queue/slot bookkeeping lives in :class:`SlotScheduler`, shared with
the Program-backed serving engine (:mod:`repro.runtime.engine`): priority
FIFO admission, bounded-queue admission control, and conservation
accounting (every submitted request reaches exactly one terminal state —
finished, rejected, or dropped — and is handed out exactly once).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ContinuousBatcher", "SlotScheduler"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class SlotScheduler:
    """Queue + slot bookkeeping for fixed-batch serving.

    Requests are admitted to free slots in (priority desc, submit order)
    — FIFO among equal priorities (``priority`` is read via ``getattr``,
    default 0, so plain :class:`Request` objects work unchanged).  With
    ``max_queue`` set, :meth:`submit` applies admission control: a full
    queue rejects instead of growing without bound.  A tier-aware caller
    can instead make room with :meth:`shed_lowest` — evict the lowest-
    priority, most recently queued request below a priority floor — so
    overload sheds low-tier work before high-tier work is turned away
    (the policy lives in the engine; this is only the mechanism).

    Invariants (property-tested in ``tests/test_serving_engine.py``):

    * conservation — ``n_submitted == n_rejected + n_finished + n_dropped
      + len(queue) + busy_slots`` at every step;
    * each request is admitted at most once and finalised at most once;
    * ``len(active slots) <= n_slots`` always.
    """

    def __init__(self, n_slots: int, max_queue: Optional[int] = None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.active: List[Optional[Any]] = [None] * n_slots
        self._heap: List[Tuple[int, int, Any]] = []   # (-priority, seq, req)
        self._active_seq: Dict[int, int] = {}         # slot -> submit seq
        self._seq = 0
        self.n_submitted = 0
        self.n_rejected = 0
        self.n_finished = 0
        self.n_dropped = 0

    # ------------------------------------------------------------------ #
    def submit(self, req: Any) -> bool:
        """Queue ``req``; False when admission control rejects it."""
        self.n_submitted += 1
        if self.max_queue is not None and len(self._heap) >= self.max_queue:
            self.n_rejected += 1
            return False
        heapq.heappush(self._heap, (-getattr(req, "priority", 0), self._seq, req))
        self._seq += 1
        return True

    def reject(self, req: Any) -> None:
        """Count a request the caller refused before queueing (invalid
        prompt, cannot fit the cache, ...) so conservation still holds —
        the accounting stays in one place instead of callers poking
        counters."""
        self.n_submitted += 1
        self.n_rejected += 1

    @property
    def queue_len(self) -> int:
        return len(self._heap)

    def peek(self) -> Optional[Any]:
        """The request :meth:`admit` would consider first, or None."""
        return self._heap[0][2] if self._heap else None

    @property
    def busy_slots(self) -> int:
        return sum(1 for s in self.active if s is not None)

    def has_work(self) -> bool:
        return bool(self._heap) or any(s is not None for s in self.active)

    def admit(self, can_admit: Optional[Callable[[Any], bool]] = None
              ) -> List[Tuple[int, Any]]:
        """Fill free slots from the queue; returns newly (slot, request)
        pairs in admission order.

        ``can_admit`` gates each candidate on a resource check beyond slot
        count (the paged engine passes a block-availability predicate).
        Admission stops at the first refused request rather than skipping
        past it: FIFO-among-equal-priority order is part of the scheduler
        contract, so a briefly-unadmittable request causes head-of-line
        blocking instead of being silently overtaken."""
        out: List[Tuple[int, Any]] = []
        for slot in range(self.n_slots):
            if self.active[slot] is None and self._heap:
                if can_admit is not None and not can_admit(self._heap[0][2]):
                    break
                _, seq, req = heapq.heappop(self._heap)
                self.active[slot] = req
                self._active_seq[slot] = seq
                out.append((slot, req))
        return out

    def preempt(self, slot: int) -> Any:
        """Evict ``slot``'s request back into the queue at its ORIGINAL
        submit position (the self-healing engine requeues every in-flight
        request after a crashed tick).  Not a terminal state: no counter
        moves (busy -> queued keeps conservation), and ``max_queue`` is
        not applied — already-admitted work is never shed by its own
        recovery."""
        seq = self._active_seq[slot]
        req = self._release(slot)
        heapq.heappush(self._heap, (-getattr(req, "priority", 0), seq, req))
        return req

    def finish(self, slot: int) -> Any:
        """Release ``slot``, counting its request as finished."""
        req = self._release(slot)
        self.n_finished += 1
        return req

    def drop(self, slot: int) -> Any:
        """Release ``slot``, counting its request as dropped (deadline,
        cancellation, ...)."""
        req = self._release(slot)
        self.n_dropped += 1
        return req

    def _release(self, slot: int) -> Any:
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not active")
        self.active[slot] = None
        self._active_seq.pop(slot, None)
        return req

    def shed_lowest(self, min_priority: int) -> Optional[Any]:
        """Evict and return the queued request with the LOWEST priority
        strictly below ``min_priority`` (ties broken toward the most
        recently submitted — the entry with the least waiting time and
        the least claim on FIFO fairness).  ``None`` when every queued
        request is at or above the floor.  The victim is counted as
        rejected: shed-at-admission is a terminal state, and conservation
        (queued -> rejected) still balances."""
        victim_i = None
        for i, (neg_pri, seq, _req) in enumerate(self._heap):
            if -neg_pri >= min_priority:
                continue
            if victim_i is None or (neg_pri, seq) > (
                    self._heap[victim_i][0], self._heap[victim_i][1]):
                victim_i = i
        if victim_i is None:
            return None
        req = self._heap.pop(victim_i)[2]
        heapq.heapify(self._heap)
        self.n_rejected += 1
        return req

    def drop_queued(self, pred: Callable[[Any], bool]) -> List[Any]:
        """Remove queued requests matching ``pred`` (e.g. expired
        deadlines) before they reach a slot."""
        keep, dropped = [], []
        for entry in self._heap:
            (dropped if pred(entry[2]) else keep).append(entry)
        if dropped:
            self._heap = keep
            heapq.heapify(self._heap)
            self.n_dropped += len(dropped)
        return [e[2] for e in dropped]

    def check_conservation(self) -> None:
        """Raise AssertionError if any request was lost or duplicated."""
        accounted = (self.n_rejected + self.n_finished + self.n_dropped
                     + len(self._heap) + self.busy_slots)
        assert accounted == self.n_submitted, (
            f"conservation violated: submitted={self.n_submitted} "
            f"accounted={accounted}")


class ContinuousBatcher:
    """Drives (prefill_fn, decode_fn) over a slot-based batch.

    prefill_fn(params, tokens (1, L)) -> (logits (1, V), caches_1, lengths_1)
    decode_fn(params, tokens (B,), caches, lengths) -> (logits (B, V), caches)
    """

    def __init__(self, model, params, *, n_slots: int, cache_cap: int,
                 eos_id: int = 1, greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_cap = cache_cap
        self.eos_id = eos_id
        self.sched = SlotScheduler(n_slots)
        self.submitted: List[Request] = []
        self.caches = model.init_caches(n_slots, cache_cap)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.next_token = jnp.zeros((n_slots,), jnp.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, {"tokens": t},
                                       cache_cap=cache_cap))
        self.steps = 0
        self.busy_slot_steps = 0

    @property
    def active(self) -> List[Optional[Request]]:
        return self.sched.active

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.sched.submit(req)
        self.submitted.append(req)

    def _splice_cache(self, slot: int, cache1: Any) -> None:
        """Write a single-sequence prefill cache into batch slot ``slot``."""
        self.caches = jax.tree.map(
            lambda full, one: _set_slot(full, one, slot),
            self.caches, cache1)

    def _admit(self) -> None:
        for slot, req in self.sched.admit():
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1, lengths1 = self._prefill(self.params, toks)
            self._splice_cache(slot, cache1)
            self.lengths = self.lengths.at[slot].set(int(lengths1[0]))
            first = int(jnp.argmax(logits[0]))
            req.out_tokens.append(first)
            self.next_token = self.next_token.at[slot].set(first)

    # ------------------------------------------------------------------ #
    def step(self) -> None:
        """One decode step over all slots (idle slots compute but are
        ignored — the fixed-batch tradeoff)."""
        self._admit()
        logits, self.caches = self._decode(self.params, self.next_token,
                                           self.caches, self.lengths)
        active = jnp.asarray([r is not None for r in self.active], jnp.int32)
        self.lengths = self.lengths + active
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.next_token = nxt
        self.steps += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.busy_slot_steps += 1
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.sched.finish(slot)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue and slots drain (or ``max_steps``); returns
        every submitted request that finished — including ones already
        admitted to slots before ``run()`` was called (a queue snapshot
        would drop those).  Finished requests are handed out exactly once:
        they leave ``submitted``, so a long-lived server neither re-delivers
        nor accumulates them."""
        while self.sched.has_work() and self.steps < max_steps:
            self.step()
        finished = [r for r in self.submitted if r.done]
        self.submitted = [r for r in self.submitted if not r.done]
        return finished

    @property
    def utilisation(self) -> float:
        return self.busy_slot_steps / max(self.steps * self.n_slots, 1)


def _set_slot(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Set batch index ``slot`` of ``full`` from single-batch ``one``.
    Works for both stacked (n_periods, B, ...) and plain (B, ...) leaves:
    the batch dim is the first whose size differs (one has size 1)."""
    for axis in range(full.ndim):
        if one.shape[axis] == 1 and full.shape[axis] != 1:
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one)
    raise ValueError(f"no batch axis found: {full.shape} vs {one.shape}")
