"""Continuous batching for serving (slot-based, vLLM-style scheduling on a
fixed decode batch).

A fixed decode batch of ``n_slots`` sequences runs every step; finished
slots (EOS or max_new_tokens) are immediately refilled from the request
queue via a single-sequence prefill whose cache is spliced into the slot.
Throughput = busy-slot fraction x decode rate, so the scheduler's job is
keeping slots busy — the test asserts slot reuse and per-request output
correctness against a no-batching reference.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ContinuousBatcher"]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Drives (prefill_fn, decode_fn) over a slot-based batch.

    prefill_fn(params, tokens (1, L)) -> (logits (1, V), caches_1, lengths_1)
    decode_fn(params, tokens (B,), caches, lengths) -> (logits (B, V), caches)
    """

    def __init__(self, model, params, *, n_slots: int, cache_cap: int,
                 eos_id: int = 1, greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_cap = cache_cap
        self.eos_id = eos_id
        self.queue: Deque[Request] = deque()
        self.submitted: List[Request] = []
        self.active: List[Optional[Request]] = [None] * n_slots
        self.caches = model.init_caches(n_slots, cache_cap)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.next_token = jnp.zeros((n_slots,), jnp.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t: model.prefill(p, {"tokens": t},
                                       cache_cap=cache_cap))
        self.steps = 0
        self.busy_slot_steps = 0

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.queue.append(req)
        self.submitted.append(req)

    def _splice_cache(self, slot: int, cache1: Any) -> None:
        """Write a single-sequence prefill cache into batch slot ``slot``."""
        self.caches = jax.tree.map(
            lambda full, one: _set_slot(full, one, slot),
            self.caches, cache1)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache1, lengths1 = self._prefill(self.params, toks)
                self._splice_cache(slot, cache1)
                self.lengths = self.lengths.at[slot].set(int(lengths1[0]))
                first = int(jnp.argmax(logits[0]))
                req.out_tokens.append(first)
                self.next_token = self.next_token.at[slot].set(first)
                self.active[slot] = req

    # ------------------------------------------------------------------ #
    def step(self) -> None:
        """One decode step over all slots (idle slots compute but are
        ignored — the fixed-batch tradeoff)."""
        self._admit()
        logits, self.caches = self._decode(self.params, self.next_token,
                                           self.caches, self.lengths)
        active = jnp.asarray([r is not None for r in self.active], jnp.int32)
        self.lengths = self.lengths + active
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.next_token = nxt
        self.steps += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.busy_slot_steps += 1
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.active[slot] = None

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue and slots drain (or ``max_steps``); returns
        every submitted request that finished — including ones already
        admitted to slots before ``run()`` was called (a queue snapshot
        would drop those).  Finished requests are handed out exactly once:
        they leave ``submitted``, so a long-lived server neither re-delivers
        nor accumulates them."""
        while (self.queue or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()
        finished = [r for r in self.submitted if r.done]
        self.submitted = [r for r in self.submitted if not r.done]
        return finished

    @property
    def utilisation(self) -> float:
        return self.busy_slot_steps / max(self.steps * self.n_slots, 1)


def _set_slot(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Set batch index ``slot`` of ``full`` from single-batch ``one``.
    Works for both stacked (n_periods, B, ...) and plain (B, ...) leaves:
    the batch dim is the first whose size differs (one has size 1)."""
    for axis in range(full.ndim):
        if one.shape[axis] == 1 and full.shape[axis] != 1:
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one)
    raise ValueError(f"no batch axis found: {full.shape} vs {one.shape}")
