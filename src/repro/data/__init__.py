"""Data pipeline: deterministic synthetic stream, packing, prefetch."""

from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticLM, pack_documents

__all__ = ["PrefetchLoader", "SyntheticLM", "pack_documents"]
