"""Deterministic synthetic token pipeline.

Tokens are a pure function of (seed, step, index) via a counter-based
philox-style mix — any host can materialise exactly its shard of any step
without coordination (the property real multi-host input pipelines need:
restart-stable, shardable, no state files).  The "documents" have a
repeating-ngram structure so a real model can actually reduce loss on them
(used by examples/train_lm.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["SyntheticLM", "pack_documents"]


def _mix(x: np.ndarray) -> np.ndarray:
    """64-bit splitmix-style mixer, vectorised."""
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


@dataclass
class SyntheticLM:
    """Batched LM stream: batch["tokens"] (B,S) int32, batch["labels"] (B,S)
    = next-token targets."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    ngram: int = 8           # structure scale: tokens repeat with period
                             # `ngram` within a doc -> learnable signal
    n_docs: int = 0          # 0: fresh docs every step (generalisation /
                             # induction task); >0: cycle a fixed doc pool
                             # (memorisable -> loss falls within ~100 steps)

    def batch_at(self, step: int, *, shard: int = 0, num_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        assert self.batch % num_shards == 0
        b_loc = self.batch // num_shards
        rows = np.arange(b_loc, dtype=np.uint64) + shard * b_loc
        cols = np.arange(self.seq_len + 1, dtype=np.uint64)
        base = np.uint64(self.seed) << np.uint64(40)
        # document ids: unique per (step, row), or cycled through a fixed pool
        ids = np.uint64(step) * np.uint64(self.batch) + rows
        if self.n_docs:
            ids = ids % np.uint64(self.n_docs)
        doc = _mix(base ^ _mix(ids * np.uint64(2654435761) + np.uint64(1)))
        # position folded modulo ngram: the sequence repeats with period
        # `ngram` within a doc (learnable copy structure)
        pos = cols % np.uint64(self.ngram)
        grid = _mix(doc[:, None] ^ _mix(pos[None, :] + np.uint64(17)))
        toks = (grid % np.uint64(self.vocab)).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def pack_documents(docs, seq_len: int, pad_id: int = 0,
                   eos_id: int = 1) -> np.ndarray:
    """Greedy sequence packing: concatenate docs separated by EOS, emit
    fixed-length rows. Returns (n_rows, seq_len) int32."""
    buf: list = []
    rows = []
    for d in docs:
        buf.extend(int(t) for t in d)
        buf.append(eos_id)
        while len(buf) >= seq_len:
            rows.append(buf[:seq_len])
            buf = buf[seq_len:]
    if buf:
        rows.append(buf + [pad_id] * (seq_len - len(buf)))
    return np.asarray(rows, dtype=np.int32)
