"""Host-sharded loader with background prefetch (double buffering).

Wraps any step->batch source (e.g. SyntheticLM.batch_at) and keeps
``prefetch`` batches materialised ahead on a worker thread, so host input
prep overlaps device compute — the standard input-pipeline overlap trick,
testable on CPU.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

__all__ = ["PrefetchLoader"]


class PrefetchLoader:
    def __init__(self, batch_fn: Callable[[int], Any], *, start_step: int = 0,
                 prefetch: int = 2):
        self._fn = batch_fn
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
