"""Immutable compiled Program artifact + the top-level ``compile`` entrypoint.

This splits the old monolithic ``Executor`` into its two real halves:

* :func:`compile` — the staged front half: run a pass pipeline
  (:class:`~repro.core.pipeline.PassManager`), resolve a backend per node
  under a :class:`~repro.core.selector.BackendPolicy`, freeze the result.
* :class:`Program` — the back half: an immutable artifact holding the
  simplified graph, the frozen backend assignment, the analytic cost table,
  and the jitted callable.  Programs can be saved to / loaded from an OXF
  bundle (the assignment is pinned into each node's ``backend`` field), so a
  tuned deployment survives process restarts without re-tuning.

Typical use::

    from repro.core import compile, AutotunePolicy

    prog = compile(graph, policy=AutotunePolicy(cache_path="tune.json"))
    (y,) = prog(x=x)
    prog.save("model_dir")           # graph + weights + frozen assignment
    prog2 = Program.load("model_dir")  # no re-measurement, same assignment
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from types import MappingProxyType
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.importer import load_graph, save_graph
from repro.core.ir import Graph, Node, TensorSpec, topological_order
from repro.core.pipeline import PassManager, PassStats, default_pipeline
from repro.core.registry import Cost, get_impl
from repro.core.selector import BackendPolicy, FixedPolicy

__all__ = ["Program", "NodeReport", "compile"]


def _partition_spec_to_json(spec) -> List[Any]:
    """PartitionSpec -> JSON dim entries (None | axis name | [axis names])."""
    return [list(e) if isinstance(e, (tuple, list)) else e for e in spec]


def _partition_spec_from_json(entries: Sequence[Any]):
    from jax.sharding import PartitionSpec
    return PartitionSpec(
        *[tuple(e) if isinstance(e, list) else e for e in entries])


@dataclass
class NodeReport:
    name: str
    op: str
    backend: str
    seconds: float
    cost: Cost
    out_spec: TensorSpec


class Program:
    """A compiled inference program: graph + frozen backend assignment.

    Instances are immutable by convention (the assignment mapping is
    read-only; the graph must not be mutated after construction) — compile a
    new Program instead of editing one.  The jitted callable is built lazily
    on first call and cached.
    """

    def __init__(self, graph: Graph, assignment: Mapping[str, str],
                 pass_stats: Sequence[PassStats] = ()):
        from repro.core.passes import infer_shapes
        # freeze the partition layout stamped by the `partition` pass before
        # any Graph rebuild below can drop the dynamic attributes
        part_specs = getattr(graph, "partition_specs", None)
        self._partition: Optional[Dict[str, Mapping[str, Any]]] = None
        if part_specs is not None:
            self._partition = {
                "mesh": MappingProxyType(
                    dict(getattr(graph, "partition_mesh", {}) or {})),
                "specs": MappingProxyType(dict(part_specs)),
            }
        self._graph = graph if graph.value_info else infer_shapes(graph)
        self._order = topological_order(self._graph)
        missing = [n.name for n in self._order if n.name not in assignment]
        if missing:
            raise ValueError(f"assignment missing nodes: {missing[:5]}")
        self._assignment: Mapping[str, str] = MappingProxyType(dict(assignment))
        self._pass_stats: Tuple[PassStats, ...] = tuple(pass_stats)
        # Frozen analytic cost table: node name -> (backend, Cost).
        table: Dict[str, Tuple[str, Cost]] = {}
        for node in self._order:
            b = self._assignment[node.name]
            in_specs = [self._graph.spec_of(v) for v in node.inputs]
            table[node.name] = (b, get_impl(node.op, b).cost(in_specs, node.attrs))
        self._cost_table: Mapping[str, Tuple[str, Cost]] = MappingProxyType(table)
        self._jitted: Optional[Callable] = None
        self._stored: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------ #
    @property
    def graph(self) -> Graph:
        return self._graph

    @property
    def assignment(self) -> Dict[str, str]:
        """node name -> chosen backend (copy; the Program's own is frozen)."""
        return dict(self._assignment)

    @property
    def pass_stats(self) -> Tuple[PassStats, ...]:
        """Per-pass compile-time profile from the pipeline that built this."""
        return self._pass_stats

    @property
    def cost_table(self) -> Mapping[str, Tuple[str, Cost]]:
        return self._cost_table

    @property
    def partition(self) -> Optional[Dict[str, Mapping[str, Any]]]:
        """Frozen partition layout, or None for unpartitioned Programs.

        ``{"mesh": {axis: size}, "specs": {value name: PartitionSpec}}``
        with a spec for every graph input, param and output — stamped by
        ``compile(mesh=...)``'s `partition` pass, serialized through OXF,
        and used by the serving engine to ``jax.device_put`` caches and
        params onto NamedShardings with zero re-planning after a load."""
        return self._partition

    def costs(self) -> List[Tuple[Node, str, Cost]]:
        return [(node, *self._cost_table[node.name]) for node in self._order]

    def total_cost(self) -> Cost:
        total = Cost()
        for _, cost in self._cost_table.values():
            total = total + cost
        return total

    # ------------------------------------------------------------------ #
    def _trace(self, params: Dict[str, Any], inputs: Dict[str, Any]) -> Tuple[Any, ...]:
        env: Dict[str, Any] = {}
        env.update(params)
        env.update(inputs)
        for node in self._order:
            fn = get_impl(node.op, self._assignment[node.name])
            args = [env[v] for v in node.inputs]
            outs = fn(args, node.attrs)
            for v, val in zip(node.outputs, outs):
                env[v] = val
        return tuple(env[v] for v in self._graph.outputs)

    def callable(self) -> Callable[..., Tuple[Any, ...]]:
        """Returns jitted ``f(inputs: dict, params: dict|None) -> tuple``.

        ``params`` defaults to the graph's stored parameters; passing them
        explicitly supports functional weight updates (training loops)."""
        if self._jitted is None:
            jf = jax.jit(self._trace)
            stored = self._stored_params()

            def call(inputs: Dict[str, Any], params: Optional[Dict[str, Any]] = None):
                return jf(stored if params is None else params, inputs)

            self._jitted = call
        return self._jitted

    def _stored_params(self) -> Dict[str, Any]:
        """Device copies of the graph params, built once and shared by
        every entry point (``__call__`` and each ``bind()``) so N bound
        callables don't hold N copies of the weights."""
        if self._stored is None:
            self._stored = {k: jnp.asarray(v)
                            for k, v in self._graph.params.items()}
        return self._stored

    def __call__(self, **inputs: Any) -> Tuple[Any, ...]:
        missing = set(self._graph.inputs) - set(inputs)
        if missing:
            raise ValueError(f"missing graph inputs: {sorted(missing)}")
        return self.callable()(inputs)

    def bind(self, *names: str,
             donate: Sequence[str] = ()) -> Callable[..., Tuple[Any, ...]]:
        """Positional fast-call path: ``bind("x", "y")`` returns
        ``f(x_arr, y_arr) -> outputs`` with stored params closed over and
        input names validated once, here, instead of per call.  This is
        the serving engine's per-step dispatch: on a hot loop the kwargs
        packing and missing-input check of :meth:`__call__` are measurable
        overhead (``benchmarks/serve_bench.py`` reports both paths).

        ``donate`` names inputs whose buffers the caller will not reuse —
        functional state threaded through the call, like the serving
        engine's KV caches — letting XLA alias them into same-shaped
        outputs instead of copying (a no-op on backends without donation
        support, e.g. CPU).  A donated buffer is consumed: pass the
        previous call's output, never the same array twice.

        With no arguments, inputs bind in the graph's declared order.
        Each ``bind()`` builds its own jitted entry point — bind once and
        reuse the returned callable."""
        order: Tuple[str, ...] = names or tuple(self._graph.inputs)
        unknown = set(order) - set(self._graph.inputs)
        if unknown:
            raise ValueError(f"not graph inputs: {sorted(unknown)}")
        if set(order) != set(self._graph.inputs):
            missing = set(self._graph.inputs) - set(order)
            raise ValueError(f"bind() must cover every input; missing {sorted(missing)}")
        bad_donate = set(donate) - set(order)
        if bad_donate:
            raise ValueError(f"donate names not inputs: {sorted(bad_donate)}")
        stored = self._stored_params()
        donate_argnums = tuple(1 + i for i, n in enumerate(order)
                               if n in set(donate))

        def positional(params: Dict[str, Any], *args: Any) -> Tuple[Any, ...]:
            return self._trace(params, dict(zip(order, args)))

        jf = jax.jit(positional, donate_argnums=donate_argnums)

        def fast(*args: Any) -> Tuple[Any, ...]:
            return jf(stored, *args)

        return fast

    # ------------------------------------------------------------------ #
    def lower(self, **input_specs: jax.ShapeDtypeStruct):
        """``jax.jit(...).lower(...)`` for dry-run / cost analysis."""
        stored = {k: jax.ShapeDtypeStruct(jnp.shape(v), jnp.asarray(v).dtype)
                  for k, v in self._graph.params.items()}
        specs = input_specs or {
            k: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype))
            for k, s in self._graph.inputs.items()}
        return jax.jit(self._trace).lower(stored, specs)

    # ------------------------------------------------------------------ #
    def run_instrumented(self, **inputs: Any) -> Tuple[Tuple[Any, ...], List[NodeReport]]:
        """Eager per-node execution with wall-clock timing — the paper's
        individual-layer evaluation. Each node's impl is jitted separately
        (so we time the op, not Python overhead), warmed once, then timed."""
        env: Dict[str, Any] = {k: jnp.asarray(v) for k, v in self._graph.params.items()}
        env.update({k: jnp.asarray(v) for k, v in inputs.items()})
        reports: List[NodeReport] = []
        for node in self._order:
            backend = self._assignment[node.name]
            fn = get_impl(node.op, backend)
            args = [env[v] for v in node.inputs]
            jf = jax.jit(lambda a, _fn=fn, _at=node.attrs: _fn(a, _at))
            outs = jf(args)
            jax.block_until_ready(outs)  # warm
            t0 = time.perf_counter()
            outs = jf(args)
            jax.block_until_ready(outs)
            dt = time.perf_counter() - t0
            reports.append(NodeReport(
                name=node.name, op=node.op, backend=backend, seconds=dt,
                cost=self._cost_table[node.name][1],
                out_spec=self._graph.spec_of(node.outputs[0])))
            for v, val in zip(node.outputs, outs):
                env[v] = val
        return tuple(env[v] for v in self._graph.outputs), reports

    # ------------------------------------------------------------------ #
    # Persistence (OXF bundle: model.json + weights.npz + program.json)
    # ------------------------------------------------------------------ #
    def save(self, path: str) -> None:
        """Serialize graph, weights AND the frozen backend assignment.

        The assignment rides inside the OXF model.json (each node's
        ``backend`` field is pinned), so any OXF loader reconstructs the
        same per-node backends; ``program.json`` additionally records the
        assignment and cost table for human inspection."""
        pinned = self._graph.clone()
        for node in pinned.nodes:
            node.backend = self._assignment[node.name]
        save_graph(pinned, path)
        from repro.core.quant import is_quantized
        meta = {
            "assignment": dict(self._assignment),
            "cost_table": {name: {"backend": b, "flops": c.flops, "bytes": c.bytes}
                           for name, (b, c) in self._cost_table.items()},
            "quantized": is_quantized(self._graph),
        }
        if self._partition is not None:
            # written only for partitioned Programs — unpartitioned bundles
            # keep their exact pre-existing bytes (OXF additive evolution)
            meta["partition"] = {
                "mesh": dict(self._partition["mesh"]),
                "specs": {name: _partition_spec_to_json(spec)
                          for name, spec in self._partition["specs"].items()},
            }
        with open(os.path.join(path, "program.json"), "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str, policy: Optional[BackendPolicy] = None,
             mesh: Optional[Any] = None) -> "Program":
        """Rebuild a Program from :meth:`save` output.  The pinned per-node
        backends win over ``policy`` (which only fills gaps, e.g. for
        bundles written by a plain ``save_graph``), so no re-tuning or
        re-measurement happens here.

        A bundle saved from a partitioned Program restores its recorded
        PartitionSpecs verbatim — zero re-planning.  Passing ``mesh``
        validates the recorded axis layout against it (clear ValueError on
        mismatch); for bundles without a recorded partition, ``mesh``
        partitions the loaded graph fresh via the `partition` pass."""
        g = load_graph(path)
        part = None
        meta_path = os.path.join(path, "program.json")
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                part = json.load(f).get("partition")
        if part is None:
            return compile(g, policy=policy, pipeline=(), mesh=mesh)
        if mesh is not None:
            from repro.sharding.specs import check_mesh_compat
            check_mesh_compat(part["mesh"], mesh)
        prog = compile(g, policy=policy, pipeline=())
        prog._partition = {
            "mesh": MappingProxyType(
                {a: int(s) for a, s in part["mesh"].items()}),
            "specs": MappingProxyType(
                {n: _partition_spec_from_json(e)
                 for n, e in part["specs"].items()}),
        }
        return prog


def compile(graph: Graph, policy: Optional[BackendPolicy] = None,
            pipeline: Optional[Union[PassManager, Sequence]] = None,
            *, validate: bool = False, quantize: Optional[str] = None,
            calib_data: Any = None,
            calib_ranges: Optional[Mapping[str, Any]] = None,
            mesh: Optional[Any] = None) -> Program:
    """Graph -> Program: the staged compilation entrypoint.

    Parameters
    ----------
    graph:
        The input GraphIR (left untouched).
    policy:
        Backend selection policy; defaults to :class:`FixedPolicy`
        (xla-then-ref).  Per-node ``Node.backend`` pins always win.
    pipeline:
        ``None`` (default) runs the standard simplify pipeline; a
        :class:`PassManager` runs as given; a sequence of pass
        names/callables is wrapped in a PassManager; an empty sequence
        skips rewriting entirely (shape inference still happens).
    validate:
        Forwarded to the default pipeline's inter-pass validation.
    quantize:
        ``"int8"`` runs post-training quantization as an extra compile
        stage after the simplify pipeline: calibration (when
        ``calib_data`` is given) followed by the
        :func:`repro.core.quant.quantize_graph` rewrite.  Weights become
        per-channel int8 params; activation scales are frozen from the
        calibration ranges.
    calib_data:
        Representative inputs for the calibration observer — a dict of
        input arrays, a sequence of dicts, or (single-input graphs) a bare
        array.  Without it, quantization is weight-only and the ``ref``
        int8 backend falls back to dynamic per-batch activation scales.
    calib_ranges:
        Precomputed value ranges (``repro.core.quant.calibrate`` output),
        used instead of running calibration here.  This is how several
        shape variants of one model (the serving engine's batched decode /
        prefill Programs and the unbatched reference — same value names,
        different batch/chunk) share one set of activation scales and stay
        numerically identical per sequence.  Mutually exclusive with
        ``calib_data``.
    mesh:
        A ``jax.sharding.Mesh``.  When given, the `partition` pass runs as
        the final compile stage (after every rewrite, so rebuilt Graph
        objects cannot drop the layout): every input/param/output is
        stamped with a PartitionSpec from the serving rules in
        :mod:`repro.sharding.specs`, frozen into ``Program.partition`` and
        serialized through OXF by :meth:`Program.save`.
    """
    from repro.core.passes import infer_shapes
    if pipeline is None:
        pipeline = default_pipeline(validate=validate)
    elif not isinstance(pipeline, PassManager):
        pipeline = PassManager(list(pipeline), validate=validate, name="custom")
    g = pipeline.run(graph)
    if quantize is not None:
        from repro.core import quant
        if quantize != "int8":
            raise ValueError(f"unsupported quantize mode {quantize!r} (only 'int8')")
        if calib_data is not None and calib_ranges is not None:
            raise ValueError("pass calib_data or calib_ranges, not both")
        if calib_ranges is not None:
            ranges: Any = calib_ranges
        else:
            ranges = (quant.calibrate(g, calib_data)
                      if calib_data is not None else None)
        g = quant.quantize_graph(g, ranges)
    if not g.value_info:
        g = infer_shapes(g)
    pass_stats = tuple(pipeline.stats)
    if mesh is not None:
        from repro.core.pipeline import make_partition_pass
        pmesh = PassManager([make_partition_pass(mesh)], name="partition")
        g = pmesh.run(g)
        pass_stats += tuple(pmesh.stats)
    policy = policy or FixedPolicy()
    assignment: Dict[str, str] = {}
    for node in topological_order(g):
        in_specs = [g.spec_of(v) for v in node.inputs]
        assignment[node.name] = policy.resolve(node, in_specs)
    return Program(g, assignment, pass_stats=pass_stats)
