"""Standard neural-network graph operators: declarations + ref/xla backends.

Layout conventions (TPU-native): activations NHWC, conv kernels HWIO.

Each op gets:
  * a shape function (used by ``passes.infer_shapes``),
  * an analytic cost model (used by the cost-model selector and the roofline
    tool when the op lowers to a Pallas custom call),
  * a ``ref`` backend — pure jnp, the oracle,
  * where meaningful, an ``xla`` backend — XLA's fused native lowering (the
    "third-party library" in Orpheus terms),
  * where meaningful, an alternative *algorithm* (e.g. ``winograd`` conv),
    mirroring the paper's GEMM-vs-spatial-pack comparison.

The ``ref`` conv2d IS the paper's GEMM (im2col) convolution, written in jnp;
``kernels/ops.py`` additionally registers the ``pallas`` TPU kernel version.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.ir import TensorSpec
from repro.core.registry import Cost, defop, impl

Attrs = Dict[str, Any]

# --------------------------------------------------------------------------- #
# Helpers
# --------------------------------------------------------------------------- #

def _pair(v) -> Tuple[int, int]:
    if isinstance(v, (tuple, list)):
        return int(v[0]), int(v[1])
    return int(v), int(v)


def _conv_pads(padding, in_hw, k_hw, stride, dilation) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Resolve 'SAME'/'VALID'/explicit padding to ((ph0,ph1),(pw0,pw1))."""
    if isinstance(padding, str):
        pads = []
        for i in range(2):
            eff_k = (k_hw[i] - 1) * dilation[i] + 1
            if padding.upper() == "VALID":
                pads.append((0, 0))
            elif padding.upper() == "SAME":
                out = -(-in_hw[i] // stride[i])
                total = max((out - 1) * stride[i] + eff_k - in_hw[i], 0)
                pads.append((total // 2, total - total // 2))
            else:
                raise ValueError(f"bad padding {padding!r}")
        return tuple(pads)  # type: ignore[return-value]
    (a, b), (c, d) = padding
    return (int(a), int(b)), (int(c), int(d))


def _conv_out_hw(in_hw, k_hw, stride, pads, dilation) -> Tuple[int, int]:
    out = []
    for i in range(2):
        eff_k = (k_hw[i] - 1) * dilation[i] + 1
        out.append((in_hw[i] + pads[i][0] + pads[i][1] - eff_k) // stride[i] + 1)
    return out[0], out[1]


def _conv_geometry(specs: Sequence[TensorSpec], attrs: Attrs):
    x, w = specs[0], specs[1]
    n, h, wd, ci = x.shape
    kh, kw, ci_g, co = w.shape
    stride = _pair(attrs.get("stride", 1))
    dilation = _pair(attrs.get("dilation", 1))
    groups = int(attrs.get("groups", 1))
    pads = _conv_pads(attrs.get("padding", "SAME"), (h, wd), (kh, kw), stride, dilation)
    oh, ow = _conv_out_hw((h, wd), (kh, kw), stride, pads, dilation)
    return n, (h, wd), (kh, kw), ci, co, groups, stride, pads, dilation, (oh, ow)


def _act(x: jax.Array, name: str) -> jax.Array:
    if name in (None, "", "none", "identity", "linear"):
        return x
    if name == "relu":
        return jnp.maximum(x, 0)
    if name == "relu6":
        return jnp.clip(x, 0, 6)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "sigmoid":
        return jax.nn.sigmoid(x)
    if name == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {name!r}")


def _bytes_of(specs: Sequence[TensorSpec]) -> float:
    return float(sum(s.nbytes for s in specs))


def _ew_shape(specs, attrs):
    return [specs[0]]


def _ew_cost(specs, attrs):
    out = specs[0]
    return Cost(flops=float(out.nelems), bytes=_bytes_of(specs) + out.nbytes)

# --------------------------------------------------------------------------- #
# conv2d  (inputs: x NHWC, w HWIO)   — the paper's flagship op
# --------------------------------------------------------------------------- #

def _conv2d_shape(specs, attrs):
    n, _, _, ci, co, groups, _, _, _, (oh, ow) = _conv_geometry(specs, attrs)
    kh, kw, ci_g, _ = specs[1].shape
    if ci_g * groups != ci:
        raise ValueError(f"conv2d channel mismatch: x has {ci}, w expects {ci_g}*{groups}")
    return [TensorSpec((n, oh, ow, co), specs[0].dtype)]


def _conv2d_cost(specs, attrs):
    n, _, (kh, kw), ci, co, groups, _, _, _, (oh, ow) = _conv_geometry(specs, attrs)
    flops = 2.0 * n * oh * ow * co * kh * kw * (ci // groups)
    out_bytes = n * oh * ow * co * np.dtype(specs[0].dtype).itemsize
    return Cost(flops=flops, bytes=_bytes_of(specs) + out_bytes)


defop("conv2d", _conv2d_shape, _conv2d_cost,
      doc="2-D convolution, NHWC x HWIO. attrs: stride, padding, dilation, groups")


def _im2col(x, k_hw, stride, pads, dilation):
    """Extract conv patches -> (N, OH, OW, KH*KW*CI). Pure jnp (GEMM conv)."""
    n, h, w, ci = x.shape
    kh, kw = k_hw
    x = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, 0)))
    oh, ow = _conv_out_hw((h, w), (kh, kw), stride, pads, dilation)
    # Gather rows/cols by advanced indexing — compiles to gathers; fine for
    # the reference path (the Pallas kernel does this in VMEM tiles).
    i = (jnp.arange(oh)[:, None] * stride[0] + jnp.arange(kh)[None, :] * dilation[0])
    j = (jnp.arange(ow)[:, None] * stride[1] + jnp.arange(kw)[None, :] * dilation[1])
    # x: (N, Hp, Wp, C) -> (N, OH, KH, Wp, C) -> (N, OH, KH, OW, KW, C)
    patches = x[:, i, :, :]                    # (N, OH, KH, Wp, C)
    patches = patches[:, :, :, j, :]           # (N, OH, KH, OW, KW, C)
    patches = jnp.transpose(patches, (0, 1, 3, 2, 4, 5))  # (N, OH, OW, KH, KW, C)
    return patches.reshape(n, oh, ow, kh * kw * ci)


@impl("conv2d", "ref", note="GEMM (im2col) convolution in pure jnp — the paper's GEMM backend")
def _conv2d_ref(inputs, attrs):
    x, w = inputs
    kh, kw, ci_g, co = w.shape
    stride = _pair(attrs.get("stride", 1))
    dilation = _pair(attrs.get("dilation", 1))
    groups = int(attrs.get("groups", 1))
    pads = _conv_pads(attrs.get("padding", "SAME"), x.shape[1:3], (kh, kw), stride, dilation)
    if groups == 1:
        cols = _im2col(x, (kh, kw), stride, pads, dilation)
        out = jnp.einsum("nhwk,ko->nhwo", cols, w.reshape(kh * kw * ci_g, co),
                         preferred_element_type=x.dtype)
        return [out]
    # grouped: split channels, vmap the dense conv over the group axis
    n, h, wd, ci = x.shape
    xg = x.reshape(n, h, wd, groups, ci // groups)
    wg = w.reshape(kh, kw, ci_g, groups, co // groups)

    def one(xs, ws):  # xs: (N,H,W,cig), ws: (KH,KW,cig,cog)
        cols = _im2col(xs, (kh, kw), stride, pads, dilation)
        return jnp.einsum("nhwk,ko->nhwo", cols, ws.reshape(kh * kw * ci_g, -1),
                          preferred_element_type=x.dtype)

    out = jax.vmap(one, in_axes=(3, 3), out_axes=3)(xg, wg)  # (N,OH,OW,G,cog)
    return [out.reshape(out.shape[0], out.shape[1], out.shape[2], co)]


@impl("conv2d", "xla", note="XLA native direct convolution (lax.conv_general_dilated)")
def _conv2d_xla(inputs, attrs):
    x, w = inputs
    kh, kw, _, _ = w.shape
    stride = _pair(attrs.get("stride", 1))
    dilation = _pair(attrs.get("dilation", 1))
    groups = int(attrs.get("groups", 1))
    pads = _conv_pads(attrs.get("padding", "SAME"), x.shape[1:3], (kh, kw), stride, dilation)
    out = lax.conv_general_dilated(
        x, w, window_strides=stride, padding=pads, rhs_dilation=dilation,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return [out]


def _winograd_supported(specs, attrs):
    kh, kw, _, _ = specs[1].shape
    stride = _pair(attrs.get("stride", 1))
    dilation = _pair(attrs.get("dilation", 1))
    groups = int(attrs.get("groups", 1))
    return (kh, kw) == (3, 3) and stride == (1, 1) and dilation == (1, 1) and groups == 1


def _winograd_cost(specs, attrs):
    base = _conv2d_cost(specs, attrs)
    # F(2x2,3x3): 16 multiplies per 4 outputs vs 36 -> 4/9 of the MACs, plus
    # transform overhead ~ linear terms; model as flops * 4/9 and ~2x bytes
    # (transform-domain intermediates).
    return Cost(flops=base.flops * 4.0 / 9.0, bytes=base.bytes * 2.0)


@impl("conv2d", "winograd", supports=_winograd_supported, cost_fn=_winograd_cost,
      note="Winograd F(2x2,3x3): 2.25x fewer multiplies; 3x3 s1 only")
def _conv2d_winograd(inputs, attrs):
    """F(2x2, 3x3) Winograd. Transforms are fp32 for stability."""
    x, w = inputs
    dt = x.dtype
    kh, kw, ci, co = w.shape
    pads = _conv_pads(attrs.get("padding", "SAME"), x.shape[1:3], (3, 3), (1, 1), (1, 1))
    n, h, wd, _ = x.shape
    oh, ow = _conv_out_hw((h, wd), (3, 3), (1, 1), pads, (1, 1))
    # tile grid of 2x2 outputs, each needs a 4x4 input tile
    th, tw = -(-oh // 2), -(-ow // 2)
    # pad so that the tiled region covers everything
    Hp = 2 * th + 2
    Wp = 2 * tw + 2
    xp = jnp.pad(x, ((0, 0),
                     (pads[0][0], max(Hp - h - pads[0][0], 0)),
                     (pads[1][0], max(Wp - wd - pads[1][0], 0)),
                     (0, 0))).astype(jnp.float32)
    B = jnp.array([[1, 0, -1, 0], [0, 1, 1, 0], [0, -1, 1, 0], [0, 1, 0, -1]],
                  jnp.float32)
    G = jnp.array([[1, 0, 0], [0.5, 0.5, 0.5], [0.5, -0.5, 0.5], [0, 0, 1]],
                  jnp.float32)
    A = jnp.array([[1, 0], [1, 1], [1, -1], [0, -1]], jnp.float32)
    # kernel transform: (4,3)@(3,3)@(3,4) per (ci,co)
    wf = jnp.einsum("ab,bcio,cd->adio", G, w.astype(jnp.float32), G.T)  # (4,4,ci,co)
    # input tiles: (N, th, tw, 4, 4, ci)
    idx_h = (jnp.arange(th)[:, None] * 2 + jnp.arange(4)[None, :])
    idx_w = (jnp.arange(tw)[:, None] * 2 + jnp.arange(4)[None, :])
    tiles = xp[:, idx_h, :, :][:, :, :, idx_w, :]          # (N,th,4,tw,4,ci)
    tiles = jnp.transpose(tiles, (0, 1, 3, 2, 4, 5))       # (N,th,tw,4,4,ci)
    tf = jnp.einsum("ab,nxybci,cd->nxyadi", B, tiles, B.T)  # B @ tile @ B^T
    # elementwise multiply in transform domain + reduce ci
    m = jnp.einsum("nxyabi,abio->nxyabo", tf, wf)
    y = jnp.einsum("pa,nxyabo,bq->nxypqo", A.T, m, A)       # (N,th,tw,2,2,co)
    y = jnp.transpose(y, (0, 1, 3, 2, 4, 5)).reshape(n, 2 * th, 2 * tw, co)
    return [y[:, :oh, :ow, :].astype(dt)]

# --------------------------------------------------------------------------- #
# conv2d_fused = conv2d + bias + activation (created by the fusion pass)
# --------------------------------------------------------------------------- #

def _conv2d_fused_shape(specs, attrs):
    return _conv2d_shape(specs[:2], attrs)


def _conv2d_fused_cost(specs, attrs):
    base = _conv2d_cost(specs[:2], attrs)
    out = _conv2d_fused_shape(specs, attrs)[0]
    return Cost(flops=base.flops + 2.0 * out.nelems, bytes=base.bytes + specs[2].nbytes)


defop("conv2d_fused", _conv2d_fused_shape, _conv2d_fused_cost,
      doc="conv2d + bias + activation; inputs (x, w, b); attrs of conv2d + act")


def _fused_from(conv_backend):
    def fn(inputs, attrs):
        x, w, b = inputs
        (y,) = conv_backend([x, w], attrs)
        return [_act(y + b, attrs.get("act", "none"))]
    return fn


impl("conv2d_fused", "ref")(_fused_from(_conv2d_ref))
impl("conv2d_fused", "xla")(_fused_from(_conv2d_xla))
impl("conv2d_fused", "winograd",
     supports=lambda specs, attrs: _winograd_supported(specs[:2], attrs))(
         _fused_from(_conv2d_winograd))

# --------------------------------------------------------------------------- #
# dense / dense_fused
# --------------------------------------------------------------------------- #

def _dense_shape(specs, attrs):
    x, w = specs[0], specs[1]
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"dense mismatch {x.shape} x {w.shape}")
    return [TensorSpec(x.shape[:-1] + (w.shape[1],), x.dtype)]


def _dense_cost(specs, attrs):
    x, w = specs[0], specs[1]
    batch = x.nelems // x.shape[-1]
    flops = 2.0 * batch * w.shape[0] * w.shape[1]
    out_b = batch * w.shape[1] * np.dtype(x.dtype).itemsize
    return Cost(flops=flops, bytes=_bytes_of(specs) + out_b)


defop("dense", _dense_shape, _dense_cost, doc="x @ w")


@impl("dense", "ref")
def _dense_ref(inputs, attrs):
    x, w = inputs
    return [jnp.matmul(x, w, preferred_element_type=x.dtype)]


@impl("dense", "xla", note="lax.dot_general with fp32 accumulation")
def _dense_xla(inputs, attrs):
    x, w = inputs
    out = lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32)
    return [out.astype(x.dtype)]


def _dense_fused_shape(specs, attrs):
    return _dense_shape(specs[:2], attrs)


def _dense_fused_cost(specs, attrs):
    base = _dense_cost(specs[:2], attrs)
    out = _dense_fused_shape(specs, attrs)[0]
    return Cost(base.flops + 2.0 * out.nelems, base.bytes + specs[2].nbytes)


defop("dense_fused", _dense_fused_shape, _dense_fused_cost,
      doc="dense + bias + activation; inputs (x, w, b)")


@impl("dense_fused", "ref")
def _dense_fused_ref(inputs, attrs):
    x, w, b = inputs
    (y,) = _dense_ref([x, w], attrs)
    return [_act(y + b, attrs.get("act", "none"))]

# --------------------------------------------------------------------------- #
# elementwise / activations
# --------------------------------------------------------------------------- #

def _binop_shape(specs, attrs):
    a, b = specs
    # numpy broadcast
    shape = np.broadcast_shapes(a.shape, b.shape)
    return [TensorSpec(tuple(int(d) for d in shape), a.dtype)]


defop("add", _binop_shape, _ew_cost)
defop("mul", _binop_shape, _ew_cost)


@impl("add", "ref")
def _add_ref(inputs, attrs):
    return [inputs[0] + inputs[1]]


@impl("mul", "ref")
def _mul_ref(inputs, attrs):
    return [inputs[0] * inputs[1]]


defop("bias_add", _binop_shape, _ew_cost, doc="x + b broadcast on last dim")


@impl("bias_add", "ref")
def _bias_add_ref(inputs, attrs):
    return [inputs[0] + inputs[1]]


for _name in ("relu", "relu6", "gelu", "silu", "sigmoid", "tanh", "identity"):
    defop(_name, _ew_shape, _ew_cost)

    def _mk(n):
        def fn(inputs, attrs):
            return [_act(inputs[0], n if n != "identity" else "none")]
        return fn

    impl(_name, "ref")(_mk(_name))


# fused_elementwise: a chain of unary elementwise ops collapsed into one node
# (created by passes.fuse_elementwise).  attrs["ops"] lists the stages in
# application order, e.g. ("relu", "tanh").

def _fused_ew_shape(specs, attrs):
    return [specs[0]]


def _fused_ew_cost(specs, attrs):
    # One read + one write for the whole chain — the fusion win vs. the sum
    # of the unfused stages (each of which round-trips the tensor).
    x = specs[0]
    n_stages = max(len(tuple(attrs.get("ops", ()))), 1)
    return Cost(flops=float(n_stages * x.nelems), bytes=2.0 * x.nbytes)


defop("fused_elementwise", _fused_ew_shape, _fused_ew_cost,
      doc="chain of unary elementwise ops; attrs: ops (tuple of op names)")


@impl("fused_elementwise", "ref",
      note="composes the ref impl of each stage — the oracle chain")
def _fused_ew_ref(inputs, attrs):
    from repro.core.registry import get_impl as _get_impl
    (x,) = inputs
    for op_name in tuple(attrs.get("ops", ())):
        (x,) = _get_impl(op_name, "ref")([x], {})
    return [x]


@impl("fused_elementwise", "xla",
      note="single traced composition — XLA fuses the chain into one loop")
def _fused_ew_xla(inputs, attrs):
    (x,) = inputs
    for op_name in tuple(attrs.get("ops", ())):
        x = _act(x, "none" if op_name == "identity" else op_name)
    return [x]


def _softmax_shape(specs, attrs):
    return [specs[0]]


defop("softmax", _softmax_shape,
      lambda specs, attrs: Cost(5.0 * specs[0].nelems, 2.0 * specs[0].nbytes))


@impl("softmax", "ref")
def _softmax_ref(inputs, attrs):
    return [jax.nn.softmax(inputs[0], axis=int(attrs.get("axis", -1)))]

# --------------------------------------------------------------------------- #
# pooling
# --------------------------------------------------------------------------- #

def _pool_shape(specs, attrs):
    x = specs[0]
    n, h, w, c = x.shape
    k = _pair(attrs.get("window", 2))
    s = _pair(attrs.get("stride", attrs.get("window", 2)))
    pads = _conv_pads(attrs.get("padding", "VALID"), (h, w), k, s, (1, 1))
    oh, ow = _conv_out_hw((h, w), k, s, pads, (1, 1))
    return [TensorSpec((n, oh, ow, c), x.dtype)]


def _pool_cost(specs, attrs):
    out = _pool_shape(specs, attrs)[0]
    k = _pair(attrs.get("window", 2))
    return Cost(flops=float(out.nelems * k[0] * k[1]),
                bytes=_bytes_of(specs) + out.nbytes)


defop("maxpool2d", _pool_shape, _pool_cost)
defop("avgpool2d", _pool_shape, _pool_cost)


def _pool(x, attrs, init, op, avg):
    k = _pair(attrs.get("window", 2))
    s = _pair(attrs.get("stride", attrs.get("window", 2)))
    pads = _conv_pads(attrs.get("padding", "VALID"), x.shape[1:3], k, s, (1, 1))
    y = lax.reduce_window(x, init, op, (1, k[0], k[1], 1), (1, s[0], s[1], 1),
                          ((0, 0), pads[0], pads[1], (0, 0)))
    if avg:
        y = y / (k[0] * k[1])
    return y


@impl("maxpool2d", "ref")
def _maxpool_ref(inputs, attrs):
    return [_pool(inputs[0], attrs, -jnp.inf, lax.max, avg=False)]


@impl("avgpool2d", "ref")
def _avgpool_ref(inputs, attrs):
    return [_pool(inputs[0], attrs, 0.0, lax.add, avg=True)]


def _gap_shape(specs, attrs):
    n, h, w, c = specs[0].shape
    return [TensorSpec((n, c), specs[0].dtype)]


defop("global_avgpool", _gap_shape,
      lambda specs, attrs: Cost(float(specs[0].nelems), specs[0].nbytes))


@impl("global_avgpool", "ref")
def _gap_ref(inputs, attrs):
    return [jnp.mean(inputs[0], axis=(1, 2))]

# --------------------------------------------------------------------------- #
# batchnorm (inference) — folds to scale/shift
# --------------------------------------------------------------------------- #

def _bn_shape(specs, attrs):
    return [specs[0]]


defop("batchnorm", _bn_shape,
      lambda specs, attrs: Cost(2.0 * specs[0].nelems, 2.0 * specs[0].nbytes),
      doc="inference BN; inputs (x, scale, bias, mean, var)")


@impl("batchnorm", "ref")
def _bn_ref(inputs, attrs):
    x, scale, bias, mean, var = inputs
    eps = float(attrs.get("eps", 1e-5))
    inv = scale * lax.rsqrt(var + eps)
    return [x * inv + (bias - mean * inv)]

# --------------------------------------------------------------------------- #
# shape plumbing
# --------------------------------------------------------------------------- #

def _flatten_shape(specs, attrs):
    x = specs[0]
    return [TensorSpec((x.shape[0], x.nelems // x.shape[0]), x.dtype)]


defop("flatten", _flatten_shape, lambda s, a: Cost(0.0, 0.0))


@impl("flatten", "ref")
def _flatten_ref(inputs, attrs):
    x = inputs[0]
    return [x.reshape(x.shape[0], -1)]


def _reshape_shape(specs, attrs):
    x = specs[0]
    shape = tuple(int(d) for d in attrs["shape"])
    if -1 in shape:
        known = -int(np.prod(shape))
        shape = tuple(d if d != -1 else x.nelems // known for d in shape)
    if int(np.prod(shape)) != x.nelems:
        raise ValueError(f"reshape {x.shape} -> {shape} size mismatch")
    return [TensorSpec(shape, x.dtype)]


defop("reshape", _reshape_shape, lambda s, a: Cost(0.0, 0.0))


@impl("reshape", "ref")
def _reshape_ref(inputs, attrs):
    return [inputs[0].reshape(tuple(int(d) for d in attrs["shape"]))]


def _transpose_shape(specs, attrs):
    x = specs[0]
    perm = tuple(int(d) for d in attrs["perm"])
    return [TensorSpec(tuple(x.shape[p] for p in perm), x.dtype)]


defop("transpose", _transpose_shape,
      lambda s, a: Cost(0.0, 2.0 * s[0].nbytes))


@impl("transpose", "ref")
def _transpose_ref(inputs, attrs):
    return [jnp.transpose(inputs[0], tuple(int(d) for d in attrs["perm"]))]


def _concat_shape(specs, attrs):
    axis = int(attrs.get("axis", -1))
    base = list(specs[0].shape)
    ax = axis % len(base)
    base[ax] = sum(s.shape[ax] for s in specs)
    return [TensorSpec(tuple(base), specs[0].dtype)]


defop("concat", _concat_shape,
      lambda s, a: Cost(0.0, 2.0 * sum(x.nbytes for x in s)))


@impl("concat", "ref")
def _concat_ref(inputs, attrs):
    return [jnp.concatenate(list(inputs), axis=int(attrs.get("axis", -1)))]
