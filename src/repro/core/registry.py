"""Backend registry — the heart of the Orpheus programming model.

Layers/operators are first-class citizens: every op is declared once
(:func:`defop`, with a shape function and an analytic cost model) and may
carry *multiple implementations* ("backends") registered independently
(:func:`impl`).  Which implementation runs is decided at execution time by a
:class:`~repro.core.selector.BackendPolicy` — fixed assignment, cost-model
argmin, or autotuning — exactly the paper's runtime layer-implementation
selection, adapted to a traced/compiled setting.

Backends used across the repo:

* ``ref``    — pure ``jax.numpy`` reference (always registered first; the
               oracle every other backend is tested against).
* ``xla``    — the "third-party library" backend: XLA's own fused lowerings
               (``lax.conv_general_dilated``, ``lax.dot_general`` …).
* ``pallas`` — hand-written TPU kernels (``pl.pallas_call`` + BlockSpec),
               registered by :mod:`repro.kernels.ops` on import.

The analytic cost models double as the roofline tool's source of truth for
FLOPs/bytes inside Pallas custom calls (XLA cost analysis cannot see into
them).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.ir import TensorSpec

__all__ = [
    "Cost",
    "OpImpl",
    "OpDef",
    "defop",
    "impl",
    "get_op",
    "get_impl",
    "backends_for",
    "registered_ops",
    "RegistryError",
]


class RegistryError(KeyError):
    pass


@dataclass(frozen=True)
class Cost:
    """Analytic per-call cost: floating-point ops and HBM bytes moved."""

    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(self.flops + other.flops, self.bytes + other.bytes)

    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)


ShapeFn = Callable[[Sequence[TensorSpec], Dict[str, Any]], List[TensorSpec]]
CostFn = Callable[[Sequence[TensorSpec], Dict[str, Any]], Cost]
ImplFn = Callable[[Sequence[Any], Dict[str, Any]], Sequence[Any]]
SupportsFn = Callable[[Sequence[TensorSpec], Dict[str, Any]], bool]


@dataclass
class OpImpl:
    op: str
    backend: str
    fn: ImplFn
    supports: SupportsFn
    note: str = ""
    # Optional per-implementation cost override, for backends whose ALGORITHM
    # changes the op's flop count (e.g. winograd conv: 2.25x fewer multiplies).
    cost_fn: Optional[CostFn] = None

    def __call__(self, inputs: Sequence[Any], attrs: Dict[str, Any]) -> Sequence[Any]:
        return self.fn(inputs, attrs)

    def cost(self, specs: Sequence[TensorSpec], attrs: Dict[str, Any]) -> "Cost":
        fn = self.cost_fn or get_op(self.op).cost_fn
        return fn(specs, attrs)


@dataclass
class OpDef:
    name: str
    shape_fn: ShapeFn
    cost_fn: CostFn
    impls: Dict[str, OpImpl] = field(default_factory=dict)
    doc: str = ""


_OPS: Dict[str, OpDef] = {}


def defop(name: str, shape_fn: ShapeFn, cost_fn: CostFn, doc: str = "") -> OpDef:
    """Declare an operator. Idempotent on identical redefinition is NOT
    allowed — ops are declared exactly once (helps catch import mistakes)."""
    if name in _OPS:
        raise RegistryError(f"op {name!r} already declared")
    op = OpDef(name=name, shape_fn=shape_fn, cost_fn=cost_fn, doc=doc)
    _OPS[name] = op
    return op


def impl(op: str, backend: str, *, supports: Optional[SupportsFn] = None,
         note: str = "", cost_fn: Optional[CostFn] = None) -> Callable[[ImplFn], ImplFn]:
    """Decorator registering ``fn`` as the ``backend`` implementation of ``op``.

    Re-registration of the same (op, backend) replaces the previous impl —
    this is deliberate: it is how a third-party module overrides a stock
    backend (the paper's "easy integration" property).
    """

    def wrap(fn: ImplFn) -> ImplFn:
        if op not in _OPS:
            raise RegistryError(f"op {op!r} not declared; call defop first")
        _OPS[op].impls[backend] = OpImpl(
            op=op, backend=backend, fn=fn,
            supports=supports or (lambda specs, attrs: True), note=note,
            cost_fn=cost_fn)
        return fn

    return wrap


def get_op(name: str) -> OpDef:
    try:
        return _OPS[name]
    except KeyError:
        raise RegistryError(f"unknown op {name!r}; known: {sorted(_OPS)}") from None


def get_impl(name: str, backend: str) -> OpImpl:
    op = get_op(name)
    try:
        return op.impls[backend]
    except KeyError:
        raise RegistryError(
            f"op {name!r} has no backend {backend!r}; available: {sorted(op.impls)}"
        ) from None


def backends_for(name: str, specs: Optional[Sequence[TensorSpec]] = None,
                 attrs: Optional[Dict[str, Any]] = None) -> List[str]:
    """Backends registered for ``name``; filtered by ``supports`` when specs
    are given. ``ref`` sorts first so tests/selectors treat it as baseline."""
    op = get_op(name)
    names = sorted(op.impls, key=lambda b: (b != "ref", b))
    if specs is None:
        return names
    attrs = attrs or {}
    return [b for b in names if op.impls[b].supports(specs, attrs)]


def registered_ops() -> List[str]:
    return sorted(_OPS)
