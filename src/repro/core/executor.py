"""DEPRECATED thin shim over :mod:`repro.core.program`.

The old monolithic ``Executor`` mixed pass running, backend assignment and
execution in one class.  That split into the staged pipeline
(:func:`repro.core.compile` -> immutable :class:`~repro.core.program.Program`);
this module keeps the old construction-site API working:

    Executor(graph, policy)   ==   compile(graph, policy, pipeline=())

(i.e. no simplification passes are run, matching the old behaviour — callers
were expected to ``simplify()`` first).  New code should call ``compile``.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.ir import Graph, Node
from repro.core.program import NodeReport, Program
from repro.core.program import compile as _compile
from repro.core.registry import Cost
from repro.core.selector import BackendPolicy, FixedPolicy

__all__ = ["Executor", "NodeReport"]


class Executor:
    """Deprecated: use ``repro.core.compile(graph, policy=...)``."""

    def __init__(self, graph: Graph, policy: Optional[BackendPolicy] = None):
        warnings.warn(
            "Executor is deprecated; use repro.core.compile(graph, policy=...) "
            "which returns an immutable Program",
            DeprecationWarning, stacklevel=2)
        self.policy = policy or FixedPolicy()
        self.program = _compile(graph, policy=self.policy, pipeline=())
        self.graph = self.program.graph

    # ------------------------------------------------------------------ #
    @property
    def assignment(self) -> Dict[str, str]:
        return self.program.assignment

    def costs(self) -> List[Tuple[Node, str, Cost]]:
        return self.program.costs()

    def compile(self) -> Callable[..., Tuple[Any, ...]]:
        return self.program.callable()

    def __call__(self, **inputs: Any) -> Tuple[Any, ...]:
        return self.program(**inputs)

    def lower(self, **input_specs: jax.ShapeDtypeStruct):
        return self.program.lower(**input_specs)

    def run_instrumented(self, **inputs: Any):
        return self.program.run_instrumented(**inputs)
