"""Graph executor: lowers a GraphIR + backend assignment to a jitted JAX
callable, and provides the paper's per-layer instrumented evaluation mode.

The executor is deliberately simple (topological interpretation at trace
time); all heavy lifting is done by XLA after ``jax.jit``.  What Orpheus
adds on top of plain XLA is the *assignment*: every node runs the backend
chosen by the policy, so two compiles of the same graph with different
policies give an apples-to-apples backend comparison.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.ir import Graph, Node, TensorSpec, topological_order
from repro.core.passes import infer_shapes
from repro.core.registry import Cost, get_impl
from repro.core.selector import BackendPolicy, FixedPolicy

__all__ = ["Executor", "NodeReport"]


@dataclass
class NodeReport:
    name: str
    op: str
    backend: str
    seconds: float
    cost: Cost
    out_spec: TensorSpec


class Executor:
    """Compile/execute a GraphIR under a backend policy."""

    def __init__(self, graph: Graph, policy: Optional[BackendPolicy] = None):
        self.graph = graph if graph.value_info else infer_shapes(graph)
        self.policy = policy or FixedPolicy()
        self._order = topological_order(self.graph)
        self._assignment: Dict[str, str] = {}
        for node in self._order:
            in_specs = [self.graph.spec_of(v) for v in node.inputs]
            self._assignment[node.name] = self.policy.resolve(node, in_specs)
        self._jitted: Optional[Callable] = None

    # ------------------------------------------------------------------ #
    @property
    def assignment(self) -> Dict[str, str]:
        """node name -> chosen backend."""
        return dict(self._assignment)

    def costs(self) -> List[Tuple[Node, str, Cost]]:
        out = []
        for node in self._order:
            b = self._assignment[node.name]
            in_specs = [self.graph.spec_of(v) for v in node.inputs]
            out.append((node, b, get_impl(node.op, b).cost(in_specs, node.attrs)))
        return out

    # ------------------------------------------------------------------ #
    def _trace(self, params: Dict[str, Any], inputs: Dict[str, Any]) -> Tuple[Any, ...]:
        env: Dict[str, Any] = {}
        env.update(params)
        env.update(inputs)
        for node in self._order:
            fn = get_impl(node.op, self._assignment[node.name])
            args = [env[v] for v in node.inputs]
            outs = fn(args, node.attrs)
            for v, val in zip(node.outputs, outs):
                env[v] = val
        return tuple(env[v] for v in self.graph.outputs)

    def compile(self) -> Callable[..., Tuple[Any, ...]]:
        """Returns jitted ``f(inputs: dict, params: dict|None) -> tuple``.

        ``params`` defaults to the graph's stored parameters; passing them
        explicitly supports functional weight updates (training loops)."""
        if self._jitted is None:
            jf = jax.jit(self._trace)
            stored = {k: jnp.asarray(v) for k, v in self.graph.params.items()}

            def call(inputs: Dict[str, Any], params: Optional[Dict[str, Any]] = None):
                return jf(stored if params is None else params, inputs)

            self._jitted = call
        return self._jitted

    def __call__(self, **inputs: Any) -> Tuple[Any, ...]:
        missing = set(self.graph.inputs) - set(inputs)
        if missing:
            raise ValueError(f"missing graph inputs: {sorted(missing)}")
        return self.compile()(inputs)

    # ------------------------------------------------------------------ #
    def lower(self, **input_specs: jax.ShapeDtypeStruct):
        """``jax.jit(...).lower(...)`` for dry-run / cost analysis."""
        stored = {k: jax.ShapeDtypeStruct(jnp.shape(v), jnp.asarray(v).dtype)
                  for k, v in self.graph.params.items()}
        specs = input_specs or {
            k: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype))
            for k, s in self.graph.inputs.items()}
        return jax.jit(self._trace).lower(stored, specs)

    # ------------------------------------------------------------------ #
    def run_instrumented(self, **inputs: Any) -> Tuple[Tuple[Any, ...], List[NodeReport]]:
        """Eager per-node execution with wall-clock timing — the paper's
        individual-layer evaluation. Each node's impl is jitted separately
        (so we time the op, not Python overhead), warmed once, then timed."""
        env: Dict[str, Any] = {k: jnp.asarray(v) for k, v in self.graph.params.items()}
        env.update({k: jnp.asarray(v) for k, v in inputs.items()})
        reports: List[NodeReport] = []
        for node in self._order:
            backend = self._assignment[node.name]
            fn = get_impl(node.op, backend)
            args = [env[v] for v in node.inputs]
            jf = jax.jit(lambda a, _fn=fn, _at=node.attrs: _fn(a, _at))
            outs = jf(args)
            jax.block_until_ready(outs)  # warm
            t0 = time.perf_counter()
            outs = jf(args)
            jax.block_until_ready(outs)
            dt = time.perf_counter() - t0
            in_specs = [self.graph.spec_of(v) for v in node.inputs]
            reports.append(NodeReport(
                name=node.name, op=node.op, backend=backend, seconds=dt,
                cost=fn.cost(in_specs, node.attrs),
                out_spec=self.graph.spec_of(node.outputs[0])))
            for v, val in zip(node.outputs, outs):
                env[v] = val
        return tuple(env[v] for v in self.graph.outputs), reports
