"""Backend selection policies — the "selected at runtime" half of the paper.

Three policies, in increasing sophistication:

* :class:`FixedPolicy` — a preference list (optionally per-op / per-node),
  first supported backend wins.  This is Orpheus's manual runtime switch.
* :class:`CostModelPolicy` — analytic roofline estimate per backend
  (impl cost model / backend throughput profile), argmin of estimated time.
  Used on the TPU target where wall-clock measurement is unavailable.
* :class:`AutotunePolicy` — measure every supported backend on the node's
  actual shapes (jitted, warmed, min-of-k) and pick the fastest; results are
  cached by (op, backend, shape-signature).  This reproduces the paper's
  core workflow: comparing layer implementations in a consistent
  environment, per layer and per workload.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ir import Node, TensorSpec
from repro.core.registry import Cost, backends_for, get_impl, get_op

__all__ = [
    "BackendPolicy",
    "FixedPolicy",
    "CostModelPolicy",
    "AutotunePolicy",
    "HardwareProfile",
    "TPU_V5E",
    "HOST_CPU",
]


@dataclass(frozen=True)
class HardwareProfile:
    """Peak throughput profile used by the analytic selector, with a
    per-backend efficiency de-rating (fraction of peak each backend is
    expected to sustain)."""

    name: str
    peak_flops: float            # FLOP/s
    hbm_bw: float                # B/s
    backend_efficiency: Tuple[Tuple[str, float], ...] = (
        ("pallas", 0.8), ("xla", 0.65), ("winograd", 0.65), ("ref", 0.35),
    )

    def efficiency(self, backend: str) -> float:
        for b, e in self.backend_efficiency:
            if b == backend:
                return e
        return 0.5

    def est_seconds(self, backend: str, cost: Cost) -> float:
        eff = self.efficiency(backend)
        return max(cost.flops / (self.peak_flops * eff),
                   cost.bytes / (self.hbm_bw * eff))


# TPU v5e single chip (the deployment target) and a nominal host CPU
# (the measurement platform in this container — same regime as the paper's
# single-core Cortex-A73 evaluation).
TPU_V5E = HardwareProfile("tpu-v5e", peak_flops=197e12, hbm_bw=819e9)
HOST_CPU = HardwareProfile("host-cpu", peak_flops=5e10, hbm_bw=2e10)


class BackendPolicy:
    """Base: always ``ref``."""

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        avail = backends_for(node.op, in_specs, node.attrs)
        if not avail:
            raise ValueError(f"no supported backend for {node.op} {in_specs}")
        return "ref" if "ref" in avail else avail[0]

    # per-node explicit override always wins
    def resolve(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        if node.backend is not None:
            avail = backends_for(node.op, in_specs, node.attrs)
            if node.backend not in avail:
                raise ValueError(
                    f"node {node.name}: pinned backend {node.backend!r} not "
                    f"supported here (available: {avail})")
            return node.backend
        return self.choose(node, in_specs)


@dataclass
class FixedPolicy(BackendPolicy):
    """Preference-ordered selection. ``prefer`` is global; ``per_op`` and
    ``per_node`` override it for specific ops / node names."""

    prefer: Sequence[str] = ("xla", "ref")
    per_op: Dict[str, Sequence[str]] = field(default_factory=dict)
    per_node: Dict[str, Sequence[str]] = field(default_factory=dict)

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        avail = backends_for(node.op, in_specs, node.attrs)
        for pref in (self.per_node.get(node.name), self.per_op.get(node.op),
                     self.prefer):
            if not pref:
                continue
            for b in pref:
                if b in avail:
                    return b
        if avail:
            return avail[0]
        raise ValueError(f"no supported backend for {node.op}")


@dataclass
class CostModelPolicy(BackendPolicy):
    """Analytic argmin over supported backends (no execution needed — works
    for the TPU target in this CPU-only container)."""

    profile: HardwareProfile = TPU_V5E

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        avail = backends_for(node.op, in_specs, node.attrs)
        if not avail:
            raise ValueError(f"no supported backend for {node.op}")
        best, best_t = None, float("inf")
        for b in avail:
            cost = get_impl(node.op, b).cost(in_specs, node.attrs)
            t = self.profile.est_seconds(b, cost)
            if t < best_t:
                best, best_t = b, t
        return best  # type: ignore[return-value]

    def estimate(self, node: Node, in_specs: Sequence[TensorSpec]) -> Dict[str, float]:
        return {b: self.profile.est_seconds(
                    b, get_impl(node.op, b).cost(in_specs, node.attrs))
                for b in backends_for(node.op, in_specs, node.attrs)}


def _spec_sig(specs: Sequence[TensorSpec], attrs: Dict[str, Any]) -> Tuple:
    def freeze(x):
        if isinstance(x, dict):
            return tuple(sorted((k, freeze(v)) for k, v in x.items()))
        if isinstance(x, (list, tuple)):
            return tuple(freeze(v) for v in x)
        if isinstance(x, np.ndarray):
            return ("nd", x.shape, str(x.dtype))
        return x

    return (tuple((s.shape, s.dtype) for s in specs), freeze(attrs))


@dataclass
class AutotunePolicy(BackendPolicy):
    """Measure-and-pick (the paper's consistent-environment comparison).

    Each candidate impl is jitted on random inputs matching the node's
    specs, warmed once, then timed ``reps`` times; min is recorded.  The
    cache makes repeated compiles of the same network free.
    """

    reps: int = 5
    candidates: Optional[Sequence[str]] = None  # None = all supported
    _cache: Dict[Tuple, str] = field(default_factory=dict)
    _timings: Dict[Tuple, Dict[str, float]] = field(default_factory=dict)

    def _random_inputs(self, specs: Sequence[TensorSpec]) -> List[jax.Array]:
        rng = np.random.default_rng(0)
        out = []
        for s in specs:
            if np.issubdtype(np.dtype(s.dtype), np.floating) or s.dtype == "bfloat16":
                arr = rng.standard_normal(s.shape, dtype=np.float32)
                out.append(jnp.asarray(arr, dtype=s.dtype))
            else:
                out.append(jnp.asarray(rng.integers(0, 2, s.shape), dtype=s.dtype))
        return out

    def measure(self, op: str, in_specs: Sequence[TensorSpec],
                attrs: Dict[str, Any]) -> Dict[str, float]:
        key = (op, _spec_sig(in_specs, attrs))
        if key in self._timings:
            return self._timings[key]
        inputs = self._random_inputs(in_specs)
        avail = backends_for(op, in_specs, attrs)
        if self.candidates is not None:
            avail = [b for b in avail if b in self.candidates]
        times: Dict[str, float] = {}
        for b in avail:
            fn = get_impl(op, b)
            jf = jax.jit(lambda args: fn(args, attrs))
            try:
                res = jf(inputs)
                jax.block_until_ready(res)
            except Exception:
                continue  # backend cannot execute on this platform; skip
            best = float("inf")
            for _ in range(self.reps):
                t0 = time.perf_counter()
                jax.block_until_ready(jf(inputs))
                best = min(best, time.perf_counter() - t0)
            times[b] = best
        self._timings[key] = times
        return times

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        key = (node.op, _spec_sig(in_specs, node.attrs))
        if key in self._cache:
            return self._cache[key]
        times = self.measure(node.op, in_specs, node.attrs)
        if not times:
            raise ValueError(f"no runnable backend for {node.op}")
        best = min(times, key=times.get)  # type: ignore[arg-type]
        self._cache[key] = best
        return best
