"""Backend selection policies — the "selected at runtime" half of the paper.

Three policies, in increasing sophistication:

* :class:`FixedPolicy` — a preference list (optionally per-op / per-node),
  first supported backend wins.  This is Orpheus's manual runtime switch.
* :class:`CostModelPolicy` — analytic roofline estimate per backend
  (impl cost model / backend throughput profile), argmin of estimated time.
  Used on the TPU target where wall-clock measurement is unavailable.
* :class:`AutotunePolicy` — measure every supported backend on the node's
  actual shapes (jitted, warmed, min-of-k) and pick the fastest; results are
  cached by (op, backend, shape-signature).  This reproduces the paper's
  core workflow: comparing layer implementations in a consistent
  environment, per layer and per workload.
"""

from __future__ import annotations

import hashlib
import json
import os
import platform
import tempfile
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ir import Node, TensorSpec
from repro.core.registry import Cost, backends_for, get_impl, get_op

__all__ = [
    "BackendPolicy",
    "FixedPolicy",
    "CostModelPolicy",
    "AutotunePolicy",
    "HardwareProfile",
    "TPU_V5E",
    "HOST_CPU",
    "hardware_fingerprint",
    "default_cache_path",
]


@dataclass(frozen=True)
class HardwareProfile:
    """Peak throughput profile used by the analytic selector, with a
    per-backend efficiency de-rating (fraction of peak each backend is
    expected to sustain)."""

    name: str
    peak_flops: float            # FLOP/s
    hbm_bw: float                # B/s
    backend_efficiency: Tuple[Tuple[str, float], ...] = (
        ("pallas", 0.8), ("pallas_split", 0.75), ("xla", 0.65),
        ("winograd", 0.65), ("ref", 0.35),
    )

    def efficiency(self, backend: str) -> float:
        for b, e in self.backend_efficiency:
            if b == backend:
                return e
        return 0.5

    def est_seconds(self, backend: str, cost: Cost) -> float:
        eff = self.efficiency(backend)
        return max(cost.flops / (self.peak_flops * eff),
                   cost.bytes / (self.hbm_bw * eff))


# TPU v5e single chip (the deployment target) and a nominal host CPU
# (the measurement platform in this container — same regime as the paper's
# single-core Cortex-A73 evaluation).
TPU_V5E = HardwareProfile("tpu-v5e", peak_flops=197e12, hbm_bw=819e9)
HOST_CPU = HardwareProfile("host-cpu", peak_flops=5e10, hbm_bw=2e10)


class BackendPolicy:
    """Base: always ``ref``."""

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        avail = backends_for(node.op, in_specs, node.attrs)
        if not avail:
            raise ValueError(f"no supported backend for {node.op} {in_specs}")
        return "ref" if "ref" in avail else avail[0]

    # per-node explicit override always wins
    def resolve(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        if node.backend is not None:
            avail = backends_for(node.op, in_specs, node.attrs)
            if node.backend not in avail:
                raise ValueError(
                    f"node {node.name}: pinned backend {node.backend!r} not "
                    f"supported here (available: {avail})")
            return node.backend
        return self.choose(node, in_specs)


@dataclass
class FixedPolicy(BackendPolicy):
    """Preference-ordered selection. ``prefer`` is global; ``per_op`` and
    ``per_node`` override it for specific ops / node names."""

    prefer: Sequence[str] = ("xla", "ref")
    per_op: Dict[str, Sequence[str]] = field(default_factory=dict)
    per_node: Dict[str, Sequence[str]] = field(default_factory=dict)

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        avail = backends_for(node.op, in_specs, node.attrs)
        for pref in (self.per_node.get(node.name), self.per_op.get(node.op),
                     self.prefer):
            if not pref:
                continue
            for b in pref:
                if b in avail:
                    return b
        if avail:
            return avail[0]
        raise ValueError(f"no supported backend for {node.op}")


@dataclass
class CostModelPolicy(BackendPolicy):
    """Analytic argmin over supported backends (no execution needed — works
    for the TPU target in this CPU-only container)."""

    profile: HardwareProfile = TPU_V5E

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        avail = backends_for(node.op, in_specs, node.attrs)
        if not avail:
            raise ValueError(f"no supported backend for {node.op}")
        best, best_t = None, float("inf")
        for b in avail:
            cost = get_impl(node.op, b).cost(in_specs, node.attrs)
            t = self.profile.est_seconds(b, cost)
            if t < best_t:
                best, best_t = b, t
        return best  # type: ignore[return-value]

    def estimate(self, node: Node, in_specs: Sequence[TensorSpec]) -> Dict[str, float]:
        return {b: self.profile.est_seconds(
                    b, get_impl(node.op, b).cost(in_specs, node.attrs))
                for b in backends_for(node.op, in_specs, node.attrs)}


def _spec_sig(specs: Sequence[TensorSpec], attrs: Dict[str, Any]) -> Tuple:
    def freeze(x):
        if isinstance(x, dict):
            return tuple(sorted((k, freeze(v)) for k, v in x.items()))
        if isinstance(x, (list, tuple)):
            return tuple(freeze(v) for v in x)
        if isinstance(x, np.ndarray):
            return ("nd", x.shape, str(x.dtype))
        return x

    return (tuple((s.shape, s.dtype) for s in specs), freeze(attrs))


def _sig_key(op: str, specs: Sequence[TensorSpec], attrs: Dict[str, Any]) -> str:
    """Stable string key for (op, shapes, attrs) — JSON-dict friendly."""
    return json.dumps([op, _spec_sig(specs, attrs)], sort_keys=True, default=str)


def hardware_fingerprint() -> str:
    """Identifies the machine a measurement is valid on.  Timings cached
    under one fingerprint are never reused on different hardware."""
    try:
        dev = jax.devices()[0]
        dev_sig = f"{dev.platform}/{getattr(dev, 'device_kind', '?')}"
    except Exception:
        dev_sig = "none"
    raw = "|".join([platform.machine(), platform.system(), dev_sig,
                    str(os.cpu_count()), jax.__version__])
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def default_cache_path() -> str:
    """Where benchmarks/examples persist autotune results by default
    (override with ORPHEUS_AUTOTUNE_CACHE)."""
    env = os.environ.get("ORPHEUS_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "orpheus",
                        "autotune.json")


_CACHE_VERSION = 1


@dataclass
class AutotunePolicy(BackendPolicy):
    """Measure-and-pick (the paper's consistent-environment comparison).

    Each candidate impl is jitted on random inputs matching the node's
    specs, warmed once, then timed ``reps`` times; min is recorded.  The
    in-memory cache makes repeated compiles of the same network free; with
    ``cache_path`` set, measurements persist as JSON across processes
    (keyed by op/backend/shape-signature under a hardware fingerprint), so
    a second compile of the same model on the same machine performs zero
    re-measurements.
    """

    reps: int = 5
    candidates: Optional[Sequence[str]] = None  # None = all supported
    cache_path: Optional[str] = None
    _cache: Dict[str, str] = field(default_factory=dict)
    _timings: Dict[str, Dict[str, float]] = field(default_factory=dict)
    n_measured: int = 0   # signatures actually benchmarked by this instance
    n_loaded: int = 0     # signatures preloaded from the on-disk cache
    # (mtime, size) of the cache file after our last write + its content,
    # so repeated saves skip re-parsing a file nobody else touched
    _disk_state: Optional[Tuple[Tuple[float, int], Dict[str, Any]]] = None

    def __post_init__(self) -> None:
        if self.cache_path:
            self._load_cache()

    # -------------------------- persistence --------------------------- #
    def _load_cache(self) -> None:
        """Best-effort preload: any corrupted, truncated or wrong-shaped
        cache file degrades to in-memory tuning instead of crashing the
        compile (the file is rewritten cleanly on the next measurement)."""
        try:
            with open(self.cache_path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return
        if not isinstance(data, dict) or data.get("version") != _CACHE_VERSION:
            return
        fps = data.get("fingerprints")
        entries = fps.get(hardware_fingerprint()) if isinstance(fps, dict) else None
        if not isinstance(entries, dict):
            return
        for key, times in entries.items():
            if key in self._timings or not isinstance(times, dict):
                continue
            try:
                self._timings[key] = {b: float(t) for b, t in times.items()}
            except (TypeError, ValueError):
                continue
            self.n_loaded += 1

    def _save_cache(self) -> None:
        """Best-effort persist: an unwritable cache location degrades to
        in-memory-only tuning instead of failing the compile."""
        path = self.cache_path
        # merge with whatever is on disk (other processes / fingerprints),
        # skipping the re-read when nobody else has written since our last
        # save — measure() saves once per new signature, so this keeps a
        # cold-cache compile from re-parsing the file N times
        data: Dict[str, Any] = {"version": _CACHE_VERSION, "fingerprints": {}}
        try:
            stamp = (os.path.getmtime(path), os.path.getsize(path))
        except OSError:
            stamp = None
        if self._disk_state is not None and stamp == self._disk_state[0]:
            data = self._disk_state[1]
        elif stamp is not None:
            try:
                with open(path) as f:
                    prev = json.load(f)
                if isinstance(prev, dict) and prev.get("version") == _CACHE_VERSION:
                    data = prev
            except (OSError, ValueError):
                pass
        fp = hardware_fingerprint()
        if not isinstance(data.get("fingerprints"), dict):
            data["fingerprints"] = {}
        if not isinstance(data["fingerprints"].get(fp), dict):
            data["fingerprints"][fp] = {}
        data["fingerprints"][fp].update(self._timings)
        tmp = None
        try:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
            self._disk_state = ((os.path.getmtime(path), os.path.getsize(path)),
                                data)
        except OSError as e:
            if tmp is not None and os.path.exists(tmp):
                os.unlink(tmp)
            warnings.warn(f"autotune cache not persisted to {path!r}: {e}")

    def _random_inputs(self, specs: Sequence[TensorSpec]) -> List[jax.Array]:
        rng = np.random.default_rng(0)
        out = []
        for s in specs:
            if np.issubdtype(np.dtype(s.dtype), np.floating) or s.dtype == "bfloat16":
                arr = rng.standard_normal(s.shape, dtype=np.float32)
                out.append(jnp.asarray(arr, dtype=s.dtype))
            else:
                out.append(jnp.asarray(rng.integers(0, 2, s.shape), dtype=s.dtype))
        return out

    def measure(self, op: str, in_specs: Sequence[TensorSpec],
                attrs: Dict[str, Any]) -> Dict[str, float]:
        """Timings for every candidate backend of (op, shapes, attrs).

        Incremental against the (possibly preloaded) cache: only backends
        with no cached timing are benchmarked, so a cache written under a
        different ``candidates`` restriction is topped up rather than
        trusted blindly.  Unrunnable backends are recorded as ``inf`` so
        they are not retried every compile.  The returned dict is filtered
        to the current candidate set."""
        key = _sig_key(op, in_specs, attrs)
        avail = backends_for(op, in_specs, attrs)
        if self.candidates is not None:
            avail = [b for b in avail if b in self.candidates]
        times = dict(self._timings.get(key, {}))
        missing = [b for b in avail if b not in times]
        if missing:
            inputs = self._random_inputs(in_specs)
            for b in missing:
                fn = get_impl(op, b)
                jf = jax.jit(lambda args: fn(args, attrs))
                try:
                    res = jf(inputs)
                    jax.block_until_ready(res)
                except Exception:
                    # backend cannot execute on this platform; remember that
                    times[b] = float("inf")
                    continue
                best = float("inf")
                for _ in range(self.reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(jf(inputs))
                    best = min(best, time.perf_counter() - t0)
                times[b] = best
            self._timings[key] = times
            self.n_measured += 1
            if self.cache_path:
                self._save_cache()
        return {b: t for b, t in times.items()
                if b in avail and t != float("inf")}

    def choose(self, node: Node, in_specs: Sequence[TensorSpec]) -> str:
        key = _sig_key(node.op, in_specs, node.attrs)
        if key in self._cache:
            return self._cache[key]
        avail = backends_for(node.op, in_specs, node.attrs)
        if self.candidates is not None:
            avail = [b for b in avail if b in self.candidates]
        if len(avail) == 1:
            # Nothing to compare: measuring would burn warm-up + reps
            # iterations to "choose" among one option.  This also skips the
            # runnability probe a measurement used to provide — a sole
            # candidate that cannot execute on this platform now fails at
            # first Program call instead of at compile; with one candidate
            # there is no alternative either way.
            self._cache[key] = avail[0]
            return avail[0]
        times = self.measure(node.op, in_specs, node.attrs)
        if not times:
            raise ValueError(f"no runnable backend for {node.op}")
        best = min(times, key=times.get)  # type: ignore[arg-type]
        self._cache[key] = best
        return best
