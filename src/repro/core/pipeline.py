"""Staged compilation pipeline: a named pass registry + PassManager.

This is the configurable half of the paper's "apply simplifications to the
computation graph" layer.  Individual passes stay pure ``Graph -> Graph``
functions (declared in :mod:`repro.core.passes` and registered here via
:func:`register_pass`); the :class:`PassManager` decides *which* passes run,
*in what order*, whether the graph is re-validated between passes, and
whether the list is iterated to a fixpoint.  Every pass execution is timed
and summarised in a :class:`PassStats` record, so a pipeline run doubles as
a pass-level profile — the same philosophy as the per-layer executor
instrumentation, applied to compile time.

Typical use::

    from repro.core import PassManager, default_pipeline, compile

    pm = default_pipeline()                  # the standard simplify pipeline
    pm = PassManager(["infer_shapes", "fuse_bias_act"], validate=True)
    g2 = pm.run(g)
    for s in pm.stats:
        print(s.name, s.nodes_before, "->", s.nodes_after, f"{s.seconds*1e3:.2f}ms")

``compile(graph, pipeline=pm)`` (see :mod:`repro.core.program`) threads the
manager through the full graph -> Program lowering.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.ir import Graph, GraphError

__all__ = [
    "PassStats",
    "PassManager",
    "PipelineError",
    "register_pass",
    "get_pass",
    "registered_passes",
    "make_partition_pass",
    "default_pipeline",
    "DEFAULT_PASSES",
]

PassFn = Callable[[Graph], Graph]


class PipelineError(RuntimeError):
    """Raised for unknown pass names or passes that corrupt the graph."""


@dataclass(frozen=True)
class PassStats:
    """One pass execution: node delta + wall time (the compile-time profile)."""

    name: str
    nodes_before: int
    nodes_after: int
    seconds: float
    iteration: int = 0
    changed: bool = False

    def __repr__(self) -> str:
        delta = self.nodes_after - self.nodes_before
        return (f"PassStats({self.name}: {self.nodes_before}->{self.nodes_after} "
                f"nodes ({delta:+d}), {self.seconds*1e3:.2f}ms, it={self.iteration})")


# --------------------------------------------------------------------------- #
# Named pass registry — mirrors the op registry: declare once, select by name.
# --------------------------------------------------------------------------- #

_PASSES: Dict[str, PassFn] = {}


def register_pass(name: str, fn: Optional[PassFn] = None):
    """Register ``fn`` under ``name``.  Usable as a decorator::

        @register_pass("my_pass")
        def my_pass(graph): ...

    Re-registration replaces the previous pass (same override semantics as
    :func:`repro.core.registry.impl` — third-party modules can swap in their
    own version of a stock pass).
    """
    if fn is None:
        def deco(f: PassFn) -> PassFn:
            _PASSES[name] = f
            return f
        return deco
    _PASSES[name] = fn
    return fn


def get_pass(name: str) -> PassFn:
    try:
        return _PASSES[name]
    except KeyError:
        raise PipelineError(
            f"unknown pass {name!r}; registered: {sorted(_PASSES)}") from None


def registered_passes() -> List[str]:
    return sorted(_PASSES)


@register_pass("partition")
def partition(graph: Graph) -> Graph:
    """Stamp mesh partition specs onto the graph (no-op without a mesh).

    The registry entry documents the stage; the working variant is the
    closure from :func:`make_partition_pass`, which ``compile(mesh=...)``
    appends as the *last* pass — rewrite passes rebuild Graph objects and
    would drop the stamped attributes, so partitioning always runs on the
    final graph."""
    return graph


def make_partition_pass(mesh) -> PassFn:
    """Bind ``mesh`` into a `partition` pass instance.

    The returned pass derives a PartitionSpec for every graph input, param
    and output from the serving rules in :mod:`repro.sharding.specs` and
    stores them as ``graph.partition_specs`` (name -> PartitionSpec) plus
    ``graph.partition_mesh`` ({axis: size}).  :class:`~repro.core.program.
    Program` freezes both into its ``partition`` property and serialises
    them through OXF."""
    def partition(graph: Graph) -> Graph:
        """Stamp PartitionSpecs for a bound mesh onto the final graph."""
        from repro.sharding.specs import graph_partition_specs, mesh_axes
        missing = [o for o in graph.outputs
                   if o not in graph.value_info and o not in graph.inputs]
        if missing:  # pipeline=() loads arrive without value_info
            graph = get_pass("infer_shapes")(graph)
        graph.partition_specs = graph_partition_specs(graph, mesh)
        graph.partition_mesh = mesh_axes(mesh)
        return graph
    return partition


# --------------------------------------------------------------------------- #
# PassManager
# --------------------------------------------------------------------------- #

def _freeze(x):
    if isinstance(x, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in x.items()))
    if isinstance(x, (list, tuple)):
        return tuple(_freeze(v) for v in x)
    if hasattr(x, "tobytes"):  # ndarray-valued attr
        return ("nd", getattr(x, "shape", None), x.tobytes())
    return x


def _structure(graph: Graph) -> Tuple:
    """Structural signature used for change detection / fixpoint convergence:
    node identity, wiring, attrs and backend pins.  Deliberately ignores
    value_info (shape inference is not a 'change')."""
    return tuple((n.name, n.op, tuple(n.inputs), tuple(n.outputs),
                  _freeze(n.attrs), n.backend)
                 for n in graph.nodes)


class PassManager:
    """Runs a configurable list of passes over a graph, recording PassStats.

    Parameters
    ----------
    passes:
        Sequence of pass names (looked up in the registry at ``run`` time,
        so registration order does not matter) and/or raw callables.
    validate:
        Re-run ``Graph.validate()`` after every pass; a pass that breaks
        well-formedness is reported by name instead of failing downstream.
    fixpoint:
        Iterate the whole pass list until the graph structure stops changing
        (or ``max_iters`` is hit).  Useful when passes enable each other,
        e.g. constant folding exposing new fusion opportunities.
    max_iters:
        Iteration cap for ``fixpoint=True`` (one pass over the list counts
        as one iteration).
    """

    def __init__(self, passes: Sequence[Union[str, PassFn]], *,
                 validate: bool = False, fixpoint: bool = False,
                 max_iters: int = 10, name: str = "pipeline"):
        self.name = name
        self.validate = validate
        self.fixpoint = fixpoint
        self.max_iters = max_iters
        self._passes: List[Union[str, PassFn]] = list(passes)
        self.stats: List[PassStats] = []

    # ------------------------------------------------------------------ #
    def pass_names(self) -> List[str]:
        return [p if isinstance(p, str) else getattr(p, "__name__", repr(p))
                for p in self._passes]

    def _resolved(self) -> List[Tuple[str, PassFn]]:
        out = []
        for p in self._passes:
            if isinstance(p, str):
                out.append((p, get_pass(p)))
            else:
                out.append((getattr(p, "__name__", repr(p)), p))
        return out

    # ------------------------------------------------------------------ #
    def run(self, graph: Graph) -> Graph:
        """Apply the pipeline; ``graph`` is left untouched.  Stats from the
        run replace ``self.stats``."""
        passes = self._resolved()
        self.stats = []
        g = graph
        n_iters = self.max_iters if self.fixpoint else 1
        for it in range(n_iters):
            sig_before_iter = _structure(g)
            for pname, fn in passes:
                before = len(g.nodes)
                sig_before = _structure(g)
                t0 = time.perf_counter()
                try:
                    g2 = fn(g)
                except GraphError as e:
                    raise PipelineError(f"pass {pname!r} failed: {e}") from e
                dt = time.perf_counter() - t0
                if not isinstance(g2, Graph):
                    raise PipelineError(
                        f"pass {pname!r} returned {type(g2).__name__}, not Graph")
                if self.validate:
                    try:
                        g2.validate()
                    except GraphError as e:
                        raise PipelineError(
                            f"pass {pname!r} produced a malformed graph: {e}") from e
                self.stats.append(PassStats(
                    name=pname, nodes_before=before, nodes_after=len(g2.nodes),
                    seconds=dt, iteration=it,
                    changed=_structure(g2) != sig_before))
                g = g2
            if not self.fixpoint or _structure(g) == sig_before_iter:
                break
        return g

    # ------------------------------------------------------------------ #
    def total_seconds(self) -> float:
        return sum(s.seconds for s in self.stats)

    def summary(self) -> str:
        """Human-readable per-pass table of the last ``run``."""
        lines = [f"{'pass':28s} {'nodes':>12s} {'time':>9s}  it"]
        for s in self.stats:
            lines.append(f"{s.name:28s} {s.nodes_before:5d} ->{s.nodes_after:4d} "
                         f"{s.seconds*1e3:7.2f}ms  {s.iteration}")
        lines.append(f"{'total':28s} {'':12s} {self.total_seconds()*1e3:7.2f}ms")
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"PassManager({self.name!r}, passes={self.pass_names()}, "
                f"validate={self.validate}, fixpoint={self.fixpoint})")


# The standard import-time simplification pipeline, by name.  Shape inference
# brackets the rewrite passes so every consumer sees fresh value_info.
DEFAULT_PASSES: Tuple[str, ...] = (
    "infer_shapes",
    "fold_constants",
    "fold_batchnorm",
    "fuse_bias_act",
    "fuse_elementwise",
    "eliminate_common_subexpr",
    "eliminate_dead",
    "infer_shapes",
)


def default_pipeline(*, validate: bool = False, fixpoint: bool = False) -> PassManager:
    """The standard simplify pipeline as a PassManager (what ``compile()``
    uses when no pipeline is given)."""
    from repro.core import passes as _passes  # noqa: F401  (registers passes)
    return PassManager(list(DEFAULT_PASSES), validate=validate,
                       fixpoint=fixpoint, name="default")
