"""Orpheus-JAX core: GraphIR, backend registry, passes, importer, executor.

Importing this package registers the standard NN ops (:mod:`repro.core.nnops`).
Pallas/TPU backends are registered by importing :mod:`repro.kernels.ops`
(done automatically by ``import repro``).
"""

from repro.core import nnops as _nnops  # noqa: F401  (registers standard ops)
from repro.core.executor import Executor, NodeReport
from repro.core.importer import load_graph, save_graph
from repro.core.ir import Graph, GraphError, Node, TensorSpec, topological_order
from repro.core.passes import (eliminate_common_subexpr, eliminate_dead,
                               fold_batchnorm, fold_constants, fuse_bias_act,
                               infer_shapes, simplify)
from repro.core.registry import (Cost, OpDef, OpImpl, backends_for, defop,
                                 get_impl, get_op, impl, registered_ops)
from repro.core.selector import (TPU_V5E, AutotunePolicy, BackendPolicy,
                                 CostModelPolicy, FixedPolicy, HardwareProfile)

__all__ = [
    "Executor", "NodeReport", "load_graph", "save_graph",
    "Graph", "GraphError", "Node", "TensorSpec", "topological_order",
    "eliminate_common_subexpr", "eliminate_dead", "fold_batchnorm",
    "fold_constants", "fuse_bias_act", "infer_shapes", "simplify",
    "Cost", "OpDef", "OpImpl", "backends_for", "defop", "get_impl", "get_op",
    "impl", "registered_ops",
    "TPU_V5E", "AutotunePolicy", "BackendPolicy", "CostModelPolicy",
    "FixedPolicy", "HardwareProfile",
]
