"""Orpheus-JAX core: GraphIR, backend registry, pass pipeline, importer,
compiled Program.

Importing this package registers the standard NN ops (:mod:`repro.core.nnops`)
and the standard simplification passes (:mod:`repro.core.passes`).
Pallas/TPU backends are registered by importing :mod:`repro.kernels.ops`
(done automatically by ``import repro``).

The staged compilation flow is::

    graph --PassManager--> simplified graph --BackendPolicy--> Program

driven by the top-level :func:`compile` entrypoint; the legacy ``Executor``
remains as a deprecated shim over it.
"""

from repro.core import nnops as _nnops  # noqa: F401  (registers standard ops)
from repro.core.executor import Executor
from repro.core.importer import load_graph, load_program, save_graph
from repro.core.ir import Graph, GraphError, Node, TensorSpec, topological_order
from repro.core.passes import (eliminate_common_subexpr, eliminate_dead,
                               fold_batchnorm, fold_constants, fuse_bias_act,
                               fuse_elementwise, infer_shapes, simplify)
from repro.core.pipeline import (DEFAULT_PASSES, PassManager, PassStats,
                                 PipelineError, default_pipeline, get_pass,
                                 register_pass, registered_passes)
from repro.core.program import NodeReport, Program, compile
from repro.core.quant import (QUANTIZABLE_OPS, calibrate, is_quantized,
                              quantize_graph, quantize_weight)
from repro.core.registry import (Cost, OpDef, OpImpl, backends_for, defop,
                                 get_impl, get_op, impl, registered_ops)
from repro.core.selector import (TPU_V5E, AutotunePolicy, BackendPolicy,
                                 CostModelPolicy, FixedPolicy, HardwareProfile,
                                 default_cache_path, hardware_fingerprint)

__all__ = [
    "compile", "Program", "Executor", "NodeReport",
    "load_graph", "load_program", "save_graph",
    "Graph", "GraphError", "Node", "TensorSpec", "topological_order",
    "eliminate_common_subexpr", "eliminate_dead", "fold_batchnorm",
    "fold_constants", "fuse_bias_act", "fuse_elementwise", "infer_shapes",
    "simplify",
    "DEFAULT_PASSES", "PassManager", "PassStats", "PipelineError",
    "default_pipeline", "get_pass", "register_pass", "registered_passes",
    "Cost", "OpDef", "OpImpl", "backends_for", "defop", "get_impl", "get_op",
    "impl", "registered_ops",
    "QUANTIZABLE_OPS", "calibrate", "is_quantized", "quantize_graph",
    "quantize_weight",
    "TPU_V5E", "AutotunePolicy", "BackendPolicy", "CostModelPolicy",
    "FixedPolicy", "HardwareProfile", "default_cache_path",
    "hardware_fingerprint",
]
