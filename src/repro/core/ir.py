"""GraphIR — Orpheus-JAX's computation-graph intermediate representation.

This is the analogue of the paper's ONNX-imported graph: a flat, explicitly
named operator graph over which the simplification passes
(:mod:`repro.core.passes`) run, and which the executor
(:mod:`repro.core.executor`) lowers to a jitted JAX callable with per-node
backend selection (:mod:`repro.core.registry`).

Design notes
------------
* Values are identified by string names (SSA-ish: each value produced once).
* ``Graph.params`` holds trained weights / constants as numpy or JAX arrays,
  keyed by value name; graph *inputs* are the runtime-fed tensors.
* ``value_info`` carries inferred ``TensorSpec`` metadata for every value —
  populated by :func:`repro.core.passes.infer_shapes` and consumed by the
  cost models and backend ``supports`` predicates.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "TensorSpec",
    "Node",
    "Graph",
    "GraphError",
    "topological_order",
]


class GraphError(ValueError):
    """Raised for malformed graphs (cycles, missing values, duplicate defs)."""


@dataclass(frozen=True)
class TensorSpec:
    """Shape/dtype metadata for a value in the graph."""

    shape: Tuple[int, ...]
    dtype: str = "float32"

    @property
    def nelems(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    @property
    def nbytes(self) -> int:
        return self.nelems * np.dtype(self.dtype).itemsize

    def __repr__(self) -> str:  # compact: f32[1,3,224,224]
        short = {"float32": "f32", "float16": "f16", "bfloat16": "bf16",
                 "int32": "i32", "int8": "i8", "bool": "pred"}.get(self.dtype, self.dtype)
        return f"{short}[{','.join(str(d) for d in self.shape)}]"


@dataclass
class Node:
    """One operator application.

    ``backend`` is an optional per-node override; when ``None`` the executor's
    :class:`~repro.core.selector.BackendPolicy` decides (the paper's
    runtime-selected implementation).
    """

    name: str
    op: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict[str, Any] = field(default_factory=dict)
    backend: Optional[str] = None

    def clone(self, **overrides: Any) -> "Node":
        kw = dict(
            name=self.name,
            op=self.op,
            inputs=list(self.inputs),
            outputs=list(self.outputs),
            attrs=dict(self.attrs),
            backend=self.backend,
        )
        kw.update(overrides)
        return Node(**kw)


@dataclass
class Graph:
    """A named operator graph with parameters (weights) attached."""

    name: str
    inputs: Dict[str, TensorSpec]
    outputs: List[str]
    nodes: List[Node]
    params: Dict[str, Any] = field(default_factory=dict)
    value_info: Dict[str, TensorSpec] = field(default_factory=dict)

    # ------------------------------------------------------------------ #
    # Introspection helpers
    # ------------------------------------------------------------------ #
    def producers(self) -> Dict[str, Node]:
        """Map value name -> producing node. Raises on duplicate definition."""
        out: Dict[str, Node] = {}
        for node in self.nodes:
            for v in node.outputs:
                if v in out:
                    raise GraphError(f"value {v!r} defined twice ({out[v].name}, {node.name})")
                if v in self.inputs or v in self.params:
                    raise GraphError(f"value {v!r} shadows a graph input/param")
                out[v] = node
        return out

    def consumers(self) -> Dict[str, List[Node]]:
        out: Dict[str, List[Node]] = {}
        for node in self.nodes:
            for v in node.inputs:
                out.setdefault(v, []).append(node)
        return out

    def available_values(self) -> set:
        vals = set(self.inputs) | set(self.params)
        for node in self.nodes:
            vals.update(node.outputs)
        return vals

    def spec_of(self, value: str) -> TensorSpec:
        if value in self.value_info:
            return self.value_info[value]
        if value in self.inputs:
            return self.inputs[value]
        if value in self.params:
            arr = self.params[value]
            return TensorSpec(tuple(int(d) for d in np.shape(arr)), str(np.asarray(arr).dtype))
        raise GraphError(f"no spec known for value {value!r}; run infer_shapes first")

    # ------------------------------------------------------------------ #
    # Validation
    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        """Check well-formedness: every input defined before use, no cycles,
        outputs produced, no duplicate node names."""
        self.producers()  # raises on duplicate value defs
        names = set()
        for node in self.nodes:
            if node.name in names:
                raise GraphError(f"duplicate node name {node.name!r}")
            names.add(node.name)
        available = set(self.inputs) | set(self.params)
        for node in topological_order(self):
            for v in node.inputs:
                if v not in available:
                    raise GraphError(f"node {node.name!r} uses undefined value {v!r}")
            available.update(node.outputs)
        for v in self.outputs:
            if v not in available:
                raise GraphError(f"graph output {v!r} is never produced")

    def clone(self) -> "Graph":
        return Graph(
            name=self.name,
            inputs=dict(self.inputs),
            outputs=list(self.outputs),
            nodes=[n.clone() for n in self.nodes],
            params=dict(self.params),
            value_info=dict(self.value_info),
        )

    def __repr__(self) -> str:
        return (f"Graph({self.name!r}, {len(self.nodes)} nodes, "
                f"{len(self.inputs)} inputs, {len(self.params)} params)")


def topological_order(graph: Graph) -> List[Node]:
    """Kahn's algorithm over value dependencies. Raises GraphError on cycles.

    Nodes already in a valid order pass through stably (we seed the ready
    queue in graph order), which keeps pass output deterministic.
    """
    produced_by: Dict[str, Node] = {}
    for node in graph.nodes:
        for v in node.outputs:
            produced_by[v] = node

    indegree: Dict[str, int] = {}
    dependents: Dict[str, List[Node]] = {}
    roots: List[Node] = []
    base = set(graph.inputs) | set(graph.params)
    for node in graph.nodes:
        deps = {v for v in node.inputs if v not in base}
        for v in deps:
            if v not in produced_by:
                raise GraphError(f"node {node.name!r} uses undefined value {v!r}")
        indegree[node.name] = len(deps)
        for v in deps:
            dependents.setdefault(produced_by[v].name, []).append(node)
        if not deps:
            roots.append(node)

    order: List[Node] = []
    queue = deque(roots)
    seen = set()
    while queue:
        node = queue.popleft()
        if node.name in seen:
            continue
        seen.add(node.name)
        order.append(node)
        for dep in dependents.get(node.name, []):
            indegree[dep.name] -= 1
            if indegree[dep.name] == 0:
                queue.append(dep)
    if len(order) != len(graph.nodes):
        missing = [n.name for n in graph.nodes if n.name not in seen]
        raise GraphError(f"cycle detected involving nodes {missing[:5]}")
    return order
