"""Graph simplification passes — the paper's "apply simplifications to the
computation graph" layer (§I contribution 2).

Passes are pure functions ``Graph -> Graph`` (input untouched), registered by
name in the :mod:`repro.core.pipeline` registry so a
:class:`~repro.core.pipeline.PassManager` can compose them.  The standard
pipeline (:func:`simplify`, also ``pipeline.default_pipeline()``) runs:

    infer_shapes -> fold_constants -> fold_batchnorm -> fuse_bias_act
                 -> fuse_elementwise -> eliminate_common_subexpr
                 -> eliminate_dead -> infer_shapes

All passes preserve graph semantics; ``tests/test_property.py`` property-checks
this with hypothesis-generated random graphs, and
``tests/test_pipeline_compile.py`` covers the PassManager machinery.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import Graph, GraphError, Node, TensorSpec, topological_order
from repro.core.pipeline import PassManager, register_pass
from repro.core.registry import get_impl, get_op

__all__ = [
    "infer_shapes",
    "fold_constants",
    "fold_batchnorm",
    "fuse_bias_act",
    "fuse_elementwise",
    "eliminate_dead",
    "eliminate_common_subexpr",
    "simplify",
]


# --------------------------------------------------------------------------- #
@register_pass("infer_shapes")
def infer_shapes(graph: Graph) -> Graph:
    """Populate ``value_info`` for every intermediate value."""
    g = graph.clone()
    g.validate()
    info: Dict[str, TensorSpec] = {}

    def spec(v: str) -> TensorSpec:
        if v in info:
            return info[v]
        return g.spec_of(v)

    for node in topological_order(g):
        in_specs = [spec(v) for v in node.inputs]
        try:
            out_specs = get_op(node.op).shape_fn(in_specs, node.attrs)
        except Exception as e:  # annotate which node failed
            raise GraphError(f"shape inference failed at {node.name} ({node.op}): {e}") from e
        if len(out_specs) != len(node.outputs):
            raise GraphError(
                f"{node.name}: shape_fn returned {len(out_specs)} specs for "
                f"{len(node.outputs)} outputs")
        for v, s in zip(node.outputs, out_specs):
            info[v] = s
    g.value_info = info
    return g


# --------------------------------------------------------------------------- #
@register_pass("fold_constants")
def fold_constants(graph: Graph, max_bytes: int = 1 << 27) -> Graph:
    """Evaluate nodes whose inputs are all params/constants with the ``ref``
    backend and promote results to params.  ``max_bytes`` caps the size of a
    folded result so we never materialise something huge at import time."""
    g = infer_shapes(graph)
    const = set(g.params)
    new_nodes: List[Node] = []
    for node in topological_order(g):
        if all(v in const for v in node.inputs) and node.op != "identity_barrier":
            out_specs = [g.value_info[v] for v in node.outputs]
            if sum(s.nbytes for s in out_specs) <= max_bytes:
                fn = get_impl(node.op, "ref")
                vals = fn([np.asarray(g.params[v]) for v in node.inputs], node.attrs)
                for v, val in zip(node.outputs, vals):
                    g.params[v] = np.asarray(val)
                    const.add(v)
                continue
        new_nodes.append(node)
    g.nodes = new_nodes
    # params that were only consumed by folded nodes get cleaned by DCE
    return eliminate_dead(g)


# --------------------------------------------------------------------------- #
@register_pass("fold_batchnorm")
def fold_batchnorm(graph: Graph) -> Graph:
    """Fold inference batchnorm into a preceding conv2d when the conv weight
    and all BN stats are graph params:  w' = w * s,  b' = (bias - mean*s)
    with s = scale / sqrt(var + eps), broadcast over output channels.

    Produces a ``conv2d_fused`` node (bias folded in, act 'none') so a later
    activation can still fuse into it."""
    g = infer_shapes(graph)
    producers = g.producers()
    consumers = g.consumers()
    replaced: Dict[str, Node] = {}
    drop: set = set()
    for node in g.nodes:
        if node.op != "batchnorm":
            continue
        x = node.inputs[0]
        prev = producers.get(x)
        if prev is None or prev.op != "conv2d" or len(consumers.get(x, [])) != 1:
            continue
        wname = prev.inputs[1]
        stats = node.inputs[1:]
        if wname not in g.params or any(s not in g.params for s in stats):
            continue
        w = np.asarray(g.params[wname], dtype=np.float64)
        scale, bias, mean, var = (np.asarray(g.params[s], dtype=np.float64) for s in stats)
        eps = float(node.attrs.get("eps", 1e-5))
        s = scale / np.sqrt(var + eps)
        w_f = (w * s[None, None, None, :]).astype(np.asarray(g.params[wname]).dtype)
        b_f = (bias - mean * s).astype(np.asarray(g.params[wname]).dtype)
        new_w = f"{prev.name}.folded_w"
        new_b = f"{prev.name}.folded_b"
        g.params[new_w] = w_f
        g.params[new_b] = b_f
        fused = Node(name=f"{prev.name}.bnfold", op="conv2d_fused",
                     inputs=[prev.inputs[0], new_w, new_b],
                     outputs=list(node.outputs),
                     attrs={**prev.attrs, "act": "none"},
                     backend=prev.backend)
        replaced[prev.name] = fused
        drop.add(node.name)
    if not replaced:
        return g
    new_nodes = []
    for node in g.nodes:
        if node.name in drop:
            continue
        new_nodes.append(replaced.get(node.name, node))
    g.nodes = new_nodes
    return eliminate_dead(infer_shapes(g))


# --------------------------------------------------------------------------- #
_ACTS = {"relu", "relu6", "gelu", "silu", "sigmoid", "tanh"}
_FUSABLE = {"conv2d": "conv2d_fused", "conv2d_fused": "conv2d_fused",
            "dense": "dense_fused", "dense_fused": "dense_fused"}


@register_pass("fuse_bias_act")
def fuse_bias_act(graph: Graph) -> Graph:
    """Pattern-fuse  (conv2d|dense) [-> bias_add] [-> activation]  into the
    corresponding fused op.  Only fires when the intermediate value has a
    single consumer (otherwise fusing would duplicate work)."""
    g = infer_shapes(graph)
    changed = True
    while changed:
        changed = False
        producers = g.producers()
        consumers = g.consumers()

        def sole_consumer(v: str) -> Optional[Node]:
            cs = consumers.get(v, [])
            return cs[0] if len(cs) == 1 and v not in g.outputs else None

        for node in list(g.nodes):
            if node.op not in _FUSABLE:
                continue
            out = node.outputs[0]
            nxt = sole_consumer(out)
            if nxt is None:
                continue
            fused: Optional[Node] = None
            if nxt.op == "bias_add" and nxt.inputs[0] == out and node.op in ("conv2d", "dense"):
                fused = Node(name=f"{node.name}.fb", op=_FUSABLE[node.op],
                             inputs=list(node.inputs) + [nxt.inputs[1]],
                             outputs=list(nxt.outputs),
                             attrs={**node.attrs, "act": "none"}, backend=node.backend)
            elif nxt.op in _ACTS and node.op in ("conv2d_fused", "dense_fused") \
                    and node.attrs.get("act", "none") in ("none", None):
                fused = node.clone(name=f"{node.name}.fa",
                                   outputs=list(nxt.outputs),
                                   attrs={**node.attrs, "act": nxt.op})
            if fused is not None:
                g.nodes = [n for n in g.nodes if n.name not in (node.name, nxt.name)]
                g.nodes.append(fused)
                g.nodes = topological_order(g)
                g = infer_shapes(g)
                changed = True
                break
    return g


# --------------------------------------------------------------------------- #
# Unary elementwise ops that can be collapsed into one fused_elementwise node.
_EW_CHAIN = {"relu", "relu6", "gelu", "silu", "sigmoid", "tanh", "identity"}


def _chain_ops(node: Node) -> Tuple[str, ...]:
    if node.op == "fused_elementwise":
        return tuple(node.attrs["ops"])
    return (node.op,)


@register_pass("fuse_elementwise")
def fuse_elementwise(graph: Graph) -> Graph:
    """Collapse chains of unary elementwise ops (relu -> tanh -> ...) into a
    single ``fused_elementwise`` node whose ``ops`` attr lists the stages.

    One fused node means one pass over the tensor instead of one per stage
    (intermediates never round-trip through HBM) and one backend decision
    for the whole chain.  Only fires when the intermediate value has a
    single consumer and is not a graph output."""
    g = graph.clone()
    changed = True
    while changed:
        changed = False
        producers = g.producers()
        consumers = g.consumers()
        for node in g.nodes:
            if node.op not in _EW_CHAIN and node.op != "fused_elementwise":
                continue
            src = node.inputs[0]
            prev = producers.get(src)
            if prev is None or (prev.op not in _EW_CHAIN
                                and prev.op != "fused_elementwise"):
                continue
            if len(consumers.get(src, [])) != 1 or src in g.outputs:
                continue
            fused = Node(name=f"{prev.name}.ew", op="fused_elementwise",
                         inputs=list(prev.inputs), outputs=list(node.outputs),
                         attrs={"ops": _chain_ops(prev) + _chain_ops(node)},
                         backend=node.backend or prev.backend)
            g.nodes = [n for n in g.nodes if n.name not in (prev.name, node.name)]
            g.nodes.append(fused)
            g.nodes = topological_order(g)
            changed = True
            break
    if g.value_info:
        g = infer_shapes(g)
    return g


# --------------------------------------------------------------------------- #
@register_pass("eliminate_dead")
def eliminate_dead(graph: Graph) -> Graph:
    """Drop nodes (and params) that do not contribute to graph outputs."""
    g = graph.clone()
    producers = g.producers()
    live_vals: set = set(g.outputs)
    live_nodes: set = set()
    stack = [v for v in g.outputs]
    while stack:
        v = stack.pop()
        node = producers.get(v)
        if node is None or node.name in live_nodes:
            continue
        live_nodes.add(node.name)
        for u in node.inputs:
            if u not in live_vals:
                live_vals.add(u)
                stack.append(u)
    g.nodes = [n for n in g.nodes if n.name in live_nodes]
    g.params = {k: v for k, v in g.params.items() if k in live_vals}
    g.value_info = {k: v for k, v in g.value_info.items()
                    if k in live_vals or k in g.inputs}
    return g


# --------------------------------------------------------------------------- #
def _node_key(node: Node) -> Tuple:
    def freeze(x: Any):
        if isinstance(x, dict):
            return tuple(sorted((k, freeze(v)) for k, v in x.items()))
        if isinstance(x, (list, tuple)):
            return tuple(freeze(v) for v in x)
        if isinstance(x, np.ndarray):
            return ("ndarray", x.shape, str(x.dtype), x.tobytes())
        return x

    return (node.op, tuple(node.inputs), freeze(node.attrs))


@register_pass("eliminate_common_subexpr")
def eliminate_common_subexpr(graph: Graph) -> Graph:
    """Merge structurally identical nodes (same op, inputs, attrs)."""
    g = graph.clone()
    seen: Dict[Tuple, Node] = {}
    rename: Dict[str, str] = {}
    new_nodes: List[Node] = []
    for node in topological_order(g):
        node = node.clone(inputs=[rename.get(v, v) for v in node.inputs])
        key = _node_key(node)
        if key in seen:
            keep = seen[key]
            for old, new in zip(node.outputs, keep.outputs):
                rename[old] = new
        else:
            seen[key] = node
            new_nodes.append(node)
    g.nodes = new_nodes
    g.outputs = [rename.get(v, v) for v in g.outputs]
    return eliminate_dead(g)


# --------------------------------------------------------------------------- #
def simplify(graph: Graph, *, fold_bn: bool = True, fuse: bool = True,
             fold_const: bool = True, cse: bool = True,
             fuse_ew: bool = True) -> Graph:
    """The standard import-time simplification pipeline.

    This is now sugar over a :class:`~repro.core.pipeline.PassManager` built
    from the registered passes; drop a flag to skip the corresponding pass,
    or construct a PassManager directly for full control (custom order,
    per-pass stats, validation, fixpoint iteration).
    """
    names = ["infer_shapes"]
    if fold_const:
        names.append("fold_constants")
    if fold_bn:
        names.append("fold_batchnorm")
    if fuse:
        names.append("fuse_bias_act")
    if fuse_ew:
        names.append("fuse_elementwise")
    if cse:
        names.append("eliminate_common_subexpr")
    names += ["eliminate_dead", "infer_shapes"]
    return PassManager(names, name="simplify").run(graph)
